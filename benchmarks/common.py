"""Shared benchmark infrastructure: trained tiny models + evaluation.

The paper evaluates pruning methods on pretrained LLMs; offline we train
tiny transformer + Mamba LMs once (cached under experiments/) and run
every table against them.  Perplexity is on the synthetic eval stream —
EXPERIMENTS.md compares *orderings and gaps*, the quantities the paper's
claims are about (DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
import os
import sys
from typing import Dict, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.paper_tiny_lm import MAMBA
from repro.data import DataPipeline, calibration_batches
from repro.models import LM
from repro.optim import AdamW
from repro.optim.schedules import warmup_cosine
from repro.train import TrainConfig, Trainer

CKPT_ROOT = os.path.join(os.path.dirname(__file__), "..", "experiments")


@dataclasses.dataclass
class BenchResult:
    name: str
    us_per_call: float        # wall time of the measured operation (µs)
    derived: str              # the table's metric, e.g. "ppl=8.07"
    # machine-readable metrics for BENCH_<sha>.json / the CI bench gate
    # (benchmarks.gate): keys named "tok_s*" gate hard on regression
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def trained_model(kind: str = "lm", steps: int = 300
                  ) -> Tuple[LM, dict, DataPipeline]:
    """Train-once-and-cache the tiny LM ('lm') or tiny Mamba ('mamba')."""
    cfg = get_config("paper_tiny_lm") if kind == "lm" else MAMBA
    model = LM(cfg)
    out = os.path.join(CKPT_ROOT, f"tiny_{kind}_ckpt")
    pipe = DataPipeline(cfg, global_batch=16, seq_len=64, seed=0)
    opt = AdamW(lr=warmup_cosine(1e-3, 20, steps))
    tc = TrainConfig(total_steps=steps, global_batch=16, seq_len=64,
                     ckpt_every=steps, out_dir=out, log_every=100)
    trainer = Trainer(model, opt, pipe, tc)
    params, _, _ = trainer.run()       # no-op if the checkpoint exists
    return model, params, pipe


def eval_ppl(model: LM, params, pipe: DataPipeline, n: int = 8) -> float:
    tot = cnt = 0.0
    for i in range(n):
        _, m = model.loss_fn(params, pipe.eval_batch(i))
        tot += float(m["ce"]) * float(m["tokens"])
        cnt += float(m["tokens"])
    return float(np.exp(tot / cnt))


def eval_last_token_acc(model: LM, params, pipe: DataPipeline,
                        n: int = 8) -> float:
    """LAMBADA-analogue: accuracy of predicting the final token of each
    eval segment (the paper's most sparsity-sensitive metric, Sec. 5.3)."""
    hit = tot = 0
    for i in range(n):
        batch = pipe.eval_batch(i)
        logits, _ = model.forward(params, batch)
        pred = jnp.argmax(logits[:, -2, :], axis=-1)
        hit += int(jnp.sum(pred == batch["tokens"][:, -1]))
        tot += int(batch["tokens"].shape[0])
    return hit / tot


def calib_for(model: LM, n_samples: int = 32, seq_len: int = 64):
    return calibration_batches(model.cfg, n_samples=n_samples,
                               seq_len=seq_len, batch=8)
