"""Paper Table 2 analogue: high sparsity (70% / 80%) — the gap between
SparseGPT (SS) and ours (SM) must WIDEN as sparsity grows, plus the
magnitude/wanda baselines for reference."""

from __future__ import annotations

import time
from typing import List

from benchmarks.common import BenchResult, calib_for, eval_ppl, trained_model
from repro.core import PruningEngine


def run(fast: bool = False) -> List[BenchResult]:
    model, params, pipe = trained_model("lm")
    calib = calib_for(model)
    dense = eval_ppl(model, params, pipe)
    out = [BenchResult("table2/dense", 0.0, f"ppl={dense:.4f}")]

    sparsities = ["0.7", "0.8"] if not fast else ["0.7"]
    methods = ["magnitude", "wanda", "SS", "SM"]
    for sp in sparsities:
        for method in methods:
            t0 = time.monotonic()
            eng = PruningEngine(model, sp, method=method, blocksize=64)
            pruned, _ = eng.run(params, calib)
            dt = time.monotonic() - t0
            ppl = eval_ppl(model, pruned, pipe)
            out.append(BenchResult(
                f"table2/{sp}/{method}", dt * 1e6, f"ppl={ppl:.4f}"))
    return out
