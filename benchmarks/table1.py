"""Paper Table 1 analogue: perplexity of SS/SM (unstructured 50%) and
SS/SM/MS/MM (2:4) across block sizes, on the trained tiny LM.

Paper claims validated here:
  - SM < SS for unstructured;  SM/MM < SS for 2:4;
  - MM typically best, SM ≈ MM at lower complexity (their recommendation);
  - holds across block sizes (S=64 and S=all).
"""

from __future__ import annotations

import time
from typing import List

from benchmarks.common import (
    BenchResult,
    calib_for,
    eval_ppl,
    trained_model,
)
from repro.core import PruningEngine


def run(fast: bool = False) -> List[BenchResult]:
    model, params, pipe = trained_model("lm")
    calib = calib_for(model)
    dense = eval_ppl(model, params, pipe)
    out = [BenchResult("table1/dense", 0.0, f"ppl={dense:.4f}")]

    blocksizes = [64] if fast else [32, 64]
    cases = []
    for bs in blocksizes:
        cases += [("0.5", m, bs) for m in ("SS", "SM")]
        cases += [("2:4", m, bs) for m in ("SS", "SM", "MS", "MM")]

    for spec, method, bs in cases:
        t0 = time.monotonic()
        eng = PruningEngine(model, spec, method=method, blocksize=bs)
        pruned, _ = eng.run(params, calib)
        dt = time.monotonic() - t0
        ppl = eval_ppl(model, pruned, pipe)
        name = f"table1/{spec}/{method}/S={bs}"
        out.append(BenchResult(name, dt * 1e6, f"ppl={ppl:.4f}"))
    return out
