"""Benchmark harness: one module per paper table + kernels + roofline.

  PYTHONPATH=src python -m benchmarks.run [--only table1,...] [--fast]
  PYTHONPATH=src python -m benchmarks.run --smoke --json BENCH_$SHA.json

Prints ``name,us_per_call,derived`` CSV (and writes
experiments/bench_results.csv).  ``--json`` additionally writes a
machine-readable report — tokens/sec, utilization, prune wall-clock —
that the CI ``bench-gate`` job uploads as an artifact and diffs against
the checked-in ``benchmarks/baseline.json`` (see benchmarks.gate;
refresh the baseline with ``--json benchmarks/baseline.json``).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MODULES = ("table1", "table2", "table3", "ablation", "kernelbench",
           "roofline", "calib_pipeline", "serve_throughput")
# the CI smoke subset: cheap, but together they exercise the trained-model
# cache, a full engine run (both pipeline modes), the continuous-batching
# serve runtime (paged KV + state pool + scheduler) and the CSV plumbing
SMOKE_MODULES = ("calib_pipeline", "serve_throughput")


def _git_sha() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(__file__)).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def write_json(path: str, results) -> None:
    import jax

    report = {
        "sha": _git_sha(),
        "jax": jax.__version__,
        "results": {
            r.name: {"us_per_call": r.us_per_call, "derived": r.derived,
                     "metrics": r.metrics}
            for r in results
        },
    }
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {MODULES}")
    ap.add_argument("--fast", action="store_true",
                    help="reduced sweeps (CI smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help=f"CI smoke: --fast over {SMOKE_MODULES} "
                         "(unless --only narrows further)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write a machine-readable BENCH report "
                         "(the CI bench-gate artifact / baseline.json)")
    args = ap.parse_args()

    if args.smoke:
        args.fast = True
    default = list(SMOKE_MODULES) if args.smoke else list(MODULES)
    chosen = args.only.split(",") if args.only else default
    results = []
    for name in chosen:
        if name not in MODULES:
            raise SystemExit(f"unknown benchmark {name!r}; pick from "
                             f"{MODULES}")
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        print(f"# running {name} ...", file=sys.stderr, flush=True)
        results.extend(mod.run(fast=args.fast))

    print("name,us_per_call,derived")
    lines = [r.csv() for r in results]
    for line in lines:
        print(line)
    out_path = os.path.join(os.path.dirname(__file__), "..",
                            "experiments", "bench_results.csv")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(lines) + "\n")
    if args.json:
        write_json(args.json, results)


if __name__ == "__main__":
    main()
