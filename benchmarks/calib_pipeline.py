"""Calibration-pipeline benchmark: serial vs pipelined PruningEngine.

Measures, on the trained tiny LM over an 8-virtual-device
(pod, data, model) mesh:

  - end-to-end prune wall-clock of the serial reference loop
    (``pipeline="off"``) vs the async scheduler (core.pipeline) with
    calibration sharded over the 4 pod×data slices;
  - the instrumented capture/solve/propagate stage costs and the overlap
    fraction the async dispatch wins back;
  - mask/weight equivalence of the two paths (the scheduler must be a
    pure perf change).

The XLA device count locks at first jax import, so ``run()`` spawns a
subprocess with ``--xla_force_host_platform_device_count=8`` (the same
trick as tests/test_dist.py) and parses its JSON report.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run(fast: bool = False) -> List["BenchResult"]:
    from benchmarks.common import BenchResult, trained_model

    trained_model("lm")            # train/cache the ckpt before the child
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.calib_pipeline", "--child"]
    if fast:
        cmd.append("--fast")
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=REPO, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(
            f"calib_pipeline child failed:\n{out.stdout}\n{out.stderr}")
    r = json.loads(out.stdout.strip().splitlines()[-1])

    # equivalence gate: masks may flip only on float-level score ties
    # (different Hessian reduction order), quality must be unchanged
    ppl_gap = abs(r["ppl_pipelined"] - r["ppl_serial"]) / r["ppl_serial"]
    if r["mask_agreement"] < 0.999 or ppl_gap > 0.02:
        raise RuntimeError(
            f"pipelined != serial: mask_agreement={r['mask_agreement']:.5f} "
            f"ppl {r['ppl_serial']:.4f} vs {r['ppl_pipelined']:.4f}")
    speedup = r["serial_s"] / max(r["pipelined_s"], 1e-9)
    local_speedup = r["local_serial_s"] / max(r["local_pipelined_s"], 1e-9)
    overlap = max(0.0, 1.0 - r["pipelined_s"] / max(r["stage_total_s"], 1e-9))
    local_overlap = max(0.0, 1.0 - r["local_pipelined_warm_s"]
                        / max(r["local_stage_total_s"], 1e-9))
    return [
        BenchResult("calib_pipeline/local/serial",
                    r["local_serial_s"] * 1e6,
                    f"wall={r['local_serial_s']:.2f}s"),
        BenchResult("calib_pipeline/local/pipelined",
                    r["local_pipelined_s"] * 1e6,
                    f"wall={r['local_pipelined_s']:.2f}s "
                    f"speedup={local_speedup:.2f}x",
                    metrics={"prune_wall_s": r["local_pipelined_s"],
                             "speedup": local_speedup}),
        BenchResult(
            "calib_pipeline/local/stages", r["local_stage_total_s"] * 1e6,
            f"capture={r['local_capture_s']:.2f}s "
            f"solve={r['local_solve_s']:.2f}s "
            f"propagate={r['local_propagate_s']:.2f}s "
            f"overlap={local_overlap:.0%}"),
        BenchResult("calib_pipeline/mesh/serial", r["serial_s"] * 1e6,
                    f"wall={r['serial_s']:.2f}s"),
        BenchResult("calib_pipeline/mesh/pipelined", r["pipelined_s"] * 1e6,
                    f"wall={r['pipelined_s']:.2f}s speedup={speedup:.2f}x "
                    f"shards={r['calib_shards']}",
                    metrics={"prune_wall_s": r["pipelined_s"],
                             "speedup": speedup}),
        BenchResult(
            "calib_pipeline/mesh/stages", r["stage_total_s"] * 1e6,
            f"capture={r['capture_s']:.2f}s solve={r['solve_s']:.2f}s "
            f"propagate={r['propagate_s']:.2f}s overlap={overlap:.0%}"),
    ]


# ----------------------------------------------------------------------
# child: runs under 8 virtual devices
# ----------------------------------------------------------------------
def _child(fast: bool) -> None:
    import time

    import jax
    import numpy as np

    from benchmarks.common import eval_ppl, trained_model
    from repro.core import PruningEngine
    from repro.core.pipeline import run_pipelined
    from repro.data import calibration_batches
    from repro.dist import use_mesh

    model, params, pipe = trained_model("lm")
    n_samples = 128 if fast else 256
    calib = calibration_batches(model.cfg, n_samples=n_samples,
                                seq_len=64, batch=8)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

    def timed(engine_kwargs, runner=None, with_mesh=True):
        import contextlib

        ctx = use_mesh(mesh) if with_mesh else contextlib.nullcontext()
        with ctx:
            eng = PruningEngine(model, "2:4", method="SM", blocksize=64,
                                **engine_kwargs)
            t0 = time.monotonic()
            if runner is None:
                pruned, _ = eng.run(params, calib)
            else:
                pruned, _ = runner(eng)
            for leaf in jax.tree.leaves(pruned):
                jax.block_until_ready(leaf)
            return eng, pruned, time.monotonic() - t0

    # pipelined runs FIRST (cold compile caches); the serial reference
    # then inherits any warm solve cache — measured speedups are
    # therefore conservative lower bounds
    _, _, local_pipe_s = timed({}, with_mesh=False)
    _, _, local_serial_s = timed({"pipeline": "off"}, with_mesh=False)
    # local instrumented pass: single-device stage costs — against the
    # async local wall this measures the dispatch overlap
    ileng, _, _ = timed(
        {}, runner=lambda e: run_pipelined(e, params, calib,
                                           instrument=True),
        with_mesh=False)
    ilstats = ileng.last_pipeline_stats
    # warm async pass — same compile state as the instrumented pass, so
    # stage_total vs this wall isolates the dispatch overlap
    _, _, local_warm_s = timed({}, with_mesh=False)

    eng, p_pipe, pipelined_s = timed({})
    stats = eng.last_pipeline_stats
    _, p_serial, serial_s = timed({"pipeline": "off"})
    # instrumented pass: block per stage → true stage costs; its
    # stage_total vs the async pass's wall measures the overlap won
    ieng, _, _ = timed(
        {}, runner=lambda e: run_pipelined(e, params, calib,
                                           instrument=True))
    istats = ieng.last_pipeline_stats

    total, agreeing = 0, 0
    for a, b in zip(jax.tree.leaves(p_serial), jax.tree.leaves(p_pipe)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        agree = (a == 0) == (b == 0)
        total += agree.size
        agreeing += int(agree.sum())

    print(json.dumps({
        "serial_s": serial_s,
        "pipelined_s": pipelined_s,
        "local_serial_s": local_serial_s,
        "local_pipelined_s": local_pipe_s,
        "local_pipelined_warm_s": local_warm_s,
        "local_capture_s": ilstats.capture_s,
        "local_solve_s": ilstats.solve_s,
        "local_propagate_s": ilstats.propagate_s,
        "local_stage_total_s": ilstats.stage_total(),
        "calib_shards": stats.calib_shards,
        "compiles": stats.compiles,
        "capture_s": istats.capture_s,
        "solve_s": istats.solve_s,
        "propagate_s": istats.propagate_s,
        "stage_total_s": istats.stage_total(),
        "mask_agreement": agreeing / total,
        "ppl_serial": eval_ppl(model, p_serial, pipe),
        "ppl_pipelined": eval_ppl(model, p_pipe, pipe),
    }))


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child("--fast" in sys.argv)
    else:
        for res in run(fast="--fast" in sys.argv):
            print(res.csv())
