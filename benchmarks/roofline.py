"""§Roofline reporting: aggregate experiments/dryrun.jsonl into the
per-(arch × shape × mesh) three-term roofline table, plus the ISSUE-9
serve bytes-moved model (compressed 2:4 weights + int8 KV).

The dry-run (launch/dryrun.py) must have produced the JSONL; this module
just reduces it (no jax device work) so `-m benchmarks.run` stays fast.
The serve-bytes section is pure arithmetic on the tiny-LM config — the
HBM-traffic bound a weight-/KV-bound decode step obeys on hardware,
reported next to the measured serve_throughput legs because the CPU
interpret oracle cannot exhibit it (docs/serving.md)."""

from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import BenchResult

DRYRUN_PATH = os.path.join(
    os.path.dirname(__file__), "..", "experiments", "dryrun.jsonl")


def load_records(path: str = DRYRUN_PATH):
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"],)] = r  # last wins
    return list(recs.values())


def serve_bytes(config_name: str = "paper_tiny_lm") -> List[BenchResult]:
    """Decode-step HBM traffic model, dense vs compressed (ISSUE-9).

    Weight traffic: every decode step streams all projection matrices
    once.  2:4 packing replaces K·N·4B (f32) with K/2·N·(4+1)B =
    0.625× (idx stored int8 here; 2-bit idx on real TPU → 0.5625×).
    KV traffic: a decode token reads the whole live KV history once —
    int8 pages cost 1B + 4B/head_dim-per-row scale vs 4B fp32.  Biases,
    norms, embeddings keep their bytes (the embedding table is read
    per-token, not per-weight-stream, and is left dense)."""
    from repro.configs import get_config

    c = get_config(config_name)
    d, f = c.d_model, c.d_ff
    hd = c.head_dim or d // c.num_heads
    kvd = c.num_kv_heads * hd
    # per-layer matmul weight counts (swiglu: wi+wg+wo; attn: q,k,v,o)
    per_layer = (d * d + 2 * d * kvd + d * d) + (2 * d * f + f * d)
    w_dense = c.num_layers * per_layer * 4
    w_packed_f32 = w_dense / 4 * 2.5            # vals f32/2 + idx int8
    w_packed_tpu = w_dense / 4 * 2.25           # 2-bit idx packing
    kv_fp32 = 2 * c.num_layers * kvd * 4        # bytes per cached token
    kv_int8 = 2 * c.num_layers * kvd * (1 + 4 / hd)
    out = [
        BenchResult(
            "roofline/serve_bytes/weights", 0.0,
            f"dense={w_dense / 1e6:.2f}MB/step "
            f"packed_f32={w_packed_f32 / 1e6:.2f}MB "
            f"({w_packed_f32 / w_dense:.4f}x, "
            f"modeled {w_dense / w_packed_f32:.2f}x) "
            f"packed_2bit={w_packed_tpu / w_dense:.4f}x "
            f"(modeled {w_dense / w_packed_tpu:.2f}x)",
            metrics={"weight_bytes_frac_f32": w_packed_f32 / w_dense,
                     "weight_bytes_frac_2bit": w_packed_tpu / w_dense,
                     "modeled_speedup_f32": w_dense / w_packed_f32}),
        BenchResult(
            "roofline/serve_bytes/kv", 0.0,
            f"fp32={kv_fp32}B/tok int8={kv_int8:.0f}B/tok "
            f"({kv_int8 / kv_fp32:.4f}x, capacity "
            f"{kv_fp32 / kv_int8:.2f}x at fixed HBM)",
            metrics={"kv_bytes_frac": kv_int8 / kv_fp32,
                     "kv_capacity_x": kv_fp32 / kv_int8}),
    ]
    return out


def run(fast: bool = False) -> List[BenchResult]:
    recs = load_records()
    out: List[BenchResult] = serve_bytes()
    if not recs:
        return out + [BenchResult(
            "roofline/missing", 0.0,
            "run `python -m repro.launch.dryrun --all --multi-pod both "
            "--out experiments/dryrun.jsonl` first")]
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    failed = [r for r in recs if r["status"] == "failed"]
    out.append(BenchResult(
        "roofline/cells", 0.0,
        f"ok={len(ok)} skipped={len(skipped)} failed={len(failed)}"))
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        terms = (f"c={r['t_compute_s'] * 1e3:.2f}ms "
                 f"m={r['t_memory_s'] * 1e3:.2f}ms "
                 f"x={r['t_collective_s'] * 1e3:.2f}ms "
                 f"dom={r['dominant']} "
                 f"useful={r['useful_flop_ratio']:.3f}"
                 if r.get("useful_flop_ratio") else "")
        out.append(BenchResult(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            r["compile_s"] * 1e6, terms))
    return out
