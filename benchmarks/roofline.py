"""§Roofline reporting: aggregate experiments/dryrun.jsonl into the
per-(arch × shape × mesh) three-term roofline table.

The dry-run (launch/dryrun.py) must have produced the JSONL; this module
just reduces it (no jax device work) so `-m benchmarks.run` stays fast.
"""

from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import BenchResult

DRYRUN_PATH = os.path.join(
    os.path.dirname(__file__), "..", "experiments", "dryrun.jsonl")


def load_records(path: str = DRYRUN_PATH):
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"],)] = r  # last wins
    return list(recs.values())


def run(fast: bool = False) -> List[BenchResult]:
    recs = load_records()
    out: List[BenchResult] = []
    if not recs:
        return [BenchResult(
            "roofline/missing", 0.0,
            "run `python -m repro.launch.dryrun --all --multi-pod both "
            "--out experiments/dryrun.jsonl` first")]
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    failed = [r for r in recs if r["status"] == "failed"]
    out.append(BenchResult(
        "roofline/cells", 0.0,
        f"ok={len(ok)} skipped={len(skipped)} failed={len(failed)}"))
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        terms = (f"c={r['t_compute_s'] * 1e3:.2f}ms "
                 f"m={r['t_memory_s'] * 1e3:.2f}ms "
                 f"x={r['t_collective_s'] * 1e3:.2f}ms "
                 f"dom={r['dominant']} "
                 f"useful={r['useful_flop_ratio']:.3f}"
                 if r.get("useful_flop_ratio") else "")
        out.append(BenchResult(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            r["compile_s"] * 1e6, terms))
    return out
