"""Paper Table 3 analogue: Mamba-family LM pruning + the last-token-
prediction accuracy (LAMBADA-analogue — the paper's most sparsity-
sensitive metric) alongside perplexity."""

from __future__ import annotations

import time
from typing import List

from benchmarks.common import (
    BenchResult,
    calib_for,
    eval_last_token_acc,
    eval_ppl,
    trained_model,
)
from repro.core import PruningEngine


def run(fast: bool = False) -> List[BenchResult]:
    model, params, pipe = trained_model("mamba")
    calib = calib_for(model)
    dense_ppl = eval_ppl(model, params, pipe)
    dense_acc = eval_last_token_acc(model, params, pipe)
    out = [BenchResult("table3/mamba/dense", 0.0,
                       f"ppl={dense_ppl:.4f} acc={dense_acc:.3f}")]

    methods = ["magnitude", "wanda", "SS", "SM"]
    for method in methods:
        t0 = time.monotonic()
        eng = PruningEngine(model, "0.5", method=method, blocksize=64)
        pruned, _ = eng.run(params, calib)
        dt = time.monotonic() - t0
        ppl = eval_ppl(model, pruned, pipe)
        acc = eval_last_token_acc(model, pruned, pipe)
        out.append(BenchResult(
            f"table3/mamba/0.5/{method}", dt * 1e6,
            f"ppl={ppl:.4f} acc={acc:.3f}"))
    return out
