"""CI bench-regression gate: diff a BENCH_<sha>.json against baseline.

  PYTHONPATH=src python -m benchmarks.gate BENCH_<sha>.json \\
      benchmarks/baseline.json [--threshold 0.2]

Gate policy (docs in benchmarks/README.md):

  - **throughput** (any metric named ``tok_s``): HARD failure when the
    current value drops more than ``--threshold`` (default 20%) below
    the baseline — the regression gate;
  - **prefix reuse** (``prefill_tok_saved_frac`` — fraction of prompt
    tokens the serve_throughput prefix leg attached from the cache
    instead of prefilling, ISSUE-7): HARD failure on a >``--threshold``
    drop (reuse regressed);
  - **step latency** (``step_ms_p50`` — p50 per-fused-decode-step wall
    from serve_throughput): HARD failure when it RISES more than
    ``--threshold`` above baseline (lower is better — the
    device-resident decode loop's headline metric, ISSUE-5);
  - **time-to-first-token** (``ttft_ms_p50`` — p50 submit→first-token
    under serve_throughput's oversubscribed streaming leg): HARD
    failure when it RISES more than ``--threshold`` (lower is better —
    the serving front end's headline SLA metric, ISSUE-6);
  - **KV pool footprint** (``kv_pool_bytes_per_tok`` — pool HBM bytes
    per token of KV capacity, serve_throughput sparse/kv_int8 legs,
    ISSUE-9): HARD failure when it RISES more than ``--threshold``
    (lower is better — a rise means int8 page packing or the pool
    sizing regressed).  The sparse leg's ``step_ms_p50`` rides the
    existing step-latency gate by key name;
  - **recovery window** (``recovery_ms`` — median supervisor
    crash-detection → restart + in-flight-failover wall from
    serve_throughput's chaos leg, ISSUE-10): HARD failure when it RISES
    more than ``--threshold`` (lower is better — fault recovery is the
    leg's headline metric; the bit-exact stream check is enforced
    inside the leg itself, not here).  Because the baseline sits near
    scheduler granularity, the rise must also clear an absolute 1ms
    noise floor (``NOISE_FLOOR``) to fail;
  - everything else (utilization, syncs/token, speedup ratios, prune
    wall-clock) is reported as an informational delta only: wall-clocks
    and thin speedup margins vary too much across runner generations to
    fail a PR on.

Results present on only one side are reported and skipped (renamed or
newly added benchmarks don't break the gate; refresh the baseline with
``python -m benchmarks.run --smoke --json benchmarks/baseline.json``).
"""

from __future__ import annotations

import argparse
import json
import sys

# higher is better, gated on drops: throughput everywhere, plus the
# prefix leg's fraction of prompt tokens served from the prefix cache
# instead of prefilled (ISSUE-7 — a drop means reuse broke)
HARD_METRICS = ("tok_s", "prefill_tok_saved_frac")
# lower is better, gated on rises: p50 fused-step latency (ISSUE-5),
# p50 time-to-first-token under the oversubscribed streaming workload
# (ISSUE-6 — queueing + chunked prefill latency the front end exposes),
# pool HBM bytes per KV-capacity token (ISSUE-9 — int8 page packing),
# and the supervisor's crash-detection → restart + failover window
# under the serve_throughput chaos leg (ISSUE-10 — a rise means
# detection, restart or the failover retry path regressed)
HARD_METRICS_LOWER = ("step_ms_p50", "ttft_ms_p50",
                      "kv_pool_bytes_per_tok", "recovery_ms")
# absolute noise floors for lower-is-better metrics whose baselines sit
# near thread-scheduling granularity: a rise must clear BOTH the
# relative threshold and this absolute delta to fail the gate.
# recovery_ms is ~1ms of supervisor wakeups + session rebuild, so a
# 20%-relative-only gate would flake on scheduler jitter; a real
# regression (extra poll interval, recompile in restart, retry storm)
# clears 1ms immediately.
NOISE_FLOOR = {"recovery_ms": 1.0}


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def compare(current: dict, baseline: dict, threshold: float):
    """Returns (failures, report_lines)."""
    failures, lines = [], []
    cur, base = current["results"], baseline["results"]
    for name in sorted(set(cur) | set(base)):
        if name not in cur:
            lines.append(f"  {name}: only in baseline (skipped)")
            continue
        if name not in base:
            lines.append(f"  {name}: new (no baseline)")
            continue
        cm, bm = cur[name].get("metrics", {}), base[name].get("metrics", {})
        for key in sorted(set(cm) & set(bm)):
            c, b = cm[key], bm[key]
            if not b:
                continue
            delta = c / b - 1.0
            tag = f"  {name}.{key}: {b:.3f} -> {c:.3f} ({delta:+.1%})"
            if key in HARD_METRICS and delta < -threshold:
                failures.append(tag + f"  [> {threshold:.0%} regression]")
            elif (key in HARD_METRICS_LOWER and delta > threshold
                  and c - b > NOISE_FLOOR.get(key, 0.0)):
                failures.append(
                    tag + f"  [> {threshold:.0%} lower-is-better "
                          f"regression]"
                )
            lines.append(tag)
    return failures, lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="BENCH_<sha>.json from this run")
    ap.add_argument("baseline", help="checked-in benchmarks/baseline.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.2,
        help="allowed fractional throughput drop (default 0.2)",
    )
    args = ap.parse_args()

    current, baseline = _load(args.current), _load(args.baseline)
    failures, lines = compare(current, baseline, args.threshold)
    print(
        f"bench gate: {current.get('sha', '?')[:12]} vs baseline "
        f"{baseline.get('sha', '?')[:12]} (threshold {args.threshold:.0%})"
    )
    print("\n".join(lines))
    if failures:
        print("\nREGRESSIONS:", file=sys.stderr)
        print("\n".join(failures), file=sys.stderr)
        raise SystemExit(1)
    print("gate: OK")


if __name__ == "__main__":
    main()
