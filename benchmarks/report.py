"""Render experiments/dryrun*.jsonl into the EXPERIMENTS.md §Roofline
markdown table (one row per arch × shape × mesh).

  PYTHONPATH=src python -m benchmarks.report [path ...]
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(path: str) -> List[dict]:
    recs: Dict[tuple, dict] = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return list(recs.values())


def fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b / 1e9:.1f}GB"
    if b >= 1e6:
        return f"{b / 1e6:.0f}MB"
    return f"{b / 1e3:.0f}KB"


def fmt_s(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    return f"{s * 1e3:.2f}ms"


def table(recs: List[dict], mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | "
        "roofline-frac | MODEL/impl FLOPs | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — |"
                f" — | {r['reason']} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                        f"{r.get('error', '?')} | | | | | | |")
            continue
        hbm = r["argument_bytes"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute_s'])} | "
            f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
            f"{r['useful_flop_ratio']:.2f} | {fmt_bytes(hbm)} |")
    return "\n".join(rows)


def main() -> None:
    paths = sys.argv[1:] or ["experiments/dryrun.jsonl"]
    for path in paths:
        recs = load(path)
        for mesh in ("16x16", "2x16x16"):
            n = sum(1 for r in recs if r["mesh"] == mesh)
            if not n:
                continue
            print(f"\n### {path} — mesh {mesh}\n")
            print(table(recs, mesh))


if __name__ == "__main__":
    main()
