"""Paper Fig. A1 analogue: dampening ratio γ and calibration-set size.

Claims: smaller γ → better (down to numerical limits); more calibration
samples → better.  Method: SM, 2:4, on the tiny LM.
"""

from __future__ import annotations

import time
from typing import List

from benchmarks.common import BenchResult, calib_for, eval_ppl, trained_model
from repro.core import PruningEngine


def run(fast: bool = False) -> List[BenchResult]:
    model, params, pipe = trained_model("lm")
    out: List[BenchResult] = []

    gammas = [0.1, 0.01, 0.001] if not fast else [0.01]
    calib = calib_for(model, n_samples=32)
    for g in gammas:
        t0 = time.monotonic()
        eng = PruningEngine(model, "2:4", method="SM", blocksize=64, gamma=g)
        pruned, _ = eng.run(params, calib)
        ppl = eval_ppl(model, pruned, pipe)
        out.append(BenchResult(
            f"ablation/gamma={g}", (time.monotonic() - t0) * 1e6,
            f"ppl={ppl:.4f}"))

    sample_counts = [8, 32, 128] if not fast else [32]
    for ns in sample_counts:
        calib_n = calib_for(model, n_samples=ns)
        t0 = time.monotonic()
        eng = PruningEngine(model, "2:4", method="SM", blocksize=64)
        pruned, _ = eng.run(params, calib_n)
        ppl = eval_ppl(model, pruned, pipe)
        out.append(BenchResult(
            f"ablation/calib={ns}", (time.monotonic() - t0) * 1e6,
            f"ppl={ppl:.4f}"))
    return out
