"""Kernel micro-benchmarks: wall time (CPU interpret — structural only)
plus the *derived* quantity that matters on TPU: weight-bytes saved by
2:4 packing, Hessian FLOPs, combo-scoring throughput, attention memory.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import BenchResult
from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.monotonic()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / reps * 1e6


def run(fast: bool = False) -> List[BenchResult]:
    out: List[BenchResult] = []
    key = jax.random.key(0)

    # nm_spmm: derived = weight-HBM-bytes dense vs packed
    k, n, m = 256, 256, 128
    w = jax.random.normal(key, (k, n), jnp.float32)
    gt = w.reshape(k // 4, 4, n).transpose(0, 2, 1)
    _, idx = jax.lax.top_k(-jnp.abs(gt), 2)
    mask = jax.nn.one_hot(idx, 4).sum(-2) > 0
    wg = jnp.where(mask, 0, gt).transpose(0, 2, 1).reshape(k, n)
    vals, pidx = ops.compress_24(wg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k))
    us = _time(lambda a: ops.nm_matmul(a, vals, pidx), x)
    dense_b = k * n * 2                       # bf16 dense
    packed_b = (k // 2) * n * 2 + (k // 2) * n * 0.25   # vals bf16 + 2-bit idx
    out.append(BenchResult(
        "kernel/nm_spmm", us,
        f"weight_bytes {dense_b}→{packed_b:.0f} ({dense_b / packed_b:.2f}x)"))

    # hessian_accum: derived = GFLOP per call
    xh = jax.random.normal(key, (128, 512))
    us = _time(ops.hessian_xxt, xh)
    out.append(BenchResult(
        "kernel/hessian_accum", us,
        f"flops={2 * 128 * 128 * 512 / 1e6:.1f}MF"))

    # nm_select: derived = combos scored per call
    wsel = jax.random.normal(key, (128, 128))
    a = jax.random.normal(jax.random.fold_in(key, 2), (128, 128))
    hinv = a @ a.T / 128 + jnp.eye(128)
    us = _time(ops.nm_select_mask, wsel, hinv)
    out.append(BenchResult(
        "kernel/nm_select", us, f"combos={128 * 32 * 6}"))

    # flash_attn: derived = score-matrix bytes avoided
    q = jax.random.normal(key, (2, 256, 64))
    us = _time(lambda a: ops.attention(a, a, a, True), q)
    out.append(BenchResult(
        "kernel/flash_attn", us,
        f"dense_scores_bytes={2 * 256 * 256 * 4}→tiled"))
    return out
