"""Kernel micro-benchmarks: wall time (CPU interpret — structural only)
plus the *derived* quantity that matters on TPU: weight-bytes saved by
2:4 packing, Hessian FLOPs, combo-scoring throughput, attention memory.

``python -m benchmarks.kernelbench --smoke`` is the CI ``kernel-bench``
job's entry point: it asserts nm_spmm (tiled + decode-shaped epilogue)
and paged_attn (fp32 + int8-KV) parity against the ``kernels/ref.py``
oracles under BOTH dispatch modes — the jnp oracle path and the Pallas
bodies (interpret off-TPU) — then writes ``BENCH_KERNELS_<sha>.json``
with the timing table and a ``parity`` block, the artifact the job
uploads.  Any mismatch raises, failing the job.
"""

from __future__ import annotations

import sys
import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import BenchResult
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.monotonic()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / reps * 1e6


def _rand_24(key, k: int, n: int):
    """A random 2:4-sparse (K, N) weight: keep the 2 largest of every
    4-group along K.  Returns (dense, vals, idx)."""
    w = jax.random.normal(key, (k, n), jnp.float32)
    gt = w.reshape(k // 4, 4, n).transpose(0, 2, 1)
    _, drop = jax.lax.top_k(-jnp.abs(gt), 2)
    mask = jax.nn.one_hot(drop, 4).sum(-2) > 0
    wg = jnp.where(mask, 0, gt).transpose(0, 2, 1).reshape(k, n)
    vals, pidx = ops.compress_24(wg)
    return wg, vals, pidx


def _paged_case(key, quantized: bool):
    """A small paged-GQA decode problem; optionally int8 pages+scales."""
    b, kvh, g, hd, page, p_max = 2, 2, 2, 16, 8, 3
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, kvh, g, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (p_max * b + 1, page, kvh, hd))
    vp = jax.random.normal(ks[2], (p_max * b + 1, page, kvh, hd))
    bt = jnp.arange(1, b * p_max + 1, dtype=jnp.int32).reshape(b, p_max)
    lengths = jnp.array([p_max * page, p_max * page - 3], jnp.int32)
    if not quantized:
        return q, kp, vp, bt, lengths, None, None
    k_s = jnp.max(jnp.abs(kp), axis=-1) / 127.0
    v_s = jnp.max(jnp.abs(vp), axis=-1) / 127.0
    kq = jnp.round(kp / jnp.maximum(k_s, 1e-8)[..., None]).astype(jnp.int8)
    vq = jnp.round(vp / jnp.maximum(v_s, 1e-8)[..., None]).astype(jnp.int8)
    return q, kq, vq, bt, lengths, k_s, v_s


def run(fast: bool = False) -> List[BenchResult]:
    out: List[BenchResult] = []
    key = jax.random.key(0)

    # nm_spmm: derived = weight-HBM-bytes dense vs packed
    k, n, m = 256, 256, 128
    _, vals, pidx = _rand_24(key, k, n)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k))
    us = _time(lambda a: ops.nm_matmul(a, vals, pidx), x)
    dense_b = k * n * 2                       # bf16 dense
    packed_b = (k // 2) * n * 2 + (k // 2) * n * 0.25   # vals bf16 + 2-bit idx
    out.append(BenchResult(
        "kernel/nm_spmm", us,
        f"weight_bytes {dense_b}→{packed_b:.0f} ({dense_b / packed_b:.2f}x)"))

    # nm_spmm decode shape (ISSUE-9): skinny M, fused bias+silu epilogue
    xd = jax.random.normal(jax.random.fold_in(key, 3), (1, k))
    bias = jax.random.normal(jax.random.fold_in(key, 4), (n,))
    us = _time(lambda a: ops.nm_matmul(a, vals, pidx, bias,
                                       activation="silu"), xd)
    out.append(BenchResult(
        "kernel/nm_spmm_decode", us,
        f"m=1 epilogue=bias+silu weight_bytes {dense_b / packed_b:.2f}x"))

    # paged_attn decode (ISSUE-9): fp32 vs int8 pages — bytes gathered
    q, kp, vp, bt, lengths, _, _ = _paged_case(key, quantized=False)
    us = _time(lambda a: ops.paged_attention(a, kp, vp, bt, lengths), q)
    tok_b = 2 * kp.shape[2] * kp.shape[3]
    out.append(BenchResult(
        "kernel/paged_attn", us,
        f"kv_bytes/tok fp32={tok_b * 4} int8={tok_b * (1 + 4 / 16):.0f}"))

    # hessian_accum: derived = GFLOP per call
    xh = jax.random.normal(key, (128, 512))
    us = _time(ops.hessian_xxt, xh)
    out.append(BenchResult(
        "kernel/hessian_accum", us,
        f"flops={2 * 128 * 128 * 512 / 1e6:.1f}MF"))

    # nm_select: derived = combos scored per call
    wsel = jax.random.normal(key, (128, 128))
    a = jax.random.normal(jax.random.fold_in(key, 2), (128, 128))
    hinv = a @ a.T / 128 + jnp.eye(128)
    us = _time(ops.nm_select_mask, wsel, hinv)
    out.append(BenchResult(
        "kernel/nm_select", us, f"combos={128 * 32 * 6}"))

    # flash_attn: derived = score-matrix bytes avoided
    q = jax.random.normal(key, (2, 256, 64))
    us = _time(lambda a: ops.attention(a, a, a, True), q)
    out.append(BenchResult(
        "kernel/flash_attn", us,
        f"dense_scores_bytes={2 * 256 * 256 * 4}→tiled"))
    return out


# -------------------------------------------------- CI parity smoke
def smoke() -> dict:
    """nm_spmm / paged_attn vs the ref oracles, both dispatch modes.

    Returns the ``parity`` dict for BENCH_KERNELS_<sha>.json: max |err|
    per (kernel, mode).  Raises AssertionError on any out-of-tolerance
    cell — the CI kernel-bench job's failure signal."""
    key = jax.random.key(7)
    parity = {}

    def check(name: str, got, want, tol: float):
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        parity[name] = err
        assert err <= tol, f"{name}: max|err|={err:.3e} > tol={tol:.1e}"

    # dispatch modes: jnp oracle vs forced Pallas (interpret off-TPU)
    modes = (("oracle", dict(force_pallas=False)),
             ("pallas", dict(force_pallas=True)))

    # nm_spmm cases: tiled prefill M, decode M=1 with fused epilogue,
    # K not a multiple of the 128 tile (wrapper zero-pads)
    cases = (("tiled_m256", 256, 256, 256, None, None),
             ("decode_m1_silu", 1, 256, 384, "bias", "silu"),
             ("decode_kpad", 4, 200, 256, "bias", "gelu"))
    for ci, (cname, m, k, n, with_bias, act) in enumerate(cases):
        kk = jax.random.fold_in(key, ci)
        _, vals, pidx = _rand_24(kk, k, n)
        x = jax.random.normal(jax.random.fold_in(kk, 1), (m, k))
        bias = (jax.random.normal(jax.random.fold_in(kk, 2), (n,))
                if with_bias else None)
        want = ref.nm_spmm_ref(x, vals, pidx, bias=bias, activation=act)
        for mname, kw in modes:
            with ops.override_dispatch(**kw):
                got = ops.nm_matmul(x, vals, pidx, bias, activation=act)
            check(f"nm_spmm/{cname}/{mname}", got, want, 1e-4)

    # paged_attn cases: fp32 pages and the int8 dequantize-at-gather path
    for qname, quant in (("fp32", False), ("int8", True)):
        q, kp, vp, bt, lengths, k_s, v_s = _paged_case(
            jax.random.fold_in(key, 11), quantized=quant)
        want = ref.paged_attn_ref(q, kp, vp, bt, lengths,
                                  k_scale=k_s, v_scale=v_s)
        for mname, kw in modes:
            with ops.override_dispatch(**kw):
                got = ops.paged_attention(q, kp, vp, bt, lengths,
                                          k_scale=k_s, v_scale=v_s)
            check(f"paged_attn/{qname}/{mname}", got, want, 1e-4)
    return parity


def main(argv=None) -> None:
    import argparse

    from benchmarks.run import _git_sha, write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI parity gate: kernels vs ref oracles under "
                         "both dispatch modes + BENCH_KERNELS_<sha>.json")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="output path (default BENCH_KERNELS_<sha>.json)")
    args = ap.parse_args(argv)

    results = run(fast=args.smoke)
    print("name,us_per_call,derived")
    for r in results:
        print(r.csv())
    if not args.smoke and not args.json:
        return
    parity = smoke()
    for name in sorted(parity):
        print(f"# parity {name}: max|err|={parity[name]:.3e}",
              file=sys.stderr)
    results.append(BenchResult(
        "kernel/parity", 0.0,
        f"{len(parity)} cells, max|err|={max(parity.values()):.3e}",
        metrics={k.replace("/", "_"): v for k, v in parity.items()}))
    write_json(args.json or f"BENCH_KERNELS_{_git_sha()}.json", results)


if __name__ == "__main__":
    main()
