"""Serve-throughput benchmark: static bucketing vs continuous batching.

A mixed-length workload (short+long prompts, heavily varied
``max_new_tokens`` — the shape real traffic has) through both
``ServeEngine`` modes on the trained tiny LM AND the trained tiny Mamba
(the recurrent-state pool path — no static fallback):

  - static: requests bucketed by prompt length; each bucket prefills
    once and runs one fused on-device decode loop (one host sync per
    bucket), burning finished slots' steps into scrap positions until
    its LONGEST request drains;
  - continuous: the paged step loop with the device-resident burst
    (``steps_per_sync=8`` fused decode steps per host sync) — prompts
    stream in as fixed-size prefill chunks interleaved with decode,
    retiring requests hand their slot and pages to the admission queue
    at the next sync.

Reports, per mode: tokens/sec, mean per-request slot-utilization
(Result.decode_steps accounting — the fraction of occupied steps that
actually emitted a token), **host-syncs-per-token** (blocking device
readbacks — the quantity the ISSUE-5 device-resident loop exists to
amortize, from ``ServeEngine.stats``) and **p50 per-step latency**
(median over repeated runs of the engine's decode-window wall /
fused device steps — see ``_timed_runs``).  Greedy
outputs must be token-identical between the modes (the engines share
one model/params); any mismatch is a hard failure.

A third leg (``streaming``) drives a :class:`ContinuousSession` under
an **oversubscribed Poisson arrival** process — rate calibrated to 2×
the engine's measured batch capacity, so the wait queue builds exactly
as an overloaded server's would — and reports the serving-latency
metrics the front end makes visible: **TTFT** p50/p95 (submit → first
streamed token, which pays queueing + chunked prefill) and **TPOT**
(mean per-token latency after the first).  The same leg checks the
prefill sync-floor fix: the mixed workload keeps prompts streaming in,
and ``burst`` (fused device steps per host sync) must stay well above 1
— before prefill was fused into the burst body it clamped to ~1 here.

A fourth leg (``prefix``, ISSUE-7) runs a **prefix-heavy
oversubscribed** workload — every prompt shares a 48-token system
prefix with a short unique tail, more requests than slots, and a pool
tight enough that the no-reuse run must swap-preempt — once with the
refcounted prefix cache ON and once OFF on the same engine config.
Greedy tokens must match bit-exact (reuse changes prefill *work*,
never results); reported: the cached run's ``tok_s``,
``prefill_tok_saved_frac`` (prefix-attached prompt tokens / total
prompt tokens — both CI-gated), ``speedup_vs_noprefix``, and the
host-arena swap traffic of the pressured run (``swap_in_ms_per_page``).

A fifth leg (``obs_overhead``, ISSUE-8) generates the mixed workload
on two engines that differ ONLY in observability — one with metrics +
tracing fully on, one with ``Obs.disabled()`` — interleaved over
repeated runs.  Token streams must be bit-identical, and the traced
median wall must stay within ``OBS_OVERHEAD_MAX`` (5%) of disabled —
a hard failure otherwise (the acceptance bound on instrumentation
cost); ``obs_overhead_frac`` is reported for trend-watching.

Latency metrics come from the obs registry (ISSUE-8): every leg's
engines are built around a traced :class:`repro.obs.Obs` bundle,
TTFT/TPOT/queue-wait percentiles are read from the registry's
histograms (``registry.reset()`` isolates the measured run from
warmup) instead of private timing lists, and each leg exports its
Chrome-trace JSON as ``BENCH_TRACE_serve_*.json`` — matched by the CI
bench-gate job's ``BENCH_*.json`` artifact upload, ignored by the
gate diff itself.

A ``chaos`` leg (ISSUE-10) drives the mixed workload through a
two-replica router while a :class:`FaultPlan` kills r0's worker on its
third burst dispatch and a supervisor recovers it (restart + in-flight
failover with replay suppression).  Every stream must be bit-exact
against an uninjected batch run — the acceptance bound — and the leg
reports **recovery_ms** (supervisor's crash-detection → restart +
all-failed-over window, median over repeated injected crashes, from the
``serve_recovery_seconds`` histogram) and tok/s under the injected
crash; ``recovery_ms`` is CI-gated on rises like the other
lower-is-better latencies.

All legs build their engines from one :class:`repro.serve.ServeConfig`
literal — the same object ``launch/serve.py`` constructs from flags.

The ``metrics`` dicts feed ``BENCH_<sha>.json`` and the CI
bench-regression gate (benchmarks.gate — ``tok_s`` and
``prefill_tok_saved_frac`` gate on drops, ``step_ms_p50`` and
``ttft_ms_p50`` on rises).
"""

from __future__ import annotations

import os
import statistics
import sys
import time
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

PROMPT_LENS = (4, 12, 28)
# cycle length coprime with PROMPT_LENS so every static bucket draws the
# full spread — incl. a 48-token straggler that pins its whole bucket
MAX_NEWS = (2, 4, 8, 48)
MAX_LEN = 96
MAX_BATCH = 8
PAGE_SIZE = 16
PREFILL_CHUNK = 16
STEPS_PER_SYNC = 8
TIMED_RUNS = 3                 # p50 step latency needs a few samples


def _workload(n: int, vocab: int) -> List["repro.serve.Request"]:
    from repro.serve import Request

    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                prompt=rng.integers(0, vocab, size=PROMPT_LENS[i % 3],
                                    dtype=np.int32),
                max_new_tokens=MAX_NEWS[i % len(MAX_NEWS)])
        for i in range(n)
    ]


def _timed_runs(eng, reqs):
    """TIMED_RUNS timed generates on a warm engine.  Returns (results,
    median wall seconds, p50 per-fused-step latency ms, syncs/token).

    Step latency uses the engine's own ``decode_wall_s`` counter — wall
    time inside burst-dispatch→readback windows only, so the metric is
    the decode hot path, NOT a reciprocal of tok/s (which also pays
    host scheduling); the step_ms_p50 CI gate therefore catches
    host-round-trip creep in the fused loop independently of end-to-end
    throughput noise.  Prefill chunks are fused into the same dispatch
    windows (ISSUE-6 sync-floor fix), so the per-unit divisor counts
    decode steps + chunks — each chunk is one more fused unit inside
    the window, not decode-step time."""
    walls, step_ms = [], []
    results = None
    for _ in range(TIMED_RUNS):
        t0 = time.monotonic()
        results = eng.generate(reqs)
        walls.append(time.monotonic() - t0)
        units = (eng.stats["device_steps"]
                 + eng.stats.get("prefill_chunks", 0))
        step_ms.append(eng.stats["decode_wall_s"] * 1e3 / max(1, units))
    syncs_per_tok = eng.stats["host_syncs"] / max(1, eng.stats["tokens"])
    return (results, statistics.median(walls), statistics.median(step_ms),
            syncs_per_tok)


def _bench_pair(tag: str, model, params, n_requests: int
                ) -> List["BenchResult"]:
    """Static vs continuous on one model; hard-fails on token mismatch."""
    from benchmarks.common import BenchResult
    from repro.obs import Obs
    from repro.serve import ServeConfig, ServeEngine

    reqs = _workload(n_requests, model.cfg.vocab_size)
    config = ServeConfig(max_batch=MAX_BATCH, max_len=MAX_LEN,
                         page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK,
                         steps_per_sync=STEPS_PER_SYNC)
    # one traced bundle, a track per mode — the leg's trace artifact
    obs = Obs.create(metrics=True, trace=True)
    static = ServeEngine(model, params, config, mode="static",
                         obs=obs.labelled("static"))
    cont = ServeEngine(model, params, config, mode="continuous",
                       obs=obs.labelled("continuous"))
    if cont.mode != "continuous":
        raise RuntimeError(f"{tag}: fell back to static — the paged "
                           f"runtime must serve this arch")

    # warm both jit caches off the measured clock with a FULL pass of
    # the exact workload — jit specializes on bucket batch and prompt-pad
    # shapes, so a partial warmup would leave compiles inside one mode's
    # timing window and measure compiler latency instead of throughput
    static.generate(reqs)
    cont.generate(reqs)

    rs, static_s, static_step_ms, static_spt = _timed_runs(static, reqs)
    rc, cont_s, cont_step_ms, cont_spt = _timed_runs(cont, reqs)

    for a, b in zip(rs, rc):
        if not np.array_equal(a.tokens, b.tokens):
            raise RuntimeError(
                f"{tag}: continuous != static greedy tokens for uid "
                f"{a.uid}: {a.tokens.tolist()} vs {b.tokens.tolist()}")

    obs.tracer.export(f"BENCH_TRACE_serve_{tag}_pair.json")
    toks = sum(len(r.tokens) for r in rs)
    tps_static = toks / static_s
    tps_cont = toks / cont_s
    util_static = float(np.mean([r.utilization for r in rs]))
    util_cont = float(np.mean([r.utilization for r in rc]))
    speedup = tps_cont / tps_static
    return [
        BenchResult(f"serve_throughput/{tag}/static", static_s * 1e6,
                    f"tok_s={tps_static:.1f} util={util_static:.0%} "
                    f"syncs/tok={static_spt:.3f} "
                    f"step_p50={static_step_ms:.2f}ms",
                    metrics={"tok_s": tps_static, "util": util_static,
                             "syncs_per_tok": static_spt,
                             "step_ms_p50": static_step_ms}),
        BenchResult(f"serve_throughput/{tag}/continuous", cont_s * 1e6,
                    f"tok_s={tps_cont:.1f} util={util_cont:.0%} "
                    f"syncs/tok={cont_spt:.3f} "
                    f"step_p50={cont_step_ms:.2f}ms "
                    f"speedup={speedup:.2f}x",
                    metrics={"tok_s": tps_cont, "util": util_cont,
                             "syncs_per_tok": cont_spt,
                             "step_ms_p50": cont_step_ms,
                             "speedup": speedup}),
    ]


OVERSUBSCRIPTION = 2.0         # Poisson arrival rate vs measured capacity


def _bench_streaming(tag: str, model, params, n_requests: int
                     ) -> List["BenchResult"]:
    """Oversubscribed Poisson-arrival streaming: TTFT / TPOT through a
    ContinuousSession (the server's code path minus the socket).  All
    latency metrics come from the engine's obs registry histograms —
    the engine stamps submit→first-token itself (ISSUE-8), so the
    harness keeps no timing dicts — and the traced run's lifecycle
    spans are exported as the leg's Chrome-trace artifact."""
    from benchmarks.common import BenchResult
    from repro.obs import Obs
    from repro.serve import ServeConfig, ServeEngine

    reqs = _workload(n_requests, model.cfg.vocab_size)
    obs = Obs.create(metrics=True, trace=True)
    eng = ServeEngine(model, params, ServeConfig(
        max_batch=MAX_BATCH, max_len=MAX_LEN, page_size=PAGE_SIZE,
        prefill_chunk=PREFILL_CHUNK, steps_per_sync=STEPS_PER_SYNC),
        obs=obs)
    eng.generate(reqs)                               # warm the jit caches
    t0 = time.monotonic()
    eng.generate(reqs)
    capacity_s = time.monotonic() - t0               # batch service time

    # exponential inter-arrival gaps at OVERSUBSCRIPTION× the measured
    # service rate: the queue grows for the whole run, so TTFT includes
    # real queueing delay, not just prefill
    rng = np.random.default_rng(1)
    gaps = rng.exponential(scale=capacity_s / n_requests / OVERSUBSCRIPTION,
                           size=n_requests)
    arrivals = np.cumsum(gaps)

    obs.metrics.reset()           # isolate the measured run from warmup
    obs.tracer.clear()
    session = eng.session(seed=0)
    submitted = 0
    start = time.monotonic()
    while submitted < n_requests or session.has_work():
        now = time.monotonic() - start
        while submitted < n_requests and arrivals[submitted] <= now:
            session.submit(reqs[submitted])
            submitted += 1
        if not session.has_work():                   # idle: next arrival
            time.sleep(max(0.0, arrivals[submitted]
                           - (time.monotonic() - start)))
            continue
        for _ in session.step():                     # engine records all
            pass                                     # latency metrics
    wall = time.monotonic() - start

    em = eng.m
    if em.ttft.count != n_requests:
        raise RuntimeError(
            f"{tag}: ttft histogram saw {em.ttft.count} requests, "
            f"expected {n_requests} — serve instrumentation broke")
    toks = int(em.tokens.value)
    syncs = int(em.host_syncs.value)
    burst = em.device_steps.value / max(1, syncs)
    obs.tracer.export(f"BENCH_TRACE_serve_{tag}_streaming.json")
    m = {"tok_s": toks / wall,
         "ttft_ms_p50": em.ttft.quantile(0.5) * 1e3,
         "ttft_ms_p95": em.ttft.quantile(0.95) * 1e3,
         "tpot_ms": em.tpot.mean * 1e3,
         "queue_wait_ms_p50": em.queue_wait.quantile(0.5) * 1e3,
         "syncs_per_tok": syncs / max(1, toks),
         "burst": burst}
    return [BenchResult(
        f"serve_throughput/{tag}/streaming", wall * 1e6,
        f"tok_s={m['tok_s']:.1f} ttft_p50={m['ttft_ms_p50']:.1f}ms "
        f"ttft_p95={m['ttft_ms_p95']:.1f}ms tpot={m['tpot_ms']:.2f}ms "
        f"burst={burst:.1f}", metrics=m)]


# ----------------------------------------------------------- prefix leg
SHARED_PREFIX = 48             # 3 full pages of system prompt
TAIL_LEN = 4                   # unique per-request suffix (L = 52)
PREFIX_MAX_NEWS = (8, 16, 24)  # growth past page 4 → pool pressure
PREFIX_NUM_PAGES = 14          # capacity 13: the no-reuse run MUST swap


def _prefix_workload(n: int, vocab: int) -> List["repro.serve.Request"]:
    from repro.serve import Request

    rng = np.random.default_rng(2)
    shared = rng.integers(0, vocab, size=SHARED_PREFIX, dtype=np.int32)
    return [
        Request(uid=i,
                prompt=np.concatenate(
                    [shared, rng.integers(0, vocab, size=TAIL_LEN,
                                          dtype=np.int32)]),
                max_new_tokens=PREFIX_MAX_NEWS[i % len(PREFIX_MAX_NEWS)])
        for i in range(n)
    ]


def _bench_prefix(tag: str, model, params, n_requests: int
                  ) -> List["BenchResult"]:
    """Prefix-heavy oversubscribed workload, cache ON vs OFF on one
    tight-pool config (host-swap arena enabled on both): the OFF run
    pays full prefill per request and swap-preempts under the page
    pressure the ON run's sharing avoids."""
    from benchmarks.common import BenchResult
    from repro.obs import Obs
    from repro.serve import ServeConfig, ServeEngine

    reqs = _prefix_workload(n_requests, model.cfg.vocab_size)
    base = ServeConfig(max_batch=4, max_len=80, page_size=PAGE_SIZE,
                       num_pages=PREFIX_NUM_PAGES,
                       prefill_chunk=PREFILL_CHUNK,
                       steps_per_sync=STEPS_PER_SYNC)
    obs = Obs.create(metrics=True, trace=True)
    off = ServeEngine(model, params, base, prefix_cache=False,
                      obs=obs.labelled("prefix_off"))
    on = ServeEngine(model, params, base, prefix_cache=True,
                     obs=obs.labelled("prefix_on"))

    off.generate(reqs)                               # warm the jit caches
    on.generate(reqs)
    r_off, off_s, _, _ = _timed_runs(off, reqs)
    r_on, on_s, _, _ = _timed_runs(on, reqs)

    for a, b in zip(r_off, r_on):
        if not np.array_equal(a.tokens, b.tokens):
            raise RuntimeError(
                f"{tag}: prefix-cache changed greedy tokens for uid "
                f"{a.uid}: {a.tokens.tolist()} vs {b.tokens.tolist()}")

    obs.tracer.export(f"BENCH_TRACE_serve_{tag}_prefix.json")
    toks = sum(len(r.tokens) for r in r_on)
    tok_s = toks / on_s
    speedup = tok_s / (toks / off_s)
    prompt_toks = sum(len(r.prompt) for r in reqs)
    saved = on.stats["prefix_hit_tokens"] / prompt_toks
    swap_pages = off.stats["swap_in_pages"]
    swap_ms = (off.stats["swap_in_wall_s"] * 1e3 / swap_pages
               if swap_pages else 0.0)
    m = {"tok_s": tok_s,
         "prefill_tok_saved_frac": saved,
         "speedup_vs_noprefix": speedup,
         "swap_in_ms_per_page": swap_ms,
         "preempt_swap_noprefix": float(off.stats["preempt_swap"]),
         "cow_copies": float(on.stats["cow_copies"])}
    return [BenchResult(
        f"serve_throughput/{tag}/prefix", on_s * 1e6,
        f"tok_s={tok_s:.1f} saved={saved:.0%} "
        f"speedup={speedup:.2f}x swap_in={swap_ms:.2f}ms/page "
        f"swaps_off={off.stats['preempt_swap']}", metrics=m)]


# ---------------------------------------------------- obs-overhead leg
OBS_OVERHEAD_MAX = 0.05        # acceptance: tracing costs < 5% wall
OVERHEAD_RUNS = 6              # interleaved medians absorb CPU noise


def _bench_obs_overhead(tag: str, model, params, n_requests: int
                        ) -> List["BenchResult"]:
    """ISSUE-8 acceptance: metrics + tracing fully ON vs
    ``Obs.disabled()`` on otherwise identical engines — token streams
    must be bit-identical and the traced median wall within
    ``OBS_OVERHEAD_MAX`` of disabled (hard failure past it).  Runs are
    interleaved so drift (thermal, page cache) hits both sides."""
    from benchmarks.common import BenchResult
    from repro.obs import Obs
    from repro.serve import ServeConfig, ServeEngine

    reqs = _workload(n_requests, model.cfg.vocab_size)
    config = ServeConfig(max_batch=MAX_BATCH, max_len=MAX_LEN,
                         page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK,
                         steps_per_sync=STEPS_PER_SYNC)
    off = ServeEngine(model, params, config, obs=Obs.disabled())
    obs = Obs.create(metrics=True, trace=True)
    on = ServeEngine(model, params, config, obs=obs)
    r_off = off.generate(reqs)                       # warm both caches
    r_on = on.generate(reqs)
    for a, b in zip(r_off, r_on):
        if not np.array_equal(a.tokens, b.tokens):
            raise RuntimeError(
                f"{tag}: tracing changed tokens for uid {a.uid}: "
                f"{a.tokens.tolist()} vs {b.tokens.tolist()}")

    walls_off, walls_on = [], []
    for i in range(OVERHEAD_RUNS):
        obs.tracer.clear()                 # bound trace memory per run
        # alternate execution order so slow drift (page cache, thermal)
        # cancels instead of biasing one side
        pair = [(off, walls_off), (on, walls_on)]
        for eng_i, sink in (pair if i % 2 == 0 else pair[::-1]):
            t0 = time.monotonic()
            eng_i.generate(reqs)
            sink.append(time.monotonic() - t0)
    off_s = statistics.median(walls_off)
    on_s = statistics.median(walls_on)
    frac = on_s / off_s - 1.0
    if frac > OBS_OVERHEAD_MAX:
        raise RuntimeError(
            f"{tag}: observability overhead {frac:.1%} exceeds the "
            f"{OBS_OVERHEAD_MAX:.0%} acceptance bound "
            f"(traced {on_s:.3f}s vs disabled {off_s:.3f}s)")
    obs.tracer.export(f"BENCH_TRACE_serve_{tag}_obs_overhead.json")
    toks = sum(len(r.tokens) for r in r_on)
    m = {"tok_s": toks / on_s, "obs_overhead_frac": frac}
    return [BenchResult(
        f"serve_throughput/{tag}/obs_overhead", on_s * 1e6,
        f"tok_s={m['tok_s']:.1f} overhead={frac:+.1%} "
        f"(bound {OBS_OVERHEAD_MAX:.0%})", metrics=m)]


# --------------------------------------------------- chaos leg (ISSUE-10)
CHAOS_RUNS = 5                 # injected crashes; medians absorb jitter
INERT_AFTER = 1 << 30          # constructor plan that can never fire


def _recovery_totals(registry):
    """(count, sum_seconds) across every serve_recovery_seconds child."""
    fam = registry.get("serve_recovery_seconds")
    if fam is None:
        return 0, 0.0
    n = s = 0
    for _, child in fam.children():
        n += child.count
        s += child.mean * child.count
    return n, s


def _bench_chaos(tag: str, model, params, n_requests: int
                 ) -> List["BenchResult"]:
    """ISSUE-10 acceptance: the mixed workload through a two-replica
    router while an injected ``engine_step`` raise kills r0's worker on
    its third burst and the supervisor recovers it.  Token streams —
    including the failed-over ones, replay-suppressed — must be
    bit-exact against the uninjected batch run; reports the median
    supervisor recovery window and tok/s paid under the crash.

    The fault hook reads ``engine.faults`` per burst, so one warmed
    engine pair serves every run: r0 is built with an inert plan (the
    hook exists but never fires) and each measured run re-arms a fresh
    3rd-burst crash before rebuilding the replicas."""
    import threading

    from benchmarks.common import BenchResult
    from repro.obs import Obs
    from repro.serve import FaultPlan, FaultSpec, ServeConfig, ServeEngine
    from repro.serve.frontend import Replica, Router, Supervisor

    reqs = _workload(n_requests, model.cfg.vocab_size)
    base = dict(max_batch=MAX_BATCH, max_len=MAX_LEN, page_size=PAGE_SIZE,
                prefill_chunk=PREFILL_CHUNK, steps_per_sync=STEPS_PER_SYNC)
    obs = Obs.create(metrics=True, trace=True)
    inert = FaultPlan([FaultSpec("engine_step", after=INERT_AFTER)])
    eng0 = ServeEngine(model, params, ServeConfig(faults=inert, **base),
                       obs=obs.labelled("r0"))
    eng1 = ServeEngine(model, params, ServeConfig(**base),
                       obs=obs.labelled("r1"))

    # the token oracle AND the jit warmup — bit-exactness is against
    # this uninjected batch run (per-(uid, step) key contract)
    ref = {r.uid: list(x.tokens)
           for r, x in zip(reqs, eng1.generate(reqs, seed=0))}
    eng0.generate(reqs, seed=0)

    walls, recoveries = [], []
    restarts = failed_over = 0
    for _ in range(CHAOS_RUNS):
        plan = FaultPlan([FaultSpec("engine_step", after=2)])
        eng0.faults = plan                       # re-arm: hook reads live
        r0 = Replica(eng0, name="r0", seed=0)
        r1 = Replica(eng1, name="r1", seed=0)
        router = Router([r0, r1])
        sup = Supervisor(router, failover_retries=8)
        lock = threading.Lock()
        toks, done = {}, {}

        def make_cb(uid, toks=toks, done=done, lock=lock):
            def cb(ev):
                with lock:
                    toks.setdefault(uid, []).extend(ev.tokens)
                    if ev.finished:
                        done[uid] = ev
            return cb

        rec0 = _recovery_totals(obs.metrics)
        t0 = time.monotonic()
        try:
            for r in reqs:
                router.submit_request(r, make_cb(r.uid))
            while len(done) < len(reqs):
                if time.monotonic() - t0 > 120:
                    raise RuntimeError(
                        f"{tag}: chaos run stuck — done={sorted(done)} "
                        f"crashed={r0.crashed!r}")
                sup.check_once()
                time.sleep(0.005)
            walls.append(time.monotonic() - t0)
            recovered = r0.crashed is None and r0.healthy
        finally:
            sup.stop()
            router.close()

        if plan.fired.get("engine_step", 0) < 1:
            raise RuntimeError(f"{tag}: injected crash never fired")
        if not recovered:
            raise RuntimeError(f"{tag}: r0 not recovered after the run")
        with lock:
            for uid, want in ref.items():
                if toks[uid] != want:
                    raise RuntimeError(
                        f"{tag}: uid {uid} stream changed under chaos: "
                        f"{toks[uid]} vs {want}")
                if done[uid].finish_reason not in ("stop", "length"):
                    raise RuntimeError(
                        f"{tag}: uid {uid} finished "
                        f"{done[uid].finish_reason!r} under chaos")
        n, s = _recovery_totals(obs.metrics)
        if n - rec0[0] < 1:
            raise RuntimeError(f"{tag}: serve_recovery_seconds never "
                               f"ticked — supervisor path unexercised")
        recoveries.append((s - rec0[1]) / (n - rec0[0]))
        snap = eng0.m.snapshot()
        restarts = int(snap["replica_restarts"])
        failed_over = int(snap["failed_over"])

    obs.tracer.export(f"BENCH_TRACE_serve_{tag}_chaos.json")
    wall = statistics.median(walls)
    toks_total = sum(len(t) for t in ref.values())
    m = {"tok_s": toks_total / wall,
         "recovery_ms": statistics.median(recoveries) * 1e3,
         "replica_restarts": float(restarts),
         "failed_over": float(failed_over)}
    return [BenchResult(
        f"serve_throughput/{tag}/chaos", wall * 1e6,
        f"tok_s={m['tok_s']:.1f} recovery={m['recovery_ms']:.1f}ms "
        f"restarts={restarts} failed_over={failed_over} "
        f"(streams bit-exact x{CHAOS_RUNS})", metrics=m)]


# ------------------------------------------- sparse / int8-KV legs (ISSUE-9)
KV_MATCH_MIN = 0.60            # int8-KV greedy agreement floor (see docs)


def _tree_bytes(tree) -> int:
    """HBM bytes of a param tree (packed {"vals","idx"} dicts contribute
    their vals+idx leaves — tree_leaves descends into them)."""
    import jax

    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def _pruned_24(model, params):
    """2:4-prune (SM) the trained tiny LM — the checkpoint the sparse
    serve path exists for."""
    from repro.core import PruningEngine
    from repro.data import calibration_batches

    calib = calibration_batches(model.cfg, n_samples=8, seq_len=64,
                                batch=8)
    eng = PruningEngine(model, "2:4", method="SM", blocksize=64)
    pruned, _ = eng.run(params, calib)
    return pruned


def _kv_bytes_per_tok(eng) -> float:
    """Pool HBM bytes per token of KV capacity (page 0 is scrap)."""
    cap = (eng.config.resolved_num_pages() - 1) * eng.config.page_size
    return eng.pool.pool_bytes() / max(1, cap)


def _bench_sparse(tag: str, model, params, n_requests: int
                  ) -> List["BenchResult"]:
    """Compressed-weight serving (the ISSUE-9 tentpole): the same
    2:4-pruned checkpoint served dense (``sparse_weights="off"`` — zeros
    shipped as f32) vs compressed (``"auto"`` — the engine packs 2:4
    leaves at load, HBM holds only (vals, idx), decode projections
    dispatch the nm_spmm kernel).  Greedy tokens must match bit-exact:
    the decompress is an exact inverse of the pack.  ``weight_bytes_frac``
    is the measured HBM param-bytes ratio (packed/dense) and
    ``modeled_speedup`` the weight-traffic roofline bound it implies for
    a weight-bound decode step — reported alongside the honest wall
    numbers because the CPU jnp oracle *decompresses* per call and so
    cannot show the bytes win (benchmarks/roofline.py carries the
    arithmetic; docs/serving.md the caveat)."""
    from benchmarks.common import BenchResult
    from repro.obs import Obs
    from repro.serve import ServeConfig, ServeEngine
    from repro.serve.sparse import compressed_param_tree

    pruned = _pruned_24(model, params)
    reqs = _workload(n_requests, model.cfg.vocab_size)
    base = dict(max_batch=MAX_BATCH, max_len=MAX_LEN, page_size=PAGE_SIZE,
                prefill_chunk=PREFILL_CHUNK, steps_per_sync=STEPS_PER_SYNC)
    obs = Obs.create(metrics=True, trace=True)
    dense = ServeEngine(model, pruned,
                        ServeConfig(sparse_weights="off", **base),
                        obs=obs.labelled("dense"))
    sparse = ServeEngine(model, pruned,
                         ServeConfig(sparse_weights="auto", **base),
                         obs=obs.labelled("sparse"))
    if not sparse.n_sparse_leaves:
        raise RuntimeError(f"{tag}: engine found no 2:4 leaves in the "
                           f"pruned checkpoint — auto-detection broke")

    dense.generate(reqs)                             # warm the jit caches
    sparse.generate(reqs)
    r_d, dense_s, dense_step_ms, _ = _timed_runs(dense, reqs)
    r_s, sparse_s, sparse_step_ms, _ = _timed_runs(sparse, reqs)

    for a, b in zip(r_d, r_s):
        if not np.array_equal(a.tokens, b.tokens):
            raise RuntimeError(
                f"{tag}: compressed weights changed greedy tokens for "
                f"uid {a.uid}: {a.tokens.tolist()} vs {b.tokens.tolist()}")

    obs.tracer.export(f"BENCH_TRACE_serve_{tag}_sparse.json")
    dense_b = _tree_bytes(pruned)
    packed_b = _tree_bytes(compressed_param_tree(pruned))
    frac = packed_b / dense_b
    toks = sum(len(r.tokens) for r in r_s)
    m = {"tok_s": toks / sparse_s,
         "step_ms_p50": sparse_step_ms,
         "step_ms_p50_dense": dense_step_ms,
         "kv_pool_bytes_per_tok": _kv_bytes_per_tok(sparse),
         "sparse_leaves": float(sparse.n_sparse_leaves),
         "sparse_dispatch": float(sparse.stats["sparse_dispatch"]),
         "weight_bytes_frac": frac,
         "modeled_speedup": 1.0 / frac}
    return [BenchResult(
        f"serve_throughput/{tag}/sparse", sparse_s * 1e6,
        f"tok_s={m['tok_s']:.1f} step_p50={sparse_step_ms:.2f}ms "
        f"(dense {dense_step_ms:.2f}ms) leaves={sparse.n_sparse_leaves} "
        f"weight_bytes={frac:.3f}x modeled={1.0 / frac:.2f}x", metrics=m)]


def _bench_kv_int8(tag: str, model, params, n_requests: int
                   ) -> List["BenchResult"]:
    """int8 per-page KV quantization: same engine config with
    ``kv_dtype="fp32"`` vs ``"int8"`` — the quantized pool must resolve
    2× the pages at no more HBM (the ISSUE-9 capacity acceptance), and
    the greedy streams must agree on at least ``KV_MATCH_MIN`` of
    requests (quantization moves logits, so bit-parity is NOT expected —
    tests/test_kernels.py holds the tight numeric bound; the tiny-config
    exact gate lives there too)."""
    from benchmarks.common import BenchResult
    from repro.obs import Obs
    from repro.serve import ServeConfig, ServeEngine

    reqs = _workload(n_requests, model.cfg.vocab_size)
    base = dict(max_batch=MAX_BATCH, max_len=MAX_LEN, page_size=PAGE_SIZE,
                prefill_chunk=PREFILL_CHUNK, steps_per_sync=STEPS_PER_SYNC)
    obs = Obs.create(metrics=True, trace=True)
    fp32 = ServeEngine(model, params, ServeConfig(kv_dtype="fp32", **base),
                       obs=obs.labelled("kv_fp32"))
    q8 = ServeEngine(model, params, ServeConfig(kv_dtype="int8", **base),
                     obs=obs.labelled("kv_int8"))

    pages_fp32 = fp32.config.resolved_num_pages()
    pages_q8 = q8.config.resolved_num_pages()
    if pages_q8 - 1 != 2 * (pages_fp32 - 1):        # page 0 is scrap
        raise RuntimeError(
            f"{tag}: int8 KV resolved {pages_q8} pages vs fp32 "
            f"{pages_fp32} — expected 2x capacity at the same budget")
    if q8.pool.pool_bytes() > fp32.pool.pool_bytes():
        raise RuntimeError(
            f"{tag}: int8 pool {q8.pool.pool_bytes()}B exceeds fp32 "
            f"{fp32.pool.pool_bytes()}B at 2x the pages")

    fp32.generate(reqs)                              # warm the jit caches
    q8.generate(reqs)
    r_f, _, _, _ = _timed_runs(fp32, reqs)
    r_q, q8_s, q8_step_ms, _ = _timed_runs(q8, reqs)

    match = float(np.mean([np.array_equal(a.tokens, b.tokens)
                           for a, b in zip(r_f, r_q)]))
    if match < KV_MATCH_MIN:
        raise RuntimeError(
            f"{tag}: int8-KV greedy streams match fp32 on only "
            f"{match:.0%} of requests (floor {KV_MATCH_MIN:.0%})")

    obs.tracer.export(f"BENCH_TRACE_serve_{tag}_kv_int8.json")
    toks = sum(len(r.tokens) for r in r_q)
    m = {"tok_s": toks / q8_s,
         "step_ms_p50": q8_step_ms,
         "kv_pool_bytes_per_tok": _kv_bytes_per_tok(q8),
         "kv_pool_bytes_per_tok_fp32": _kv_bytes_per_tok(fp32),
         "num_pages": float(pages_q8),
         "num_pages_fp32": float(pages_fp32),
         "kv_quant_pages": float(q8.stats["kv_quant_pages"]),
         "token_match_frac": match}
    return [BenchResult(
        f"serve_throughput/{tag}/kv_int8", q8_s * 1e6,
        f"tok_s={m['tok_s']:.1f} pages={pages_q8} (fp32 {pages_fp32}) "
        f"kv_B/tok={m['kv_pool_bytes_per_tok']:.0f} "
        f"(fp32 {m['kv_pool_bytes_per_tok_fp32']:.0f}) "
        f"match={match:.0%}", metrics=m)]


def run(fast: bool = False) -> List["BenchResult"]:
    from benchmarks.common import trained_model

    n_requests = 16 if fast else 24
    results = []
    model, params, _ = trained_model("lm")
    results += _bench_pair("lm", model, params, n_requests)
    results += _bench_streaming("lm", model, params, n_requests)
    results += _bench_prefix("lm", model, params, n_requests)
    results += _bench_obs_overhead("lm", model, params, n_requests)
    results += _bench_chaos("lm", model, params, n_requests)
    results += _bench_sparse("lm", model, params, n_requests)
    results += _bench_kv_int8("lm", model, params, n_requests)
    # the recurrent-state pool path (ISSUE-4 acceptance: a Mamba config
    # through mode="continuous", tokens identical to the dense cache)
    model, params, _ = trained_model("mamba")
    results += _bench_pair("mamba", model, params, n_requests)
    return results


if __name__ == "__main__":
    for res in run(fast="--fast" in sys.argv):
        print(res.csv())
