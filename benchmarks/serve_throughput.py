"""Serve-throughput benchmark: static bucketing vs continuous batching.

A mixed-length workload (short+long prompts, heavily varied
``max_new_tokens`` — the shape real traffic has) through both
``ServeEngine`` modes on the trained tiny LM:

  - static: requests bucketed by prompt length; each bucket decodes
    until its LONGEST request finishes, burning every other slot's
    steps into scrap positions;
  - continuous: the paged-KV step loop — retiring requests hand their
    slot and pages to the admission queue the same step.

Reports tokens/sec for both, the speedup, and the mean per-request
slot-utilization (Result.decode_steps accounting) — the fraction of
occupied decode steps that actually emitted a token, i.e. exactly what
continuous batching recovers.  Greedy outputs must be token-identical
between the modes (the engines share one model/params); any mismatch is
a hard failure.
"""

from __future__ import annotations

import os
import sys
import time
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

PROMPT_LENS = (4, 12, 28)
# cycle length coprime with PROMPT_LENS so every static bucket draws the
# full spread — incl. a 48-token straggler that pins its whole bucket
MAX_NEWS = (2, 4, 8, 48)
MAX_LEN = 96
MAX_BATCH = 8
PAGE_SIZE = 16


def _workload(n: int, vocab: int) -> List["repro.serve.Request"]:
    from repro.serve import Request

    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                prompt=rng.integers(0, vocab, size=PROMPT_LENS[i % 3],
                                    dtype=np.int32),
                max_new_tokens=MAX_NEWS[i % len(MAX_NEWS)])
        for i in range(n)
    ]


def run(fast: bool = False) -> List["BenchResult"]:
    from benchmarks.common import BenchResult, trained_model
    from repro.serve import ServeEngine

    model, params, _ = trained_model("lm")
    n_requests = 16 if fast else 24
    reqs = _workload(n_requests, model.cfg.vocab_size)

    static = ServeEngine(model, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                         mode="static")
    cont = ServeEngine(model, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                       mode="continuous", page_size=PAGE_SIZE)

    # warm both jit caches off the measured clock with a FULL pass of
    # the exact workload — jit specializes on bucket batch and prompt-pad
    # shapes, so a partial warmup would leave compiles inside one mode's
    # timing window and measure compiler latency instead of throughput
    static.generate(reqs)
    cont.generate(reqs)

    t0 = time.monotonic()
    rs = static.generate(reqs)
    static_s = time.monotonic() - t0
    t0 = time.monotonic()
    rc = cont.generate(reqs)
    cont_s = time.monotonic() - t0

    for a, b in zip(rs, rc):
        if not np.array_equal(a.tokens, b.tokens):
            raise RuntimeError(
                f"continuous != static greedy tokens for uid {a.uid}: "
                f"{a.tokens.tolist()} vs {b.tokens.tolist()}")

    toks = sum(len(r.tokens) for r in rs)
    tps_static = toks / static_s
    tps_cont = toks / cont_s
    util_static = float(np.mean([r.utilization for r in rs]))
    util_cont = float(np.mean([r.utilization for r in rc]))
    speedup = tps_cont / tps_static
    return [
        BenchResult("serve_throughput/static", static_s * 1e6,
                    f"tok_s={tps_static:.1f} util={util_static:.0%}"),
        BenchResult("serve_throughput/continuous", cont_s * 1e6,
                    f"tok_s={tps_cont:.1f} util={util_cont:.0%} "
                    f"speedup={speedup:.2f}x"),
    ]


if __name__ == "__main__":
    for res in run(fast="--fast" in sys.argv):
        print(res.csv())
