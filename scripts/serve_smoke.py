"""CI serve-smoke: boot the streaming HTTP server on the tiny LM, run
a stdlib streaming client, and assert the serving front end's
load-bearing properties end to end (docs/serving_frontend.md):

  1. SSE chunks arrive INCREMENTALLY — more than one data frame per
     request (steps_per_sync=2 forces several sync intervals), each
     flushed before the stream ends;
  2. the concatenated stream is bit-identical to batch-mode
     ServeEngine.generate output for the same uid/seed;
  3. GET /metrics (ISSUE-8) serves the Prometheus exposition across
     both replica labels of one shared registry, and the series the
     traffic implies are PRESENT AND NONZERO — ttft histogram count,
     host syncs, tokens, and (after a sequential duplicate-prompt
     wave that must hit the same replica's prefix index)
     serve_prefix_pages_reused_total; the preemption counters must at
     least be exposed.  /stats carries the registry-derived _summary.

Also smokes /healthz and the 404 path.  Runs in-process (no
subprocess-orchestration flakiness): server on the asyncio loop,
replicas on their worker threads — the same topology the CLI boots.

  PYTHONPATH=src python scripts/serve_smoke.py

``--chaos`` (ISSUE-10) runs the fault-tolerance smoke instead: a
FaultPlan kills replica r0's worker mid-stream (injected engine_step
raise on its third burst) while a supervisor polls, and one streaming
client hangs up mid-response.  Asserts every surviving stream —
including the failed-over ones — is bit-exact against an uninjected
batch run, the disconnect frees its request, and the /metrics recovery
counters (replica_restarts_total, requests_failed_over_total,
requests_cancelled_total, serve_recovery_seconds) actually ticked.
"""

import asyncio
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import LM
from repro.obs import Obs
from repro.serve import FaultPlan, FaultSpec, Request, ServeEngine
from repro.serve.frontend import (Replica, Router, Server, Supervisor,
                                  sse_decode)

STEPS_PER_SYNC = 2        # several sync intervals per request →
#                           several SSE frames: the incrementality check


def engine(model, params, obs=None, **kw):
    return ServeEngine(model, params, max_batch=4, max_len=64,
                       page_size=8, prefill_chunk=8,
                       steps_per_sync=STEPS_PER_SYNC, obs=obs, **kw)


async def post(host, port, obj):
    body = json.dumps(obj).encode()
    r, w = await asyncio.open_connection(host, port)
    w.write(f"POST /v1/completions HTTP/1.1\r\nHost: s\r\n"
            f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await w.drain()
    data = await r.read()
    w.close()
    head, _, rest = data.partition(b"\r\n\r\n")
    return int(head.split()[1]), rest


async def get(host, port, path):
    r, w = await asyncio.open_connection(host, port)
    w.write(f"GET {path} HTTP/1.1\r\nHost: s\r\n\r\n".encode())
    data = await r.read()
    w.close()
    head, _, body = data.partition(b"\r\n\r\n")
    return int(head.split()[1]), body


def series_sum(text, name):
    """(present, total) for one Prometheus series name across labels."""
    present, tot = False, 0.0
    for ln in text.splitlines():
        if ln == name or ln.startswith(name + "{") \
                or ln.startswith(name + " "):
            present = True
            tot += float(ln.rsplit(" ", 1)[1])
    return present, tot


async def main() -> None:
    cfg = get_smoke("paper_tiny_lm")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    params["unembed"]["head"] = params["unembed"]["head"] * 8.0

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(5, 9)[i % 2],
                                        dtype=np.int32),
                    max_new_tokens=(8, 11)[i % 2])
            for i in range(4)]
    ref = engine(model, params).generate(reqs, seed=0)

    # ONE shared registry with a replica-labelled view per engine — the
    # launcher's topology, and what makes /metrics collision-free
    obs = Obs.create(metrics=True, trace=False)
    router = Router([Replica(engine(model, params, obs.labelled(f"r{i}")),
                             name=f"r{i}", seed=0)
                     for i in range(2)])
    srv = Server(router, port=0)
    host, port = await srv.start()
    print(f"server up on {host}:{port} with 2 replicas")

    outs = await asyncio.gather(*[
        post(host, port, {"prompt": [int(t) for t in r.prompt],
                          "max_tokens": r.max_new_tokens, "uid": r.uid,
                          "stream": True})
        for r in reqs])
    for r, (status, rest) in zip(reqs, outs):
        assert status == 200, (r.uid, status)
        chunks = sse_decode(rest)
        assert len(chunks) > 1, \
            f"uid {r.uid}: expected incremental SSE frames, got {len(chunks)}"
        assert chunks[-1].finished
        toks = [t for c in chunks for t in c.tokens]
        want = list(next(x for x in ref if x.uid == r.uid).tokens)
        assert toks == want, f"uid {r.uid}: stream {toks} != batch {want}"
        print(f"uid {r.uid}: {len(chunks)} frames, {len(toks)} tokens, "
              f"stream == batch")

    # sequential duplicate ≥1-page prompts: both idle-tie-break onto r0,
    # so the second MUST attach the first's cached prefix page
    shared = [int(t) for t in rng.integers(0, cfg.vocab_size, size=12)]
    dup = []
    for uid in (100, 101):
        status, body = await post(host, port, {
            "prompt": shared, "max_tokens": 6, "uid": uid})
        assert status == 200, (uid, status)
        dup.append(json.loads(body)["tokens"])
    assert dup[0] == dup[1], f"prefix reuse changed tokens: {dup}"

    # ---- /metrics scrape gate (ISSUE-8 acceptance) --------------------
    status, body = await get(host, port, "/metrics")
    assert status == 200
    text = body.decode()
    assert 'replica="r0"' in text and 'replica="r1"' in text, \
        "metrics must carry both replica labels"
    for name, need_nonzero in (
            ("serve_host_syncs_total", True),
            ("serve_tokens_total", True),
            ("serve_requests_total", True),
            ("serve_ttft_seconds_count", True),
            ("serve_prefix_pages_reused_total", True),
            ("serve_preempt_swap_total", False),
            ("serve_preempt_recompute_total", False)):
        present, tot = series_sum(text, name)
        assert present, f"/metrics is missing {name}"
        if need_nonzero:
            assert tot > 0, f"{name} is zero after traffic"
    present, healthy = series_sum(text, "serve_replica_healthy")
    assert present and healthy == 2.0, f"healthy gauge: {healthy}"
    print("metrics scrape OK: required series present and nonzero")

    status, body = await get(host, port, "/stats")
    assert status == 200
    stats = json.loads(body)
    summary = stats.pop("_summary")
    assert summary["ttft_count"] >= len(reqs) + 2
    assert summary["ttft_ms_p50"] > 0
    assert sum(s["tokens"] for s in stats.values()) > 0
    print(f"/stats summary: ttft_p50={summary['ttft_ms_p50']:.1f}ms "
          f"over {summary['ttft_count']:.0f} requests")

    status, _ = await get(host, port, "/healthz")
    assert status == 200
    status, _ = await get(host, port, "/nope")
    assert status == 404
    await srv.shutdown(timeout=30)
    router.close()
    print("serve smoke OK: incremental SSE + batch parity + /metrics "
          "on 2 replicas")


async def chaos() -> None:
    """Fault-tolerance smoke (ISSUE-10): mid-stream replica crash with
    supervised failover + a mid-stream client disconnect."""
    cfg = get_smoke("paper_tiny_lm")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    params["unembed"]["head"] = params["unembed"]["head"] * 8.0

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(5, 9)[i % 2],
                                        dtype=np.int32),
                    max_new_tokens=(10, 13)[i % 2])
            for i in range(6)]
    ref = {r.uid: list(x.tokens) for r, x in
           zip(reqs, engine(model, params).generate(reqs, seed=0))}

    # ONE plan shared by both replicas, scoped to r0 (the burst hook
    # passes the engine's obs label): r0's third burst dispatch raises
    plan = FaultPlan([FaultSpec("engine_step", after=2, replica="r0")])
    obs = Obs.create(metrics=True, trace=False)
    router = Router([Replica(engine(model, params, obs.labelled(f"r{i}"),
                                    faults=plan),
                             name=f"r{i}", seed=0)
                     for i in range(2)])
    sup = Supervisor(router, poll_s=0.05, failover_retries=8)
    sup.start()
    srv = Server(router, port=0)
    host, port = await srv.start()
    print(f"chaos server up on {host}:{port}; plan: engine_step "
          f"after=2 on r0")

    # one extra streaming client that will hang up mid-response
    async def disconnecting_client():
        body = json.dumps({"prompt": [3, 1, 4, 1, 5], "max_tokens": 40,
                           "uid": 50, "stream": True}).encode()
        r, w = await asyncio.open_connection(host, port)
        w.write(f"POST /v1/completions HTTP/1.1\r\nHost: s\r\n"
                f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        await w.drain()
        await r.readuntil(b"\n\n")     # headers/first frame flowing...
        w.close()                      # ...then vanish

    outs, _ = await asyncio.gather(
        asyncio.gather(*[
            post(host, port, {"prompt": [int(t) for t in r.prompt],
                              "max_tokens": r.max_new_tokens, "uid": r.uid,
                              "stream": True})
            for r in reqs]),
        disconnecting_client())

    assert plan.fired.get("engine_step", 0) >= 1, \
        "chaos plan never fired — the crash was not exercised"
    for r, (status, rest) in zip(reqs, outs):
        assert status == 200, (r.uid, status)
        chunks = sse_decode(rest)
        assert chunks[-1].finished
        assert chunks[-1].finish_reason in ("stop", "length"), \
            f"uid {r.uid} did not finish cleanly: {chunks[-1].finish_reason}"
        toks = [t for c in chunks for t in c.tokens]
        assert toks == ref[r.uid], \
            f"uid {r.uid}: stream changed under chaos: {toks} != {ref[r.uid]}"
    print(f"all {len(reqs)} streams bit-exact vs uninjected run "
          f"(fault fired {plan.fired['engine_step']}x)")

    # the disconnect cancels asynchronously — wait for the counter
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        _, body = await get(host, port, "/metrics")
        _, n_cancel = series_sum(body.decode(), "requests_cancelled_total")
        if n_cancel >= 1:
            break
        await asyncio.sleep(0.05)

    status, body = await get(host, port, "/metrics")
    assert status == 200
    text = body.decode()
    for name in ("replica_restarts_total", "requests_failed_over_total",
                 "requests_cancelled_total", "serve_recovery_seconds_count"):
        present, tot = series_sum(text, name)
        assert present, f"/metrics is missing {name}"
        assert tot >= 1, f"{name} did not tick under chaos ({tot})"
        print(f"  {name} = {tot:.0f}")
    present, healthy = series_sum(text, "serve_replica_healthy")
    assert present and healthy == 2.0, \
        f"replicas not healthy after recovery: {healthy}"

    sup.stop()
    await srv.shutdown(timeout=30)
    router.close()
    print("chaos smoke OK: crash recovered, streams bit-exact, "
          "disconnect cancelled, recovery counters ticked")


if __name__ == "__main__":
    asyncio.run(chaos() if "--chaos" in sys.argv else main())
