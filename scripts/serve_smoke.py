"""CI serve-smoke: boot the streaming HTTP server on the tiny LM, run
a stdlib streaming client, and assert the serving front end's
load-bearing properties end to end (docs/serving_frontend.md):

  1. SSE chunks arrive INCREMENTALLY — more than one data frame per
     request (steps_per_sync=2 forces several sync intervals), each
     flushed before the stream ends;
  2. the concatenated stream is bit-identical to batch-mode
     ServeEngine.generate output for the same uid/seed;
  3. GET /metrics (ISSUE-8) serves the Prometheus exposition across
     both replica labels of one shared registry, and the series the
     traffic implies are PRESENT AND NONZERO — ttft histogram count,
     host syncs, tokens, and (after a sequential duplicate-prompt
     wave that must hit the same replica's prefix index)
     serve_prefix_pages_reused_total; the preemption counters must at
     least be exposed.  /stats carries the registry-derived _summary.

Also smokes /healthz and the 404 path.  Runs in-process (no
subprocess-orchestration flakiness): server on the asyncio loop,
replicas on their worker threads — the same topology the CLI boots.

  PYTHONPATH=src python scripts/serve_smoke.py
"""

import asyncio
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import LM
from repro.obs import Obs
from repro.serve import Request, ServeEngine
from repro.serve.frontend import Replica, Router, Server, sse_decode

STEPS_PER_SYNC = 2        # several sync intervals per request →
#                           several SSE frames: the incrementality check


def engine(model, params, obs=None):
    return ServeEngine(model, params, max_batch=4, max_len=64,
                       page_size=8, prefill_chunk=8,
                       steps_per_sync=STEPS_PER_SYNC, obs=obs)


async def post(host, port, obj):
    body = json.dumps(obj).encode()
    r, w = await asyncio.open_connection(host, port)
    w.write(f"POST /v1/completions HTTP/1.1\r\nHost: s\r\n"
            f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await w.drain()
    data = await r.read()
    w.close()
    head, _, rest = data.partition(b"\r\n\r\n")
    return int(head.split()[1]), rest


async def get(host, port, path):
    r, w = await asyncio.open_connection(host, port)
    w.write(f"GET {path} HTTP/1.1\r\nHost: s\r\n\r\n".encode())
    data = await r.read()
    w.close()
    head, _, body = data.partition(b"\r\n\r\n")
    return int(head.split()[1]), body


def series_sum(text, name):
    """(present, total) for one Prometheus series name across labels."""
    present, tot = False, 0.0
    for ln in text.splitlines():
        if ln == name or ln.startswith(name + "{") \
                or ln.startswith(name + " "):
            present = True
            tot += float(ln.rsplit(" ", 1)[1])
    return present, tot


async def main() -> None:
    cfg = get_smoke("paper_tiny_lm")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    params["unembed"]["head"] = params["unembed"]["head"] * 8.0

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=(5, 9)[i % 2],
                                        dtype=np.int32),
                    max_new_tokens=(8, 11)[i % 2])
            for i in range(4)]
    ref = engine(model, params).generate(reqs, seed=0)

    # ONE shared registry with a replica-labelled view per engine — the
    # launcher's topology, and what makes /metrics collision-free
    obs = Obs.create(metrics=True, trace=False)
    router = Router([Replica(engine(model, params, obs.labelled(f"r{i}")),
                             name=f"r{i}", seed=0)
                     for i in range(2)])
    srv = Server(router, port=0)
    host, port = await srv.start()
    print(f"server up on {host}:{port} with 2 replicas")

    outs = await asyncio.gather(*[
        post(host, port, {"prompt": [int(t) for t in r.prompt],
                          "max_tokens": r.max_new_tokens, "uid": r.uid,
                          "stream": True})
        for r in reqs])
    for r, (status, rest) in zip(reqs, outs):
        assert status == 200, (r.uid, status)
        chunks = sse_decode(rest)
        assert len(chunks) > 1, \
            f"uid {r.uid}: expected incremental SSE frames, got {len(chunks)}"
        assert chunks[-1].finished
        toks = [t for c in chunks for t in c.tokens]
        want = list(next(x for x in ref if x.uid == r.uid).tokens)
        assert toks == want, f"uid {r.uid}: stream {toks} != batch {want}"
        print(f"uid {r.uid}: {len(chunks)} frames, {len(toks)} tokens, "
              f"stream == batch")

    # sequential duplicate ≥1-page prompts: both idle-tie-break onto r0,
    # so the second MUST attach the first's cached prefix page
    shared = [int(t) for t in rng.integers(0, cfg.vocab_size, size=12)]
    dup = []
    for uid in (100, 101):
        status, body = await post(host, port, {
            "prompt": shared, "max_tokens": 6, "uid": uid})
        assert status == 200, (uid, status)
        dup.append(json.loads(body)["tokens"])
    assert dup[0] == dup[1], f"prefix reuse changed tokens: {dup}"

    # ---- /metrics scrape gate (ISSUE-8 acceptance) --------------------
    status, body = await get(host, port, "/metrics")
    assert status == 200
    text = body.decode()
    assert 'replica="r0"' in text and 'replica="r1"' in text, \
        "metrics must carry both replica labels"
    for name, need_nonzero in (
            ("serve_host_syncs_total", True),
            ("serve_tokens_total", True),
            ("serve_requests_total", True),
            ("serve_ttft_seconds_count", True),
            ("serve_prefix_pages_reused_total", True),
            ("serve_preempt_swap_total", False),
            ("serve_preempt_recompute_total", False)):
        present, tot = series_sum(text, name)
        assert present, f"/metrics is missing {name}"
        if need_nonzero:
            assert tot > 0, f"{name} is zero after traffic"
    present, healthy = series_sum(text, "serve_replica_healthy")
    assert present and healthy == 2.0, f"healthy gauge: {healthy}"
    print("metrics scrape OK: required series present and nonzero")

    status, body = await get(host, port, "/stats")
    assert status == 200
    stats = json.loads(body)
    summary = stats.pop("_summary")
    assert summary["ttft_count"] >= len(reqs) + 2
    assert summary["ttft_ms_p50"] > 0
    assert sum(s["tokens"] for s in stats.values()) > 0
    print(f"/stats summary: ttft_p50={summary['ttft_ms_p50']:.1f}ms "
          f"over {summary['ttft_count']:.0f} requests")

    status, _ = await get(host, port, "/healthz")
    assert status == 200
    status, _ = await get(host, port, "/nope")
    assert status == 404
    await srv.shutdown(timeout=30)
    router.close()
    print("serve smoke OK: incremental SSE + batch parity + /metrics "
          "on 2 replicas")


if __name__ == "__main__":
    asyncio.run(main())
