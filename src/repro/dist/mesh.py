"""Mesh construction (functions only — importing this module never
touches jax device state; jax locks the device count on first use, and
the dry-run must set XLA_FLAGS before that happens).

Axis-naming convention (docs/dist_api.md): ``pod`` (DCN, gradient/batch
outer axis), ``data`` (batch + FSDP), ``model`` (tensor/expert parallel).

``mesh_from_spec`` / ``add_mesh_argument`` / ``mesh_context`` are the
common ``--mesh`` entry path shared by the launch CLIs
(launch/train.py, launch/prune.py, launch/serve.py).
"""

from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> Tuple[str, ...]:
    """The batch-sharding axes of a mesh (the pod/data subset present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_host_mesh():
    """1×1 mesh over the local device (CPU tests of mesh-aware code)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_from_spec(spec: Optional[str]):
    """Resolve a ``--mesh`` CLI spec to a mesh (or ``None``).

    Accepted specs:
      ``none``/``""``/None  no mesh — single-device operation;
      ``host``              1×1 local mesh (exercises mesh code on CPU);
      ``production``        16×16 single pod;
      ``production-2pod``   2×16×16 two pods;
      ``AxB`` / ``AxBxC``   explicit shape, e.g. ``2x4`` → (data, model),
                            ``2x4x4`` → (pod, data, model).
    """
    if spec is None or spec in ("", "none"):
        return None
    if spec == "host":
        return make_host_mesh()
    if spec == "production":
        return make_production_mesh()
    if spec in ("production-2pod", "multipod"):
        return make_production_mesh(multi_pod=True)
    dims = spec.lower().split("x")
    if all(d.isdigit() for d in dims) and len(dims) in (2, 3):
        shape = tuple(int(d) for d in dims)
        axes = ("data", "model") if len(dims) == 2 else (
            "pod", "data", "model")
        return jax.make_mesh(shape, axes)
    raise ValueError(f"unrecognized --mesh spec {spec!r}")


def add_mesh_argument(parser) -> None:
    """Attach the shared ``--mesh`` flag to an argparse parser."""
    parser.add_argument(
        "--mesh", default="none",
        help="device mesh: none | host | production | production-2pod "
             "| AxB[xC] (see repro.dist.mesh.mesh_from_spec)")


def mesh_context(spec: Optional[str]):
    """``use_mesh`` over ``mesh_from_spec(spec)`` — a no-op null context
    (yielding ``None``) when the spec resolves to no mesh."""
    from repro.dist.api import use_mesh

    mesh = mesh_from_spec(spec)
    if mesh is None:
        return contextlib.nullcontext(None)
    return use_mesh(mesh)
