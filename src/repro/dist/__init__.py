"""repro.dist — the single source of truth for device context.

Everything mesh-shaped in the codebase goes through this package (full
reference: ``docs/dist_api.md``):

  - :mod:`repro.dist.api`      — ``use_mesh`` / ``current_ctx`` /
    ``constrain``: the ambient device context every model, trainer,
    pruner and server resolves instead of threading a mesh by hand;
  - :mod:`repro.dist.mesh`     — mesh construction (production pods,
    host test mesh, ``--mesh`` CLI specs);
  - :mod:`repro.dist.sharding` — the rules layer: param / batch
    PartitionSpecs and NamedShardings (FSDP over the data axes, tensor
    parallel over ``model``, MoE expert parallel);
  - :mod:`repro.dist.compat`   — version bridge for ``shard_map`` across
    the jax 0.4.x → 0.6+ API rename.

Axis-naming convention: ``pod`` (DCN, outer batch axis), ``data``
(batch + FSDP), ``model`` (tensor/expert parallel).
"""

from repro.dist.api import (
    DistContext,
    constrain,
    current_ctx,
    use_mesh,
)
from repro.dist.compat import cost_analysis_dict, shard_map
from repro.dist.mesh import (
    add_mesh_argument,
    dp_axes_of,
    make_host_mesh,
    make_production_mesh,
    mesh_context,
    mesh_from_spec,
)
from repro.dist.sharding import (
    FSDP_EXCLUDE_EMBED,
    batch_sharding,
    batch_spec,
    decode_cache_block_specs,
    moe_dispatch_specs,
    named_shardings,
    paged_kv_block_specs,
    paged_state_block_specs,
    param_shardings,
    param_specs,
    replicated,
    row_sharding,
    shard_params,
)

__all__ = [
    "DistContext",
    "constrain",
    "current_ctx",
    "use_mesh",
    "cost_analysis_dict",
    "shard_map",
    "add_mesh_argument",
    "dp_axes_of",
    "make_host_mesh",
    "make_production_mesh",
    "mesh_context",
    "mesh_from_spec",
    "FSDP_EXCLUDE_EMBED",
    "batch_sharding",
    "batch_spec",
    "decode_cache_block_specs",
    "moe_dispatch_specs",
    "named_shardings",
    "paged_kv_block_specs",
    "paged_state_block_specs",
    "param_shardings",
    "param_specs",
    "replicated",
    "row_sharding",
    "shard_params",
]
