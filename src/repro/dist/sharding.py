"""Sharding rules: every param/batch PartitionSpec in repro comes from
here (docs/dist_api.md).  No other module constructs NamedSharding /
PartitionSpec rules for params or batches ad hoc.

Layout policy (all assignments guarded by divisibility — a dim that does
not divide its mesh axes stays replicated, so any model is *correct* on
any mesh and merely less sharded when shapes don't line up):

  - linear kernels are stored (in, out).  Up-projections (wq/wk/wv/wi/
    wg/in_proj/…) are column-parallel: out dim over ``model``; the
    matching down-projections (wo/out_proj) are row-parallel: in dim
    over ``model`` — the Megatron pairing, one all-reduce per block;
  - FSDP shards the remaining matrix dim over ``fsdp_axes`` (the data
    (+pod) axes).  ``fsdp_exclude`` path patterns opt params out —
    :data:`FSDP_EXCLUDE_EMBED` keeps the embedding/LM-head resident
    (their per-step FSDP all-gather dominates the wire otherwise);
  - MoE expert stacks (E, d, f) shard experts over ``model``; with
    ``serve_moe=True`` additionally d_ff over ``data`` (2-D expert
    sharding — trillion-param MoEs fit resident at serve time);
  - embeddings (V, D) are vocab-parallel over ``model``; the router and
    all vectors (norm scales, biases) replicate;
  - stacked-layer subtrees ("layers/…", "enc/layers/…") carry a leading
    lax.scan dim that is never sharded;
  - batches shard dim 0 over the data (+pod) axes.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import tree_map_with_path

from repro.dist.mesh import dp_axes_of

# Param-path patterns kept out of FSDP: the tied/untied embedding matrix
# and the LM head (used with OptFlags.fsdp_embed_fix, §Perf iteration 1).
FSDP_EXCLUDE_EMBED: Tuple[str, ...] = ("embed/tok", "unembed/head")

# (in, out) kernels whose OUT dim is model-parallel (column-parallel).
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "wi", "wg", "wz", "wf", "wo_gate",
    "in_proj", "dt_proj", "x_proj", "frontend_proj", "head",
})
# (in, out) kernels whose IN dim is the model-parallel contraction.
_ROW_PARALLEL = frozenset({"wo", "out_proj"})
# Always replicated regardless of shape (f32 router: tiny and
# load-balance sensitive — sharding it buys nothing).
_REPLICATED = frozenset({"router"})

# Subtrees stacked over a leading lax.scan layer dim.
_STACKED_PREFIXES = ("layers/", "enc/layers/")


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _entry(axes: Sequence[str]):
    """PartitionSpec entry for one array dim over 1+ mesh axes."""
    axes = tuple(axes)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


# ----------------------------------------------------------------------
# Param rules
# ----------------------------------------------------------------------
def param_specs(
    params: Any,
    mesh: Mesh,
    *,
    fsdp_axes: Sequence[str] = (),
    fsdp_exclude: Sequence[str] = (),
    tp_axis: str = "model",
    serve_moe: bool = False,
) -> Any:
    """PartitionSpec pytree for a param tree under the layout policy
    above.  ``fsdp_axes=()`` disables FSDP (tensor-parallel only —
    the resident-weights serving configuration)."""
    fsdp_axes = tuple(a for a in fsdp_axes if a in mesh.axis_names)
    dp_size = _axes_size(mesh, fsdp_axes) if fsdp_axes else 1
    tp_axes = (tp_axis,) if tp_axis in mesh.axis_names else ()
    tp = mesh.shape[tp_axis] if tp_axes else 1
    data_axes = ("data",) if "data" in mesh.axis_names else ()
    data_size = mesh.shape["data"] if data_axes else 1

    def spec_for(path, leaf):
        name = _path_str(path)
        key = name.rsplit("/", 1)[-1]
        shape = tuple(leaf.shape)
        lead = 1 if name.startswith(_STACKED_PREFIXES) else 0
        base = shape[lead:]
        entries: list = [None] * len(base)
        excluded = any(pat in name for pat in fsdp_exclude)
        fsdp = fsdp_axes if (fsdp_axes and not excluded) else ()

        def put(dim: int, axes: Sequence[str], size: int) -> bool:
            if axes and entries[dim] is None and base[dim] % size == 0:
                entries[dim] = _entry(axes)
                return True
            return False

        is_expert = (len(base) == 3 and key in ("wi", "wg", "wo")
                     and "moe" in name.split("/"))
        if is_expert:
            put(0, tp_axes, tp)                       # experts × model
            f_dim, d_dim = (1, 2) if key == "wo" else (2, 1)
            if serve_moe:
                put(f_dim, data_axes, data_size)      # d_ff × data (2-D)
            else:
                put(d_dim, fsdp, dp_size)
        elif key == "tok" and len(base) == 2:
            put(0, tp_axes, tp)                       # vocab-parallel
            put(1, fsdp, dp_size)
        elif len(base) == 2 and key in _COL_PARALLEL:
            put(1, tp_axes, tp)
            put(0, fsdp, dp_size)
        elif len(base) == 2 and key in _ROW_PARALLEL:
            put(0, tp_axes, tp)
            put(1, fsdp, dp_size)
        elif key in _REPLICATED or len(base) < 2:
            pass                                      # replicate
        else:
            put(0, fsdp, dp_size)                     # generic FSDP
        if not any(e is not None for e in entries):
            return P()
        return P(*([None] * lead), *entries)

    return tree_map_with_path(spec_for, params)


def param_shardings(
    params: Any,
    mesh: Mesh,
    fsdp_axes: Sequence[str] = (),
    **kwargs,
) -> Any:
    """NamedSharding pytree over :func:`param_specs` (same keywords)."""
    return named_shardings(
        mesh, param_specs(params, mesh, fsdp_axes=fsdp_axes, **kwargs))


def shard_params(
    params: Any,
    mesh: Optional[Mesh] = None,
    fsdp_axes: Sequence[str] = (),
    **kwargs,
) -> Any:
    """Place a param tree onto the mesh under the standard rules.

    ``mesh=None`` resolves the active context's mesh (and its dp_axes as
    the FSDP axes unless given); with no context the params are returned
    unplaced — the single-device no-op.
    """
    if mesh is None:
        from repro.dist.api import current_ctx

        ctx = current_ctx()
        if ctx is None:
            return params
        mesh = ctx.mesh
        if not fsdp_axes:
            fsdp_axes = ctx.dp_axes
    return jax.device_put(
        params, param_shardings(params, mesh, fsdp_axes, **kwargs))


# ----------------------------------------------------------------------
# Batch rules
# ----------------------------------------------------------------------
def batch_spec(mesh: Mesh, dp_axes: Optional[Sequence[str]] = None) -> P:
    """Batch PartitionSpec: dim 0 over the data (+pod) axes, the rest
    replicated (trailing dims are unconstrained in PartitionSpec)."""
    if dp_axes is None:
        dp_axes = dp_axes_of(mesh)
    dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    if not dp_axes:
        return P()
    return P(_entry(dp_axes))


def batch_sharding(
    mesh: Mesh, dp_axes: Optional[Sequence[str]] = None
) -> NamedSharding:
    """NamedSharding twin of :func:`batch_spec`."""
    return NamedSharding(mesh, batch_spec(mesh, dp_axes))


# ----------------------------------------------------------------------
# Generic helpers
# ----------------------------------------------------------------------
def named_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    """Wrap a PartitionSpec pytree (e.g. from :func:`param_specs` or
    ``LM.cache_specs``) into NamedShardings on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully-replicated NamedSharding on ``mesh``."""
    return NamedSharding(mesh, P())


def row_sharding(mesh: Mesh, axis="model", ndim: int = 2) -> NamedSharding:
    """Dim 0 over ``axis`` (one mesh axis, or a tuple like
    ``("pod", "data")``), the rest replicated — the layout of the
    row-parallel layer solve (core.distributed, Remark 4.2) and of the
    stacked per-shard Hessians entering ``hessian_allreduce``."""
    entry = _entry(axis) if isinstance(axis, (tuple, list)) else axis
    return NamedSharding(mesh, P(entry, *([None] * (ndim - 1))))
