"""Sharding rules: every param/batch PartitionSpec in repro comes from
here (docs/dist_api.md).  No other module constructs NamedSharding /
PartitionSpec rules for params or batches ad hoc.

Layout policy (all assignments guarded by divisibility — a dim that does
not divide its mesh axes stays replicated, so any model is *correct* on
any mesh and merely less sharded when shapes don't line up):

  - linear kernels are stored (in, out).  Up-projections (wq/wk/wv/wi/
    wg/in_proj/…) are column-parallel: out dim over ``model``; the
    matching down-projections (wo/out_proj) are row-parallel: in dim
    over ``model`` — the Megatron pairing, one all-reduce per block;
  - FSDP shards the remaining matrix dim over ``fsdp_axes`` (the data
    (+pod) axes).  ``fsdp_exclude`` path patterns opt params out —
    :data:`FSDP_EXCLUDE_EMBED` keeps the embedding/LM-head resident
    (their per-step FSDP all-gather dominates the wire otherwise);
  - MoE expert stacks (E, d, f) shard experts over ``model``; with
    ``serve_moe=True`` additionally d_ff over ``data`` (2-D expert
    sharding — trillion-param MoEs fit resident at serve time);
  - embeddings (V, D) are vocab-parallel over ``model``; the router and
    all vectors (norm scales, biases) replicate;
  - stacked-layer subtrees ("layers/…", "enc/layers/…") carry a leading
    lax.scan dim that is never sharded;
  - batches shard dim 0 over the data (+pod) axes.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import tree_map_with_path

from repro.dist.mesh import dp_axes_of

# Param-path patterns kept out of FSDP: the tied/untied embedding matrix
# and the LM head (used with OptFlags.fsdp_embed_fix, §Perf iteration 1).
FSDP_EXCLUDE_EMBED: Tuple[str, ...] = ("embed/tok", "unembed/head")

# (in, out) kernels whose OUT dim is model-parallel (column-parallel).
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "wi", "wg", "wz", "wf", "wo_gate",
    "in_proj", "dt_proj", "x_proj", "frontend_proj", "head",
})
# (in, out) kernels whose IN dim is the model-parallel contraction.
_ROW_PARALLEL = frozenset({"wo", "out_proj"})
# Always replicated regardless of shape (f32 router: tiny and
# load-balance sensitive — sharding it buys nothing).
_REPLICATED = frozenset({"router"})

# Subtrees stacked over a leading lax.scan layer dim.
_STACKED_PREFIXES = ("layers/", "enc/layers/")


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _entry(axes: Sequence[str]):
    """PartitionSpec entry for one array dim over 1+ mesh axes."""
    axes = tuple(axes)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


# ----------------------------------------------------------------------
# Param rules
# ----------------------------------------------------------------------
def param_specs(
    params: Any,
    mesh: Mesh,
    *,
    fsdp_axes: Sequence[str] = (),
    fsdp_exclude: Sequence[str] = (),
    tp_axis: str = "model",
    serve_moe: bool = False,
    head_dim: Optional[int] = None,
) -> Any:
    """PartitionSpec pytree for a param tree under the layout policy
    above.  ``fsdp_axes=()`` disables FSDP (tensor-parallel only —
    the resident-weights serving configuration).

    ``head_dim``: when given, the attention projections (wq/wk/wv
    column-parallel, attn/xattn wo row-parallel) only shard over
    ``model`` if every shard holds WHOLE heads (dim/tp a multiple of
    head_dim).  A shard boundary through a head makes rope/qk-norm/
    softmax operate on split halves — semantically fine under GSPMD,
    but the jax 0.4.x CPU partitioner mis-executes the attention
    slice+concat chain (observed maxdiff ~1 on the smoke configs), so
    serving passes cfg.hd and falls back to replicating those
    projections.  Leave None for the pure shape-divisibility rule
    (training)."""
    fsdp_axes = tuple(a for a in fsdp_axes if a in mesh.axis_names)
    dp_size = _axes_size(mesh, fsdp_axes) if fsdp_axes else 1
    tp_axes = (tp_axis,) if tp_axis in mesh.axis_names else ()
    tp = mesh.shape[tp_axis] if tp_axes else 1
    data_axes = ("data",) if "data" in mesh.axis_names else ()
    data_size = mesh.shape["data"] if data_axes else 1

    def spec_for(path, leaf):
        name = _path_str(path)
        parts = name.split("/")
        key = parts[-1]
        # 2:4-packed leaves (serve.sparse pack_24): .../wq/vals and
        # .../wq/idx inherit the parent projection's rule — vals/idx
        # share the dense leaf's shape except K halved, so the
        # column-parallel N split carries over unchanged and the
        # row-parallel contraction split applies to K/2 rows (the
        # head-alignment guard below accounts for the halving)
        packed = (key in ("vals", "idx") and len(parts) >= 2
                  and (parts[-2] in _COL_PARALLEL
                       or parts[-2] in _ROW_PARALLEL))
        if packed:
            parts = parts[:-1]
            key = parts[-1]
        shape = tuple(leaf.shape)
        lead = 1 if name.startswith(_STACKED_PREFIXES) else 0
        base = shape[lead:]
        entries: list = [None] * len(base)
        excluded = any(pat in name for pat in fsdp_exclude)
        fsdp = fsdp_axes if (fsdp_axes and not excluded) else ()

        def put(dim: int, axes: Sequence[str], size: int) -> bool:
            if axes and entries[dim] is None and base[dim] % size == 0:
                entries[dim] = _entry(axes)
                return True
            return False

        is_expert = (len(base) == 3 and key in ("wi", "wg", "wo")
                     and "moe" in name.split("/"))
        if is_expert:
            put(0, tp_axes, tp)                       # experts × model
            f_dim, d_dim = (1, 2) if key == "wo" else (2, 1)
            if serve_moe:
                put(f_dim, data_axes, data_size)      # d_ff × data (2-D)
            else:
                put(d_dim, fsdp, dp_size)
        elif key == "tok" and len(base) == 2:
            put(0, tp_axes, tp)                       # vocab-parallel
            put(1, fsdp, dp_size)
        elif len(base) == 2 and key in _COL_PARALLEL:
            whole_heads = (head_dim is None or key not in ("wq", "wk", "wv")
                           or (base[1] // tp) % head_dim == 0)
            if whole_heads:
                put(1, tp_axes, tp)
            put(0, fsdp, dp_size)
        elif len(base) == 2 and key in _ROW_PARALLEL:
            parent = parts[-2] if len(parts) >= 2 else ""
            k_full = base[0] * (2 if packed else 1)
            whole_heads = (head_dim is None
                           or parent not in ("attn", "xattn")
                           or (k_full // tp) % head_dim == 0)
            if whole_heads:
                put(0, tp_axes, tp)
            put(1, fsdp, dp_size)
        elif key in _REPLICATED or len(base) < 2:
            pass                                      # replicate
        else:
            put(0, fsdp, dp_size)                     # generic FSDP
        if not any(e is not None for e in entries):
            return P()
        return P(*([None] * lead), *entries)

    return tree_map_with_path(spec_for, params)


def param_shardings(
    params: Any,
    mesh: Mesh,
    fsdp_axes: Sequence[str] = (),
    **kwargs,
) -> Any:
    """NamedSharding pytree over :func:`param_specs` (same keywords)."""
    return named_shardings(
        mesh, param_specs(params, mesh, fsdp_axes=fsdp_axes, **kwargs))


def shard_params(
    params: Any,
    mesh: Optional[Mesh] = None,
    fsdp_axes: Sequence[str] = (),
    **kwargs,
) -> Any:
    """Place a param tree onto the mesh under the standard rules.

    ``mesh=None`` resolves the active context's mesh (and its dp_axes as
    the FSDP axes unless given); with no context the params are returned
    unplaced — the single-device no-op.
    """
    if mesh is None:
        from repro.dist.api import current_ctx

        ctx = current_ctx()
        if ctx is None:
            return params
        mesh = ctx.mesh
        if not fsdp_axes:
            fsdp_axes = ctx.dp_axes
    return jax.device_put(
        params, param_shardings(params, mesh, fsdp_axes, **kwargs))


# ----------------------------------------------------------------------
# Batch rules
# ----------------------------------------------------------------------
def batch_spec(mesh: Mesh, dp_axes: Optional[Sequence[str]] = None) -> P:
    """Batch PartitionSpec: dim 0 over the data (+pod) axes, the rest
    replicated (trailing dims are unconstrained in PartitionSpec)."""
    if dp_axes is None:
        dp_axes = dp_axes_of(mesh)
    dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    if not dp_axes:
        return P()
    return P(_entry(dp_axes))


def batch_sharding(
    mesh: Mesh, dp_axes: Optional[Sequence[str]] = None
) -> NamedSharding:
    """NamedSharding twin of :func:`batch_spec`."""
    return NamedSharding(mesh, batch_spec(mesh, dp_axes))


# ----------------------------------------------------------------------
# Decode-cache rules (LM.cache_specs / the paged serve pool)
# ----------------------------------------------------------------------
def decode_cache_block_specs(
    kind: str,
    dims: dict,
    mesh: Mesh,
    *,
    extra_lead: int = 0,
    dp_axes: Sequence[str] = ("data",),
    tp_axis: str = "model",
    seq_shard: bool = False,
    prefer_seq: bool = False,
):
    """PartitionSpec dict for ONE decode-cache block of ``kind``.

    The cache twin of :func:`param_specs` — every decode-cache
    PartitionSpec in repro comes from here (``LM.cache_specs`` merely
    assembles these per the model's block layout).  ``dims`` carries the
    divisibility-relevant model dims: ``num_kv_heads``, ``hd``,
    ``d_inner``, ``d_model``, ``mlstm_hd``.

    Layout policy: batch over the data (+pod) axes, the per-kind 'width'
    dim (KV heads / head_dim / d_inner) over the model axis when
    divisible.  ``seq_shard=True`` (long-context, batch < #data-shards):
    the KV cache's *sequence* dim shards over the data axes instead of
    batch (ring-attention-style context parallelism for decode);
    recurrent state caches replicate over data (they are O(d) small).
    ``prefer_seq``: when KV heads don't divide the model axis, shard the
    sequence dim over model instead of head_dim (§Perf — an S-sharded
    cache keeps attention scores local and reduces only softmax
    partials).  ``extra_lead`` prepends unsharded dims (the lax.scan
    layer-stack dim).
    """
    tp = mesh.shape[tp_axis]
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    dpe = dp if len(dp) > 1 else (dp[0] if dp else None)
    if seq_shard:
        seq_dpe, dpe = dpe, None
    else:
        seq_dpe = None
    lead = [None] * extra_lead

    def kv_spec():
        # (B, S, KV, hd): KV heads when they divide TP; otherwise either
        # head_dim (baseline) or — prefer_seq — the SEQUENCE dim over
        # model (GSPMD all-gathers an hd-sharded cache for the score
        # contraction; an S-sharded cache keeps scores local).
        if dims["num_kv_heads"] % tp == 0:
            sp = (dpe, seq_dpe, tp_axis, None)
        elif prefer_seq and seq_dpe is None:
            sp = (dpe, tp_axis, None, None)
        elif dims["hd"] % tp == 0:
            sp = (dpe, seq_dpe, None, tp_axis)
        else:
            sp = (dpe, seq_dpe, None, None)
        return P(*lead, *sp)

    if kind in ("attn", "attn_local"):
        return {"k": kv_spec(), "v": kv_spec()}
    if kind == "dec_attn":
        return {"k": kv_spec(), "v": kv_spec(),
                "xk": kv_spec(), "xv": kv_spec()}
    if kind == "mamba":
        di = tp_axis if dims["d_inner"] % tp == 0 else None
        return {"conv": P(*lead, dpe, None, di),
                "ssm": P(*lead, dpe, di, None)}
    if kind == "mlstm":
        hsp = tp_axis if dims["mlstm_hd"] % tp == 0 else None
        return {"c": P(*lead, dpe, None, hsp, None),
                "n": P(*lead, dpe, None, hsp),
                "m": P(*lead, dpe, None)}
    if kind == "slstm":
        dsp = tp_axis if dims["d_model"] % tp == 0 else None
        return {k: P(*lead, dpe, dsp) for k in "cnhm"}
    raise ValueError(kind)


def paged_kv_block_specs(
    dims: dict,
    mesh: Mesh,
    *,
    extra_lead: int = 0,
    tp_axis: str = "model",
    quantized: bool = False,
):
    """PartitionSpec dict for one paged KV-pool block (serve.kvpool).

    Pool leaves are (num_pages, page_size, KV, hd).  The page pool is a
    single global address space indexed by per-request block tables, so
    the page dims never shard (replicated over the data axes — any
    device can serve any request); KV heads shard over the model axis
    when they divide it, keeping the pool's layout aligned with the
    head-parallel resident weights.  Unlike the dense decode cache there
    is NO head_dim fallback: an hd-sharded pool would split the decode
    score contraction across the model axis — an all-reduce inside every
    paged-attention call, and a different f32 reduction order that
    breaks the paged path's greedy bit-parity with the dense one
    (docs/serving.md).
    """
    tp = mesh.shape[tp_axis] if tp_axis in mesh.axis_names else 1
    lead = [None] * extra_lead
    if tp > 1 and dims["num_kv_heads"] % tp == 0:
        sp = (None, None, tp_axis, None)
    else:
        sp = (None, None, None, None)
    spec = P(*lead, *sp)
    out = {"k": spec, "v": spec}
    if quantized:
        # int8 pools carry per-row f32 scale leaves (num_pages,
        # page_size, KV) — same placement as their pages minus the hd dim
        scale_spec = P(*lead, *sp[:3])
        out["k_scale"] = scale_spec
        out["v_scale"] = scale_spec
    return out


def paged_state_block_specs(
    kind: str,
    dims: dict,
    mesh: Mesh,
    *,
    extra_lead: int = 0,
    tp_axis: str = "model",
):
    """PartitionSpec dict for one slot-pooled recurrent-state block
    (serve.kvpool.StatePool — the state twin of
    :func:`paged_kv_block_specs`).

    State leaves carry a leading ``max_slots`` dim that never shards
    (any device serves any request — same policy as the page dims).
    The width dim shards over the model axis only when the split is
    head-aligned: d_inner for mamba (elementwise + N-contractions only,
    always safe when divisible), whole mLSTM/sLSTM *heads* — like the
    pool's no-head_dim-fallback rule, a sub-head split would move a
    contraction across the model axis and change the f32 reduction
    order the paged/dense bit-parity rests on.
    """
    tp = mesh.shape[tp_axis] if tp_axis in mesh.axis_names else 1
    lead = [None] * extra_lead
    if kind == "mamba":
        di = tp_axis if tp > 1 and dims["d_inner"] % tp == 0 else None
        return {"conv": P(*lead, None, None, di),
                "ssm": P(*lead, None, di, None)}
    if kind == "mlstm":
        hsp = tp_axis if tp > 1 and dims["num_heads"] % tp == 0 else None
        return {"c": P(*lead, None, hsp, None, None),
                "n": P(*lead, None, hsp, None),
                "m": P(*lead, None, hsp)}
    if kind == "slstm":
        ok = (tp > 1 and dims["num_heads"] % tp == 0
              and dims["d_model"] % tp == 0)
        dsp = tp_axis if ok else None
        return {k: P(*lead, None, dsp) for k in "cnhm"}
    raise ValueError(kind)


def decode_state_specs(state: Any) -> Any:
    """PartitionSpec pytree for the serve engine's device-resident
    scheduler-state blob (serve.fused.init_burst_state — per-slot
    ``tok``/``pos``/``uid``/``n_tok``/``max_new``/``done`` vectors, the
    token output ring, and the dynamic burst counter).

    Everything replicates: the slot dim never shards (any device serves
    any request — the same policy as the page/slot dims in
    :func:`paged_kv_block_specs` / :func:`paged_state_block_specs`),
    and the arrays are a few hundred bytes — but the specs live HERE,
    in the rules layer, so the fused burst's loop-carried state has an
    explicit mesh-agnostic placement instead of whatever jit infers
    from an uncommitted host upload (docs/dist_api.md)."""
    return jax.tree.map(lambda _: P(), state)


def host_arena_stage_spec() -> P:
    """Placement rule for a host-arena page blob staged for swap-in
    (serve.kvpool.HostArena — the host-memory KV swap tier below the
    paged pool, docs/serving.md).

    Replicated.  The staged blob's leading dim is *pages*, and the page
    dims of the pool never shard (see :func:`paged_kv_block_specs`: the
    pool is one global address space — any device serves any request),
    so the bytes streaming back from the host tier replicate the same
    way; the scatter that lands them (``pool_leaf.at[pages].set(blob)``)
    then inherits each leaf's pool sharding through its *output*, and
    the KV-head split (when ``model`` divides the heads) is re-imposed
    by the operand, not by the staging upload.  Committing the upload
    here — instead of leaving it an uncommitted host array — keeps the
    swap-in path's placement an explicit rule rather than whatever the
    eager scatter infers (docs/dist_api.md)."""
    return P()


# ----------------------------------------------------------------------
# MoE expert-dispatch rules (models/moe.py shard_map)
# ----------------------------------------------------------------------
def moe_dispatch_specs(ctx) -> Tuple[tuple, P]:
    """shard_map specs for the expert-parallel MoE dispatch.

    Built from a :class:`repro.dist.api.DistContext`: token-major
    operands shard dim 0 over the context's batch axes; the three
    (E, ·, ·) expert weight stacks shard experts over its
    tensor-parallel axis.  Returns ``(in_specs, out_specs)`` for
    ``(tokens, gates, wi, wg, wo) -> out``.
    """
    tok = P(_entry(ctx.dp_axes), None)
    exp = P(ctx.tp_axis, None, None)
    return (tok, tok, exp, exp, exp), tok


# ----------------------------------------------------------------------
# Generic helpers
# ----------------------------------------------------------------------
def named_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    """Wrap a PartitionSpec pytree (e.g. from :func:`param_specs` or
    ``LM.cache_specs``) into NamedShardings on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully-replicated NamedSharding on ``mesh``."""
    return NamedSharding(mesh, P())


def row_sharding(mesh: Mesh, axis="model", ndim: int = 2) -> NamedSharding:
    """Dim 0 over ``axis`` (one mesh axis, or a tuple like
    ``("pod", "data")``), the rest replicated — the layout of the
    row-parallel layer solve (core.distributed, Remark 4.2) and of the
    stacked per-shard Hessians entering ``hessian_allreduce``."""
    entry = _entry(axis) if isinstance(axis, (tuple, list)) else axis
    return NamedSharding(mesh, P(entry, *([None] * (ndim - 1))))
