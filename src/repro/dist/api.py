"""Device-context API: ``use_mesh`` / ``current_ctx`` / ``constrain``.

The context is an ambient, thread-local stack: code that cares about
distribution asks ``current_ctx()`` and gets either a :class:`DistContext`
(inside ``use_mesh``) or ``None`` — in which case every call site degrades
to a single-device no-op.  That one convention is what lets the same
model / trainer / pruner / server code run unchanged on a laptop CPU and
on a 512-chip multi-pod mesh.

Lifecycle (see docs/dist_api.md):

    mesh = make_production_mesh()
    with use_mesh(mesh):                # activates ctx + enters mesh for jit
        ctx = current_ctx()             # DistContext(mesh, dp_axes, ...)
        y = constrain(x, ctx.dp_axes)   # sharding constraint (no-op outside)
    current_ctx()                       # -> None again

Contexts nest: an inner ``use_mesh`` shadows the outer one and exiting it
restores the outer context exactly (tested in tests/test_dist_api.py).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Iterator, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_local = threading.local()


def _stack() -> list:
    stack = getattr(_local, "stack", None)
    if stack is None:
        stack = _local.stack = []
    return stack


@dataclasses.dataclass(frozen=True)
class DistContext:
    """Active device context: the mesh plus the axis-role assignment.

    ``dp_axes`` are the batch/FSDP axes (``("pod", "data")`` on a
    multi-pod mesh, ``("data",)`` otherwise); ``tp_axis`` is the
    tensor/expert-parallel axis (``None`` when the mesh has no ``model``
    axis).  ``dp`` / ``tp`` are the corresponding total shard counts.
    """

    mesh: Mesh
    dp_axes: Tuple[str, ...]
    tp_axis: Optional[str]

    @property
    def dp(self) -> int:
        size = 1
        for a in self.dp_axes:
            size *= self.mesh.shape[a]
        return size

    @property
    def tp(self) -> int:
        if self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]


def current_ctx() -> Optional[DistContext]:
    """The innermost active :class:`DistContext`, or ``None`` outside any
    ``use_mesh`` — callers treat ``None`` as "single device, do nothing"."""
    stack = _stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh(
    mesh: Mesh,
    dp_axes: Optional[Sequence[str]] = None,
    tp_axis: Optional[str] = "model",
) -> Iterator[DistContext]:
    """Activate ``mesh`` as the ambient device context (and enter it for
    jit, so bare-PartitionSpec shardings resolve against it).

    ``dp_axes`` defaults to the batch axes present in the mesh
    (``pod``/``data``); ``tp_axis`` degrades to ``None`` when the mesh
    has no such axis, so host meshes like ``(8,) ("data",)`` work too.
    """
    from repro.dist.mesh import dp_axes_of

    if dp_axes is None:
        dp_axes = dp_axes_of(mesh)
    if tp_axis is not None and tp_axis not in mesh.axis_names:
        tp_axis = None
    ctx = DistContext(mesh, tuple(dp_axes), tp_axis)
    _stack().append(ctx)
    try:
        with mesh:
            yield ctx
    finally:
        _stack().pop()


def constrain(x: jax.Array, *spec) -> jax.Array:
    """Sharding-constraint wrapper: ``constrain(x, "data", None)`` pins
    ``x``'s layout on the active mesh; without an active context it
    returns ``x`` untouched (single-device no-op).

    Spec entries follow PartitionSpec: an axis name, a tuple of axis
    names (one array dim over several mesh axes), or ``None``.
    """
    ctx = current_ctx()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, PartitionSpec(*spec)))
