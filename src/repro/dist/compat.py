"""Version bridge for ``shard_map`` across jax API generations.

jax ≥ 0.6 exposes ``jax.shard_map`` with a ``check_vma`` flag; 0.4.x has
``jax.experimental.shard_map.shard_map`` with the equivalent flag named
``check_rep``.  All repro call sites import :func:`shard_map` from here
(or from ``repro.dist``) so the rest of the codebase is written against
one signature.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax


def shard_map(
    f: Optional[Callable] = None,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
) -> Callable:
    """``jax.shard_map`` with the modern keyword signature on any
    installed jax.  Usable directly or as ``functools.partial``-style
    decorator (``shard_map(mesh=..., in_specs=..., out_specs=...)(f)``).
    """
    if f is None:
        return functools.partial(
            shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as one flat dict on any jax: 0.4.x
    returns a one-entry list of per-device dicts, newer jax the dict
    itself."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
