"""2:4-compressed weight × activation matmul (Pallas TPU kernel).

TPU adaptation of GPU 2:4 sparse tensor cores (DESIGN.md §4.2): TPUs have
no sparse MXU, but 2:4 serving is HBM-bandwidth-bound at decode — so we
store weights compressed (half the bytes: values (K/2,N) + 2-bit indices,
carried as int8 here) and *decompress inside VMEM* right before a dense
MXU matmul.  Weight HBM traffic drops ~1.9× (2.0× values, minus the index
stream), which is the roofline win for memory-bound decode layers.

Tiling: grid (M/bm, N/bn, K/bk); x tile (bm,bk), compressed tiles
(bk/2,bn), f32 accumulator tile (bm,bn) revisited along k (innermost,
sequential on TPU).  Default 128³ dense-equivalent tiles: VMEM ≈
32KB (x, bf16) + 16KB (vals) + 8KB (idx) + 64KB (acc f32) ≪ v5e VMEM;
all matmul dims are 128-aligned for the MXU.

In-VMEM decompress is branch-free VPU code:
  dense[4g + r, n] = Σ_s vals[2g+s, n] · (idx[2g+s, n] == r)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nm_spmm_kernel(x_ref, vals_ref, idx_ref, o_ref, *, bk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                                # (bm, bk)
    vals = vals_ref[...]                          # (bk//2, bn)
    idx = idx_ref[...]                            # (bk//2, bn) int8
    g = bk // 4
    bn = vals.shape[-1]
    v = vals.reshape(g, 2, bn).astype(jnp.float32)
    ix = idx.reshape(g, 2, bn).astype(jnp.int32)
    r = jax.lax.broadcasted_iota(jnp.int32, (g, 2, 4, bn), 2)
    hit = (ix[:, :, None, :] == r).astype(jnp.float32)
    dense = jnp.sum(v[:, :, None, :] * hit, axis=1).reshape(bk, bn)
    o_ref[...] += jax.lax.dot(
        x.astype(jnp.float32), dense,
        preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret"),
)
def nm_spmm(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y = x @ decompress_24(vals, idx).

    x: (M, K); vals/idx: (K/2, N). Returns (M, N) float32.
    M, K, N must divide by the tile sizes (callers pad).
    """
    m, k = x.shape
    k2, n = vals.shape
    if k2 * 2 != k:
        raise ValueError(f"vals rows {k2} != K/2 = {k // 2}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{k},{n}) not divisible by "
                         f"tiles ({bm},{bk},{bn})")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_nm_spmm_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, vals, idx)
