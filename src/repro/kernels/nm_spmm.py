"""2:4-compressed weight × activation matmul (Pallas TPU kernel).

TPU adaptation of GPU 2:4 sparse tensor cores (DESIGN.md §4.2): TPUs have
no sparse MXU, but 2:4 serving is HBM-bandwidth-bound at decode — so we
store weights compressed (half the bytes: values (K/2,N) + 2-bit indices,
carried as int8 here) and *decompress inside VMEM* right before a dense
MXU matmul.  Weight HBM traffic drops ~1.9× (2.0× values, minus the index
stream), which is the roofline win for memory-bound decode layers.

Tiling: grid (M/bm, N/bn, K/bk); x tile (bm,bk), compressed tiles
(bk/2,bn), f32 accumulator tile (bm,bn) revisited along k (innermost,
sequential on TPU).  Default 128³ dense-equivalent tiles: VMEM ≈
32KB (x, bf16) + 16KB (vals) + 8KB (idx) + 64KB (acc f32) ≪ v5e VMEM;
all matmul dims are 128-aligned for the MXU.

In-VMEM decompress is branch-free VPU code:
  dense[4g + r, n] = Σ_s vals[2g+s, n] · (idx[2g+s, n] == r)

:func:`nm_spmm_decode` is the serve-time decode shape (ISSUE-9): M is
the decode batch (a handful of rows, padded to the f32 sublane minimum
of 8), so the whole M extent is ONE block and the grid drops to
(N/bn, K/bk) with k innermost — plus a fused epilogue (bias add +
activation) applied to the accumulator tile at the last k step, saving
the extra HBM round-trip a separate bias/act op would cost on a
memory-bound step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import activate


def _decompress_tile(vals, idx, bk: int) -> jax.Array:
    """Branch-free in-VMEM 2:4 decompress of one (bk/2, bn) tile pair to
    a dense (bk, bn) f32 tile (the shared body of both kernels)."""
    g = bk // 4
    bn = vals.shape[-1]
    v = vals.reshape(g, 2, bn).astype(jnp.float32)
    ix = idx.reshape(g, 2, bn).astype(jnp.int32)
    r = jax.lax.broadcasted_iota(jnp.int32, (g, 2, 4, bn), 2)
    hit = (ix[:, :, None, :] == r).astype(jnp.float32)
    return jnp.sum(v[:, :, None, :] * hit, axis=1).reshape(bk, bn)


def _nm_spmm_kernel(x_ref, vals_ref, idx_ref, o_ref, *, bk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dense = _decompress_tile(vals_ref[...], idx_ref[...], bk)
    o_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), dense,
        preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret"),
)
def nm_spmm(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """y = x @ decompress_24(vals, idx).

    x: (M, K); vals/idx: (K/2, N). Returns (M, N) float32.
    M, K, N must divide by the tile sizes (callers pad).
    """
    m, k = x.shape
    k2, n = vals.shape
    if k2 * 2 != k:
        raise ValueError(f"vals rows {k2} != K/2 = {k // 2}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{k},{n}) not divisible by "
                         f"tiles ({bm},{bk},{bn})")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_nm_spmm_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, vals, idx)


def _nm_spmm_decode_kernel(x_ref, vals_ref, idx_ref, bias_ref, o_ref, *,
                           bk: int, activation):
    k_step = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    dense = _decompress_tile(vals_ref[...], idx_ref[...], bk)
    o_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), dense,
        preferred_element_type=jnp.float32)

    # fused epilogue: bias + activation on the resident accumulator tile
    # at the last k step — no second pass over the (M, N) output in HBM
    @pl.when(k_step == n_k - 1)
    def _epilogue():
        o_ref[...] = activate(o_ref[...] + bias_ref[...], activation)


@functools.partial(
    jax.jit,
    static_argnames=("bn", "bk", "activation", "interpret"),
)
def nm_spmm_decode(
    x: jax.Array,
    vals: jax.Array,
    idx: jax.Array,
    bias: jax.Array,
    *,
    bn: int = 128,
    bk: int = 128,
    activation=None,
    interpret: bool = False,
) -> jax.Array:
    """Decode-shaped y = act(x @ decompress_24(vals, idx) + bias).

    x: (M, K) with skinny M (the decode batch; callers pad M to ≥8 for
    the f32 sublane tile) — the whole M extent is one block, so the grid
    is (N/bn, K/bk) with k innermost.  bias: (1, N) (pass zeros for
    none); ``activation``: None | "silu" | "gelu", applied in the
    epilogue.  N and K must divide by the tile sizes (callers pad).
    Returns (M, N) float32.
    """
    m, k = x.shape
    k2, n = vals.shape
    if k2 * 2 != k:
        raise ValueError(f"vals rows {k2} != K/2 = {k // 2}")
    if n % bn or k % bk:
        raise ValueError(f"shape ({m},{k},{n}) not divisible by "
                         f"tiles ({bk},{bn})")
    grid = (n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_nm_spmm_decode_kernel, bk=bk,
                          activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, bk), lambda j, t: (0, t)),
            pl.BlockSpec((bk // 2, bn), lambda j, t: (t, j)),
            pl.BlockSpec((bk // 2, bn), lambda j, t: (t, j)),
            pl.BlockSpec((1, bn), lambda j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j, t: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, vals, idx, bias)
