"""Solution 𝔐 2:4 mask selection — Eq. (12) combo scoring (Pallas kernel).

For every group of 4 columns, score all C(4,2)=6 pruning combinations
with the exact MRP loss (Eq. 12):

  L(p,q) = ½ · w_{p,q} · A⁻¹ · w_{p,q}ᵀ,   A = Hinv[{p,q},{p,q}]

and emit the argmin combination's mask.  The 2×2 inverse is closed-form
(adjugate/det), so the whole thing is branch-free VPU arithmetic — the 6
combos are unrolled at trace time.

Inputs: w tile (br, 4·bg) and the per-group Hinv diagonal blocks packed
as hg (G, 16) (= 4×4 flattened; gathered once per layer by ops.py — it's
O(m) memory vs the O(m²) full Hinv).  Grid (R/br, G/bg).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import NM_COMBOS_24

_COMBO_MASK = np.zeros((6, 4), np.float32)
for _ci, (_p, _q) in enumerate(np.asarray(NM_COMBOS_24)):
    _COMBO_MASK[_ci, _p] = _COMBO_MASK[_ci, _q] = 1.0


def _nm_select_kernel(w_ref, hg_ref, o_ref, *, bg: int):
    br = w_ref.shape[0]
    w = w_ref[...].astype(jnp.float32).reshape(br, bg, 4)
    hg = hg_ref[...].astype(jnp.float32)              # (bg, 16)

    losses = []
    for (p, q) in np.asarray(NM_COMBOS_24):
        app = hg[:, 4 * p + p][None]                  # (1, bg)
        aqq = hg[:, 4 * q + q][None]
        apq = hg[:, 4 * p + q][None]
        wp = w[:, :, p]
        wq = w[:, :, q]
        det = app * aqq - apq * apq
        losses.append(
            0.5 * (wp * wp * aqq - 2.0 * wp * wq * apq + wq * wq * app) / det)
    l6 = jnp.stack(losses, axis=-1)                   # (br, bg, 6)
    best = jnp.argmin(l6, axis=-1)                    # (br, bg)
    # position f is pruned iff the winning combo contains f — unrolled so
    # no constant array is captured (Pallas kernels take refs only).
    combos = np.asarray(NM_COMBOS_24)
    pos_masks = []
    for f in range(4):
        hits = [ci for ci, (p, q) in enumerate(combos) if f in (p, q)]
        m = (best == hits[0])
        for ci in hits[1:]:
            m = m | (best == ci)
        pos_masks.append(m)
    mask = jnp.stack(pos_masks, axis=-1)              # (br, bg, 4) bool
    o_ref[...] = mask.reshape(br, bg * 4).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("br", "bg", "interpret"))
def nm_select(
    w: jax.Array,
    hg: jax.Array,
    *,
    br: int = 128,
    bg: int = 32,
    interpret: bool = False,
) -> jax.Array:
    """w: (R, C) paper orientation, C = 4·G; hg: (G, 16) group Hinv blocks.
    Returns int8 mask (R, C), 1 = pruned (exactly 2 per group of 4)."""
    r, c = w.shape
    g = c // 4
    if c % 4 or hg.shape != (g, 16):
        raise ValueError(f"bad shapes w={w.shape} hg={hg.shape}")
    if r % br or g % bg:
        raise ValueError(f"({r},{g}) not divisible by ({br},{bg})")
    grid = (r // br, g // bg)
    return pl.pallas_call(
        functools.partial(_nm_select_kernel, bg=bg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bg * 4), lambda i, j: (i, j)),
            pl.BlockSpec((bg, 16), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((br, bg * 4), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int8),
        interpret=interpret,
    )(w, hg)
