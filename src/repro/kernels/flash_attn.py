"""Causal flash attention (online softmax) — Pallas TPU kernel.

Used by the 32k prefill shapes: materializing a (32768)² score matrix is
4GB f32 per head — flash attention keeps only (bq, bk) score tiles in
VMEM with running max/sum rescaling (Dao 2022, adapted to TPU: the kv
dimension is the innermost *sequential* grid axis, accumulator + running
stats live in VMEM scratch that persists across kv steps).

Grid (BH, T/bq, T/bk).  Causal: kv tiles entirely above the diagonal are
skipped via @pl.when (their DMA still issues, but no FLOPs — on TPU the
mosaic pipeliner overlaps the dead DMA with live compute).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, bq: int, bk: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                              # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # kv tiles fully above the diagonal contribute nothing — skip
        pl.when((ki * bk) <= (qi * bq + bq - 1))(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "causal", "interpret"))
def flash_attn(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    bq: int = 128,
    bk: int = 128,
    causal: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """q,k,v: (BH, T, D) (heads pre-folded). Returns (BH, T, D) f32."""
    bh, t, d = q.shape
    if t % bq or t % bk:
        raise ValueError(f"T={t} not divisible by ({bq},{bk})")
    scale = 1.0 / math.sqrt(d)
    grid = (bh, t // bq, t // bk)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum l
        ],
        interpret=interpret,
    )(q, k, v)
