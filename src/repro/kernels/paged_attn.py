"""Paged-attention decode — Pallas TPU kernel over block-table pages.

Continuous-batching decode (serve.engine) keeps each request's KV cache
in fixed-size pages scattered across a global pool; a per-request block
table maps logical KV positions to physical pages.  This kernel computes
one decode step of grouped (GQA) attention directly over the paged pool:
the block table rides in as a *scalar-prefetch* operand so each grid
step's K/V page DMA is issued from ``block_tables[b, p]`` — the gather
never materializes a per-request contiguous cache (the jnp oracle in
ref.paged_attn_ref does exactly that, and is the CPU serving path).

Grid (B, KV, P_max); the page axis is the innermost *sequential* axis —
accumulator + running max/sum live in VMEM scratch across page steps
(same online-softmax structure as flash_attn.py).  Pages past a
request's length are skipped via @pl.when (their DMA still issues but
runs no FLOPs; the mosaic pipeliner overlaps it with live compute), and
an idle slot (length 0) computes nothing and emits zeros.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(bt_ref, len_ref, q_ref, *refs, page_size: int,
                       window: Optional[int], scale: float,
                       quantized: bool = False):
    if quantized:
        # int8 KV pages ride with per-row f32 scales (serve/kvpool.py
        # kv_dtype="int8"); dequant happens on the VMEM tile right
        # after load — HBM still moves only the int8 bytes
        k_ref, ks_ref, v_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = refs
    else:
        k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    b = pl.program_id(0)
    p = pl.program_id(2)
    n_pages = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # a page is live when it overlaps the valid key range
    # [max(0, length-window), length) — every live page has >= 1 unmasked
    # key, so the -1e30 mask never produces an all-masked softmax row
    live = p * page_size < length
    if window is not None:
        live &= (p + 1) * page_size > length - window

    @pl.when(live)
    def _compute():
        g = q_ref.shape[2]
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)            # (ps, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)            # (ps, hd)
        if quantized:
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, ps)
        kpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (g, page_size), 1)
        ok = kpos < length
        if window is not None:
            ok &= kpos >= length - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]                               # (G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        pmat = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(pmat, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            pmat, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attn(
    q: jax.Array,                # (B, KV, G, hd)
    k_pages: jax.Array,          # (P, page_size, KV, hd)
    v_pages: jax.Array,          # (P, page_size, KV, hd)
    block_tables: jax.Array,     # (B, P_max) int32 — physical page ids
    lengths: jax.Array,          # (B,) int32 — valid KV entries per request
    *,
    window: Optional[int] = None,
    interpret: bool = False,
    k_scale: Optional[jax.Array] = None,   # (P, page_size, KV) f32
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """One paged GQA decode step. Returns (B, KV, G, hd) f32.

    When ``k_scale``/``v_scale`` are given, k/v_pages are int8 and each
    page tile is dequantized row-wise in VMEM (``int8 * scale``) — the
    scale blocks ride the same block-table prefetch as their pages.
    """
    b, kvh, g, hd = q.shape
    _, page_size, _, _ = k_pages.shape
    p_max = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)
    quantized = k_scale is not None
    page_spec = pl.BlockSpec((1, page_size, 1, hd),
                             lambda bb, kk, pp, bt, ln: (bt[bb, pp], 0, kk, 0))
    scale_spec = pl.BlockSpec((1, page_size, 1),
                              lambda bb, kk, pp, bt, ln: (bt[bb, pp], 0, kk))
    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda bb, kk, pp, bt, ln: (bb, kk, 0, 0)),
        page_spec,
    ]
    operands = [q, k_pages]
    if quantized:
        in_specs.append(scale_spec)
        operands.append(k_scale)
    in_specs.append(page_spec)
    operands.append(v_pages)
    if quantized:
        in_specs.append(scale_spec)
        operands.append(v_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, kvh, p_max),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bb, kk, pp, bt, ln: (bb, kk, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((g, 1), jnp.float32),    # running max m
            pltpu.VMEM((g, 1), jnp.float32),    # running sum l
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, page_size=page_size,
                          window=window, scale=scale, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), jnp.float32),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      *operands)
