"""Public jit'd wrappers around the Pallas kernels.

Dispatch is resolved per call through :func:`dispatch_mode` (no module
globals to mutate — ISSUE-7 api_redesign):

- ``interpret`` auto-detects the backend: on this CPU container every
  kernel runs in interpret mode (Python-level execution of the kernel
  body — bit-faithful to the TPU program structure); on TPU they
  compile to Mosaic.  All wrappers handle padding to tile multiples.
- ``force_pallas`` (env ``JAX_PALLAS_INTERPRET=1`` — the CI tier-1
  kernel step) forces the Pallas kernel BODIES, in interpret mode,
  through every dispatch that would otherwise take a jnp-oracle
  shortcut off-TPU (``paged_attention`` below), so kernels/
  paged_attn.py logic is exercised on CPU-only runners.

Tests and callers that need a specific mode use the
:func:`override_dispatch` context manager instead of monkeypatching:

    with ops.override_dispatch(force_pallas=True):
        ops.paged_attention(...)        # kernel body, interpreted
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attn import flash_attn as _flash
from repro.kernels.paged_attn import paged_attn as _paged_attn
from repro.kernels.hessian_accum import hessian_accum as _hessian
from repro.kernels.nm_select import nm_select as _nm_select
from repro.kernels.nm_spmm import nm_spmm as _nm_spmm
from repro.kernels.nm_spmm import nm_spmm_decode as _nm_spmm_decode


@dataclasses.dataclass(frozen=True)
class DispatchMode:
    """How the wrappers run their kernels right now (immutable —
    replace via :func:`override_dispatch`, never mutate)."""

    interpret: bool       # Pallas interpret mode (off-TPU default)
    force_pallas: bool    # kernel bodies even where a jnp oracle exists


_OVERRIDE: list = []      # override stack (innermost last)


def dispatch_mode() -> DispatchMode:
    """The active kernel dispatch mode: the innermost
    :func:`override_dispatch` if one is active, else resolved from the
    backend and the ``JAX_PALLAS_INTERPRET`` env var at call time."""
    if _OVERRIDE:
        return _OVERRIDE[-1]
    return DispatchMode(
        interpret=jax.default_backend() != "tpu",
        force_pallas=os.environ.get("JAX_PALLAS_INTERPRET", "")
        not in ("", "0"))


@contextlib.contextmanager
def override_dispatch(interpret: Optional[bool] = None,
                      force_pallas: Optional[bool] = None
                      ) -> Iterator[DispatchMode]:
    """Scoped dispatch override (replaces the old pattern of tests
    mutating ``ops.INTERPRET``/``ops.FORCE_PALLAS`` module globals).
    Unspecified fields inherit the currently active mode; overrides
    nest."""
    base = dispatch_mode()
    mode = DispatchMode(
        interpret=base.interpret if interpret is None else interpret,
        force_pallas=(base.force_pallas if force_pallas is None
                      else force_pallas))
    _OVERRIDE.append(mode)
    try:
        yield mode
    finally:
        _OVERRIDE.pop()


def _pad_to(x: jax.Array, mults: Tuple[int, ...]) -> jax.Array:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


# ----------------------------------------------------------------------
def compress_24(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dense 2:4-sparse (K, N) → packed (vals, idx). See ref.compress_24."""
    return ref.compress_24(w)


def nm_matmul(x: jax.Array, vals: jax.Array, idx: jax.Array,
              bias: Optional[jax.Array] = None, *,
              activation: Optional[str] = None,
              out_dtype=None, block: int = 128,
              use_kernel: Optional[bool] = None) -> jax.Array:
    """y = act(x @ w_sparse + bias) for packed 2:4 weights.

    x: (..., K); vals/idx: (K/2, N) → (..., N).  ``bias`` ((N,) or
    (1, N)) and ``activation`` (None | "silu" | "gelu") form the fused
    decode epilogue.

    Dispatch mirrors :func:`paged_attention`: the Pallas kernel on TPU
    (or forced via ``JAX_PALLAS_INTERPRET=1`` / ``override_dispatch``);
    the jnp decompress-oracle otherwise — this wrapper sits inside the
    jitted serve decode burst, where interpret-mode execution would
    dominate the step.  The oracle decompress is an exact inverse of
    :func:`compress_24`, so f32 packed serving is bit-identical to the
    dense path.  On the kernel side, skinny M (≤ ``block`` rows — every
    decode burst) takes the single-M-block :func:`nm_spmm_decode`
    variant with the epilogue fused into the accumulator tile; larger M
    (prefill/calibration shapes) takes the tiled kernel with the
    epilogue applied on the sliced result.
    """
    mode = dispatch_mode()
    if use_kernel is None:
        use_kernel = mode.force_pallas or not mode.interpret
    if not use_kernel:
        y = ref.nm_spmm_ref(x, vals, idx, bias=bias, activation=activation)
        return y.astype(out_dtype or x.dtype)
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = vals.shape[-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    valsp = _pad_to(vals, (block // 2, block))
    idxp = _pad_to(idx, (block // 2, block))
    if m <= block:
        # decode shape: one M block (padded to the f32 sublane tile),
        # bias + activation fused into the kernel epilogue
        b2 = (jnp.zeros((1, n), jnp.float32) if bias is None
              else jnp.reshape(bias, (1, n)).astype(jnp.float32))
        mp = max(8, -(-m // 8) * 8)
        y = _nm_spmm_decode(
            _pad_to(x2, (mp, block)), valsp, idxp, _pad_to(b2, (1, block)),
            bn=block, bk=block, activation=activation,
            interpret=mode.interpret)
        return y[:m, :n].reshape(*lead, n).astype(out_dtype or x.dtype)
    bm = min(block, max(8, m))
    x2p = _pad_to(x2, (bm, block))
    y = _nm_spmm(x2p, valsp, idxp, bm=bm, bn=block, bk=block,
                 interpret=mode.interpret)
    y = y[:m, :n]
    if bias is not None:
        y = y + jnp.reshape(bias, (1, n)).astype(jnp.float32)
    y = ref.activate(y, activation).reshape(*lead, n)
    return y.astype(out_dtype or x.dtype)


def hessian_xxt(x: jax.Array, block: int = 128) -> jax.Array:
    """H = 2·x·xᵀ for x (m, T) via the streaming kernel (f32)."""
    m, t = x.shape
    xp = _pad_to(x, (block, block))
    h = _hessian(xp, bi=block, bj=block, bt=block,
                 interpret=dispatch_mode().interpret)
    return h[:m, :m]


def nm_select_mask(w: jax.Array, hinv: jax.Array,
                   br: int = 128, bg: int = 32) -> jax.Array:
    """Solution 𝔐 2:4 mask (bool, True = pruned) for paper-orientation w.

    Extracts the (G, 4, 4) group diagonal blocks of Hinv host-side-cheap
    (O(m) gather) and runs the combo-scoring kernel.
    """
    r, c = w.shape
    g = c // 4
    cols = (jnp.arange(g) * 4)[:, None] + jnp.arange(4)[None, :]
    hg = hinv[cols[:, :, None], cols[:, None, :]].reshape(g, 16)
    brr = min(br, max(8, r))
    wp = _pad_to(w, (brr, 4 * bg))
    gp = wp.shape[1] // 4
    hgp = _pad_to(hg, (bg, 16))
    # padding groups get identity A (det=1) — harmless, rows sliced off
    if gp > g:
        eye = jnp.tile(jnp.eye(4).reshape(1, 16), (gp - g, 1))
        hgp = hgp.at[g:].set(eye)
    mask = _nm_select(wp, hgp, br=brr, bg=bg,
                      interpret=dispatch_mode().interpret)
    return mask[:r, :c].astype(bool)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array,
                    window: Optional[int] = None,
                    use_kernel: Optional[bool] = None,
                    k_scale: Optional[jax.Array] = None,
                    v_scale: Optional[jax.Array] = None) -> jax.Array:
    """Paged GQA decode attention over block-table pages.

    q: (B, KV, G, hd); k/v_pages: (P, page_size, KV, hd); block_tables:
    (B, P_max) int32; lengths: (B,). Returns (B, KV, G, hd) in v.dtype
    (f32 when ``k_scale``/``v_scale`` engage the int8 KV-page path —
    pages dequantize row-wise at the gather, see serve/kvpool.py).

    Dispatch: the Pallas kernel on TPU (block-table scalar prefetch, no
    gather materialization); the jnp oracle otherwise — unlike the other
    wrappers this does NOT default to interpret mode on CPU, because it
    sits inside the jitted serve decode step and interpret execution
    would dominate the step; ref.paged_attn_ref is the same math and is
    bit-identical to the dense-cache decode path (use_kernel=True forces
    the kernel, under interpret off-TPU — the parity tests, and
    ``dispatch_mode().force_pallas`` — env JAX_PALLAS_INTERPRET=1 or an
    ``override_dispatch(force_pallas=True)`` scope — forces it for
    every default dispatch: the CI kernel-logic step).
    """
    mode = dispatch_mode()
    if use_kernel is None:
        use_kernel = mode.force_pallas or not mode.interpret
    if not use_kernel:
        return ref.paged_attn_ref(q, k_pages, v_pages, block_tables,
                                  lengths, window=window,
                                  k_scale=k_scale, v_scale=v_scale)
    out = _paged_attn(q, k_pages, v_pages, block_tables, lengths,
                      window=window, interpret=mode.interpret,
                      k_scale=k_scale, v_scale=v_scale)
    if k_scale is not None:
        return out                       # dequantized compute — f32 out
    return out.astype(v_pages.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True, bq: int = 128, bk: int = 128
              ) -> jax.Array:
    """Flash attention on (BH, T, D); T padded to tile multiples."""
    bh, t, d = q.shape
    bq = min(bq, t) if t % bq == 0 or t < bq else bq
    tpad = (-t) % max(bq, bk)
    if tpad:
        qp = jnp.pad(q, ((0, 0), (0, tpad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, tpad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, tpad), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    if qp.shape[1] < bq:
        bq = bk = qp.shape[1]
    o = _flash(qp, kp, vp, bq=bq, bk=bk, causal=causal,
               interpret=dispatch_mode().interpret)
    return o[:, :t, :]
