"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

NM_COMBOS_24 = np.array(
    [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)], dtype=np.int32)


# ----------------------------------------------------------------------
# 2:4 compressed format
# ----------------------------------------------------------------------
def compress_24(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dense (K, N) with 2:4 sparsity along K → (vals (K/2,N), idx (K/2,N)).

    Every group of 4 consecutive K-rows holds ≤2 nonzeros per column; the
    two kept entries' in-group positions go to idx (int8, ascending), the
    values to vals.  (Groups with <2 nonzeros pad with zeros at unused
    slots — idx still valid.)
    """
    k, n = w.shape
    assert k % 4 == 0, f"K={k} must divide by 4"
    g = w.reshape(k // 4, 4, n)
    nz = (g != 0)
    # order: nonzeros first (stable by position)
    rank = jnp.cumsum(nz, axis=1) * nz          # 1,2 at kept slots
    pos = jnp.arange(4, dtype=jnp.int32)[None, :, None]
    idx0 = jnp.min(jnp.where(rank == 1, pos, 4), axis=1)
    idx1 = jnp.min(jnp.where(rank == 2, pos, 4), axis=1)
    # groups with <2 nonzeros: point the unused slot at position 0 value 0
    idx0c = jnp.where(idx0 == 4, 0, idx0)
    idx1c = jnp.where(idx1 == 4, 0, idx1)
    v0 = jnp.take_along_axis(g, idx0c[:, None, :], axis=1)[:, 0, :]
    v1 = jnp.take_along_axis(g, idx1c[:, None, :], axis=1)[:, 0, :]
    v0 = jnp.where(idx0 == 4, 0, v0)
    v1 = jnp.where(idx1 == 4, 0, v1)
    vals = jnp.stack([v0, v1], axis=1).reshape(k // 2, n)
    idx = jnp.stack([idx0c, idx1c], axis=1).reshape(k // 2, n).astype(jnp.int8)
    return vals, idx


def decompress_24(vals: jax.Array, idx: jax.Array) -> jax.Array:
    """(K/2, N) pairs → dense (K, N)."""
    k2, n = vals.shape
    g = k2 // 2
    v = vals.reshape(g, 2, n)
    ix = idx.reshape(g, 2, n).astype(jnp.int32)
    r = jnp.arange(4, dtype=jnp.int32)[None, :, None]       # (1,4,1)
    dense = jnp.sum(
        v[:, :, None, :] * (ix[:, :, None, :] == r[:, None, :, :]).astype(
            vals.dtype),
        axis=1)                                             # (g,4,n)
    return dense.reshape(g * 4, n)


def activate(y: jax.Array, activation) -> jax.Array:
    """The decode-epilogue activation: None | "silu" | "gelu".  Shared
    by the fused nm_spmm_decode kernel and the jnp oracle so both sides
    of the dispatch run the identical op sequence."""
    if activation is None:
        return y
    if activation == "silu":
        return jax.nn.silu(y)
    if activation == "gelu":
        return jax.nn.gelu(y)
    raise ValueError(f"unknown epilogue activation {activation!r}")


def nm_spmm_ref(x: jax.Array, vals: jax.Array, idx: jax.Array,
                bias=None, activation=None) -> jax.Array:
    """y = act(x @ decompress(vals, idx) + bias). x: (..., K) → (..., N)
    f32.  The decompress is an exact inverse of :func:`compress_24`, so
    on f32 inputs this is bit-identical to the dense ``x @ w`` — the
    property that lets the serve engine swap packed leaves in without
    perturbing greedy token streams."""
    w = decompress_24(vals, idx)
    y = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return activate(y, activation)


# ----------------------------------------------------------------------
def hessian_accum_ref(x: jax.Array) -> jax.Array:
    """H = 2 · x xᵀ for x (m, T) — f32."""
    x32 = x.astype(jnp.float32)
    return 2.0 * (x32 @ x32.T)


# ----------------------------------------------------------------------
def nm_select_ref(w: jax.Array, hinv: jax.Array) -> jax.Array:
    """Solution 𝔐 2:4 mask via Eq. (12) — reference (loops over combos).

    w: (R, C) paper orientation; hinv: (C, C). Returns bool mask (R, C),
    True = pruned, exactly 2 per group of 4.
    """
    r, c = w.shape
    g = c // 4
    w32 = w.astype(jnp.float32).reshape(r, g, 4)
    cols = (jnp.arange(g) * 4)[:, None] + jnp.arange(4)[None, :]
    hg = hinv[cols[:, :, None], cols[:, None, :]].astype(jnp.float32)  # (g,4,4)
    losses = []
    for (p, q) in np.asarray(NM_COMBOS_24):
        app = hg[:, p, p][None]
        aqq = hg[:, q, q][None]
        apq = hg[:, p, q][None]
        wp = w32[:, :, p]
        wq = w32[:, :, q]
        det = app * aqq - apq * apq
        loss = 0.5 * (wp * wp * aqq - 2 * wp * wq * apq + wq * wq * app) / det
        losses.append(loss)
    losses = jnp.stack(losses, axis=-1)                      # (r,g,6)
    best = jnp.argmin(losses, axis=-1)                       # (r,g)
    combo_mask = np.zeros((6, 4), bool)
    for ci, (p, q) in enumerate(np.asarray(NM_COMBOS_24)):
        combo_mask[ci, p] = combo_mask[ci, q] = True
    mask = jnp.asarray(combo_mask)[best]                     # (r,g,4)
    return mask.reshape(r, c)


# ----------------------------------------------------------------------
def paged_attn_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                   block_tables: jax.Array, lengths: jax.Array,
                   window=None, k_scale=None, v_scale=None) -> jax.Array:
    """Paged GQA decode oracle (and the CPU serving path — jittable).

    q: (B, KV, G, hd); k/v_pages: (P, page_size, KV, hd); block_tables:
    (B, P_max) int32 physical page ids; lengths: (B,) valid KV entries.
    Gathers each request's pages contiguous, then runs exactly the
    einsum/softmax sequence of models.layers._sdpa so paged greedy
    decode is bit-identical to the dense cache path.  Returns
    (B, KV, G, hd) in v.dtype (idle rows, length 0, are garbage — the
    caller masks them).

    ``k_scale``/``v_scale`` (P, page_size, KV) f32 engage the int8
    KV-page path (serve/kvpool.py ``kv_dtype="int8"``): pages are
    dequantized row-wise right after the gather (``int8 * scale``) and
    attention proceeds in f32 exactly as above — output dtype f32.
    """
    b, kvh, g, hd = q.shape
    _, page_size, _, _ = k_pages.shape
    p_max = block_tables.shape[1]
    s_len = p_max * page_size
    k = k_pages[block_tables].reshape(b, s_len, kvh, hd)
    v = v_pages[block_tables].reshape(b, s_len, kvh, hd)
    if k_scale is not None:
        ks = k_scale[block_tables].reshape(b, s_len, kvh)
        vs = v_scale[block_tables].reshape(b, s_len, kvh)
        k = k.astype(jnp.float32) * ks[..., None]
        v = v.astype(jnp.float32) * vs[..., None]
    # the einsum strings (incl. the T=1 dim) mirror layers._sdpa exactly
    # — any other contraction layout lowers to a different f32 reduction
    # order and breaks decode bit-parity with the dense cache
    qg = q[:, None]                                   # (B, 1, KV, G, hd)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    kpos = jnp.arange(s_len, dtype=jnp.int32)[None, :]
    ok = kpos < lengths[:, None]
    if window is not None:
        ok &= kpos >= lengths[:, None] - window
    scores = jnp.where(ok[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out[:, 0]                                  # (B, KV, G, hd)


# ----------------------------------------------------------------------
def flash_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                   causal: bool = True) -> jax.Array:
    """q,k,v: (BH, T, D). Plain softmax attention — f32 output."""
    bh, t, d = q.shape
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32))
