"""Pallas TPU kernels for the pruning framework's compute hot spots.

  nm_spmm        2:4-compressed weight × activation matmul (serving)
  hessian_accum  streaming H = 2·x·xᵀ over calibration tokens (pruning)
  nm_select      Eq. (12) per-group combination scoring → 𝔐 mask (pruning)
  flash_attn     online-softmax causal attention (32k prefill)
  paged_attn     block-table paged GQA decode attention (serve runtime)

Each kernel has a pure-jnp oracle in ref.py and a jit'd public wrapper in
ops.py.  On this CPU container they are validated with interpret=True;
BlockSpecs are sized for TPU v5e VMEM (128-aligned MXU tiles).
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
