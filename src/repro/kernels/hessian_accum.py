"""Streaming calibration Hessian H = 2·x·xᵀ (Pallas TPU kernel).

The pruning engine's hot loop: for every linear layer, all calibration
tokens stream through H += 2 x xᵀ.  x is (m, T) with T ≫ m; one H tile
(bi, bj) stays resident in VMEM while token chunks (bt) stream from HBM —
the classic outer-product accumulation, f32 accumulator, MXU tiles.

Grid (m/bi, m/bj, T/bt), token dim innermost (sequential accumulation).
VMEM: xi (bi,bt) + xj (bj,bt) + acc (bi,bj) f32 ≈ 3·64KB at 128² tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hessian_kernel(xi_ref, xj_ref, o_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xi = xi_ref[...].astype(jnp.float32)          # (bi, bt)
    xj = xj_ref[...].astype(jnp.float32)          # (bj, bt)
    o_ref[...] += 2.0 * jax.lax.dot_general(
        xi, xj, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bt", "interpret"))
def hessian_accum(
    x: jax.Array,
    *,
    bi: int = 128,
    bj: int = 128,
    bt: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """H = 2 · x xᵀ for x (m, T). Returns (m, m) float32."""
    m, t = x.shape
    if m % bi or m % bj or t % bt:
        raise ValueError(f"({m},{t}) not divisible by ({bi},{bj},{bt})")
    grid = (m // bi, m // bj, t // bt)
    return pl.pallas_call(
        _hessian_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bt), lambda i, j, tt: (i, tt)),
            pl.BlockSpec((bj, bt), lambda i, j, tt: (j, tt)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, tt: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=interpret,
    )(x, x)
