"""Data pipeline: synthetic corpora, step-indexed batching, calibration."""

from repro.data.synthetic import MarkovCorpus, zipf_logits
from repro.data.pipeline import DataPipeline, calibration_batches

__all__ = [
    "MarkovCorpus",
    "zipf_logits",
    "DataPipeline",
    "calibration_batches",
]
