"""Synthetic token corpus with learnable structure (offline C4 stand-in).

A per-seed first-order Markov chain over the vocabulary: transition
logits = Zipf unigram bias + a sparse high-probability successor pattern.
Low-entropy enough that a tiny LM's perplexity drops fast, high-entropy
enough that pruning damage is measurable — which is all the paper's
experiments need (EXPERIMENTS.md validates *orderings*, not absolute C4
perplexities; see DESIGN.md §8).

Determinism contract: every batch is a pure function of (seed, stream,
step) via fold_in — restarting a crashed run re-generates the identical
token stream, so checkpoint-resume is bit-exact (tested).
"""

from __future__ import annotations

import functools
import jax
import jax.numpy as jnp

STREAM_TRAIN = 0
STREAM_CALIB = 1
STREAM_EVAL = 2


def zipf_logits(vocab: int, alpha: float = 1.2) -> jax.Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


class MarkovCorpus:
    """First-order Markov token source with Zipf marginals."""

    def __init__(self, vocab: int, seed: int = 0, alpha: float = 1.2,
                 peak: float = 8.0):
        self.vocab = vocab
        self.seed = seed
        key = jax.random.key(seed)
        k1, k2 = jax.random.split(key)
        base = zipf_logits(vocab, alpha)[None, :]            # (1, V)
        # each token gets a few strongly-preferred successors
        succ = jax.random.randint(k1, (vocab, 3), 0, vocab)
        boost = jnp.zeros((vocab, vocab)).at[
            jnp.arange(vocab)[:, None], succ
        ].add(peak)
        noise = 0.5 * jax.random.normal(k2, (vocab, vocab))
        self.trans_logits = base + boost + noise             # (V, V)

    @functools.partial(jax.jit, static_argnames=("self", "batch", "length"))
    def sample(self, key, batch: int, length: int) -> jax.Array:
        """(batch, length) int32 token matrix."""
        k0, kseq = jax.random.split(key)
        t0 = jax.random.categorical(
            k0, jnp.broadcast_to(zipf_logits(self.vocab), (batch, self.vocab)))

        def step(tok, k):
            nxt = jax.random.categorical(k, self.trans_logits[tok])
            return nxt, nxt

        _, toks = jax.lax.scan(step, t0, jax.random.split(kseq, length - 1))
        return jnp.concatenate(
            [t0[None], toks], axis=0).T.astype(jnp.int32)     # (B, L)

    def batch_key(self, stream: int, step: int) -> jax.Array:
        key = jax.random.key(self.seed)
        key = jax.random.fold_in(key, stream)
        return jax.random.fold_in(key, step)

    def batch_at(self, stream: int, step: int, batch: int,
                 length: int) -> jax.Array:
        return self.sample(self.batch_key(stream, step), batch, length)
