"""Step-indexed batching for training / calibration / evaluation.

``DataPipeline.batch_at(step)`` is a pure function of the step index —
the fault-tolerant trainer resumes by simply continuing the step counter
(no iterator state to checkpoint, no data replay drift), and a straggler
-skipped step can be re-assigned deterministically.

When a mesh is provided — explicitly, or resolved from the active
``repro.dist`` context at construction — batches are placed with the
batch dim sharded over the data(+pod) axes (rules: dist.sharding).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.data.synthetic import (
    STREAM_CALIB,
    STREAM_EVAL,
    STREAM_TRAIN,
    MarkovCorpus,
)
from repro.models.base import ArchConfig


class DataPipeline:
    def __init__(
        self,
        cfg: ArchConfig,
        global_batch: int,
        seq_len: int,
        seed: int = 0,
        mesh=None,
        dp_axes: Optional[Sequence[str]] = None,
    ):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.corpus = MarkovCorpus(cfg.vocab_size, seed=seed)
        if mesh is None:
            from repro.dist import current_ctx

            ctx = current_ctx()
            if ctx is not None:
                mesh = ctx.mesh
                if dp_axes is None:
                    dp_axes = ctx.dp_axes
        self.mesh = mesh
        self.dp_axes = tuple(dp_axes) if dp_axes is not None else ("data",)

    # ------------------------------------------------------------------
    def _finish(self, batch: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        if self.mesh is None:
            return batch
        from repro.dist.sharding import batch_sharding

        sh = batch_sharding(self.mesh, self.dp_axes)
        return {k: jax.device_put(v, sh) for k, v in batch.items()}

    def _make(self, stream: int, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        t_text = self.seq_len
        if cfg.frontend is not None and not cfg.encdec:
            t_text = self.seq_len - cfg.frontend_len
        toks = self.corpus.batch_at(stream, step, self.global_batch, t_text)
        batch = {"tokens": toks, "labels": toks}
        if cfg.frontend is not None:
            fkey = jax.random.fold_in(
                self.corpus.batch_key(stream, step), 987)
            batch["frontend_feats"] = 0.25 * jax.random.normal(
                fkey, (self.global_batch, cfg.frontend_len, cfg.frontend_dim),
                jnp.float32).astype(jnp.bfloat16)
        return self._finish(batch)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        return self._make(STREAM_TRAIN, step)

    def eval_batch(self, step: int) -> Dict[str, jax.Array]:
        return self._make(STREAM_EVAL, step)

    def calib_batch(self, idx: int) -> Dict[str, jax.Array]:
        return self._make(STREAM_CALIB, idx)


def calibration_batches(
    cfg: ArchConfig,
    n_samples: int = 128,
    seq_len: int = 128,
    batch: int = 8,
    seed: int = 0,
) -> List[Dict[str, jax.Array]]:
    """The paper's calibration protocol: ``n_samples`` random segments of
    ``seq_len`` tokens (their 128×2048 from C4, scaled to CPU models)."""
    pipe = DataPipeline(cfg, batch, seq_len, seed=seed)
    n_batches = max(1, n_samples // batch)
    return [pipe.calib_batch(i) for i in range(n_batches)]
