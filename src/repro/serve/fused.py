"""Device-resident decode: the fused sample/record/advance step and the
multi-step burst loops the serve engine dispatches between scheduler
events (docs/serving.md).

The PR-3/4 step loop was host-driven: every decode step blocked on a
``device_get`` of the sampled tokens, did per-sequence Python
bookkeeping, and re-uploaded ``tok``/``pos`` vectors — at small batch
the host round-trip, not the pruned matmuls, set the token clock.  This
module moves the whole inner loop on device:

  - :func:`sample_rows` / :func:`sample_batch` — the sampling math
    (greedy argmax, temperature, top-k / top-p nucleus filtering) as
    pure functions.  ``sample_rows`` keys every draw per (request uid,
    generated-token index) — the contract that makes streams
    batch-independent and preemption-recompute bit-exact — and is the
    single implementation behind the fused loop, the host-side prefill
    sample, and the old per-step path's tests.
  - :func:`make_continuous_burst` — a jitted ``lax.while_loop`` over
    the fused step: paged ``decode_step`` + per-(uid, step) sampling +
    EOS / length done-detection + position advance, carrying the
    scheduler state (:func:`init_burst_state`: ``tok``/``pos``/``uid``/
    ``n_tok``/``max_new``/``done`` + a token output ring) as device
    arrays.  The host syncs ONCE per burst, reading back the small
    packed state blob instead of per-step logits.
  - :func:`make_prefill_burst` — the sync-floor fix (ISSUE-6): ONE
    prompt chunk (``LM.prefill_chunk``) fused in front of the same
    K-step decode loop.  A final chunk samples token 0 under the
    per-(uid, 0) key and *activates its slot on device* (tok/pos/uid/
    ring fields set where ``is_final``), so the newly-running request
    decodes in the very same burst — prefill-heavy load no longer
    clamps bursts to K=1, and a mixed chunk+decode interval costs one
    dispatch and one host sync instead of two dispatches at K=1.
  - :func:`make_static_burst` — the static-bucket twin: dense-cache
    decode + batch-keyed sampling + done bookkeeping fused into one
    while_loop (or, when EOS is off and every request shares one
    ``max_new_tokens`` so the early-exit scan could never fire, a plain
    ``fori_loop`` with no done tracking at all).

Token-stream parity is the correctness bar: the fused bodies run the
exact ops of the per-step path (same decode_step, same per-row filter,
same fold_in keys / key splits), so ``steps_per_sync=1`` and
``steps_per_sync=8`` — and the old host loop — emit bit-identical
tokens (tests/test_serve_paged.py fused-parity suite).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------
# sampling (pure functions — shared by fused and host paths)
# ----------------------------------------------------------------------
def filter_logits(row: jax.Array, top_k: Optional[int],
                  top_p: Optional[float]) -> jax.Array:
    """Top-k / top-p (nucleus) filtering of one temperature-scaled logit
    row: filtered-out entries go to -inf.  Pure per-row — the batched
    (vmapped) and solo paths run the identical ops, which is what keeps
    the per-(uid, step) streams batch-independent."""
    v = row.shape[-1]
    if top_k is not None and 0 < top_k < v:
        kth = jax.lax.top_k(row, top_k)[0][-1]
        row = jnp.where(row < kth, -jnp.inf, row)
    if top_p is not None and 0.0 < top_p < 1.0:
        srt = jnp.sort(row)[::-1]                     # descending
        probs = jax.nn.softmax(srt)
        # keep the smallest prefix whose mass reaches top_p (the
        # first token always survives: exclusive cumsum < p)
        keep = (jnp.cumsum(probs) - probs) < top_p
        thr = jnp.min(jnp.where(keep, srt, jnp.inf))
        row = jnp.where(row < thr, -jnp.inf, row)
    return row


def sample_rows(logits: jax.Array, uids: jax.Array, steps: jax.Array,
                base_key, *, temperature: float, top_k: Optional[int],
                top_p: Optional[float]) -> jax.Array:
    """Per-(uid, step)-keyed sampling of every row — the continuous-mode
    draw.  Row ``i`` uses ``fold_in(fold_in(base_key, uids[i]),
    steps[i])``; idle rows draw garbage that is never recorded."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def draw(uid, step, row):
        key = jax.random.fold_in(jax.random.fold_in(base_key, uid), step)
        return jax.random.categorical(
            key, filter_logits(row / temperature, top_k, top_p))

    return jax.vmap(draw)(uids, steps, logits).astype(jnp.int32)


def sample_batch(logits: jax.Array, key, *, temperature: float,
                 top_k: Optional[int], top_p: Optional[float]) -> jax.Array:
    """Static-mode sampling: one batch-keyed draw per step."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    rows = jax.vmap(lambda r: filter_logits(r, top_k, top_p))(
        logits / temperature)
    return jax.random.categorical(key, rows).astype(jnp.int32)


# ----------------------------------------------------------------------
# continuous mode: the fused multi-step burst
# ----------------------------------------------------------------------
def init_burst_state(max_batch: int, ring: int) -> Dict[str, np.ndarray]:
    """Host template of the device-resident scheduler state.  All slots
    start idle (``pos`` -1); the engine fills the running slots before
    each burst.  ``out`` is the token output ring — ``ring`` must be
    ≥ the burst length + 1 so every emitted token has a cell (the +1 is
    the slot a prefill-fused burst activates mid-interval: token 0 from
    the final chunk, then up to a full burst of decode tokens)."""
    return {
        "tok": np.zeros((max_batch,), np.int32),
        "pos": np.full((max_batch,), -1, np.int32),     # -1 = idle slot
        "uid": np.zeros((max_batch,), np.int32),
        "n_tok": np.zeros((max_batch,), np.int32),      # len(seq.tokens)
        "max_new": np.zeros((max_batch,), np.int32),
        "done": np.zeros((max_batch,), bool),           # finished in-burst
        "out": np.zeros((max_batch, ring), np.int32),   # emitted tokens
        "n_out": np.zeros((max_batch,), np.int32),
        "steps_left": np.asarray(0, np.int32),          # dynamic burst len
    }


def _make_decode_loop(model, page_size: int, *, temperature: float,
                      top_k: Optional[int], top_p: Optional[float],
                      eos: int):
    """The K-step fused decode ``while_loop`` — the single body behind
    :func:`make_continuous_burst` and :func:`make_prefill_burst`.

    ``loop(params, kv, tables, state, base_key) -> (kv, state)`` runs up
    to ``state["steps_left"]`` fused decode steps (early-exiting when
    every slot goes idle).  Per step: ``decode_step(paged=...)`` writes
    this token's KV / advances the state rows and yields logits;
    :func:`sample_rows` draws the next token under the per-(uid, step)
    key; the token is recorded into the output ring; EOS / ``max_new``
    mark the slot done (``pos`` frozen to -1 — its remaining burst
    steps treat it idle, exactly like a retired slot awaiting
    re-admission); live slots advance ``pos``."""

    def loop(params, kv, tables, state, base_key):
        def cond(carry):
            _, st = carry
            return (st["steps_left"] > 0) & jnp.any(st["pos"] >= 0)

        def body(carry):
            kv, st = carry
            active = st["pos"] >= 0
            logits, kv = model.decode_step(
                params, st["tok"], kv, st["pos"],
                paged={"block_tables": tables}, page_size=page_size)
            sampled = sample_rows(
                logits, st["uid"], st["n_tok"], base_key,
                temperature=temperature, top_k=top_k, top_p=top_p)
            rows = jnp.arange(sampled.shape[0])
            cell = st["out"][rows, st["n_out"]]
            out = st["out"].at[rows, st["n_out"]].set(
                jnp.where(active, sampled, cell))
            n_tok = st["n_tok"] + active.astype(jnp.int32)
            newly_done = active & ((sampled == eos) | (n_tok >= st["max_new"]))
            st = {
                "tok": jnp.where(active, sampled, st["tok"]),
                "pos": jnp.where(newly_done, -1,
                                 jnp.where(active, st["pos"] + 1, st["pos"])),
                "uid": st["uid"],
                "n_tok": n_tok,
                "max_new": st["max_new"],
                "done": st["done"] | newly_done,
                "out": out,
                "n_out": st["n_out"] + active.astype(jnp.int32),
                "steps_left": st["steps_left"] - 1,
            }
            return kv, st

        return jax.lax.while_loop(cond, body, (kv, state))

    return loop


def _with_host_hook(jitted, host_hook):
    """Wrap a jitted burst with a host-side pre-dispatch hook — the
    fault-injection seam (ISSUE-10, serve.faults): the hook runs on the
    host BEFORE the device dispatch, where it can stall (slow_burst) or
    raise (engine_step) without ever entering a traced body.  None (the
    default everywhere outside chaos runs) keeps the bare jitted
    callable — zero overhead."""
    if host_hook is None:
        return jitted

    def burst(*args):
        host_hook()
        return jitted(*args)

    return burst


def make_continuous_burst(model, page_size: int, *, temperature: float,
                          top_k: Optional[int], top_p: Optional[float],
                          eos_id: Optional[int], host_hook=None):
    """Build the jitted K-step continuous-decode burst.

    ``burst(params, kv, tables, state, base_key) -> (kv, state)`` runs
    up to ``state["steps_left"]`` fused decode steps entirely on device
    (early-exiting when every slot goes idle), donating the paged cache.
    The burst length is a *dynamic* field of the state blob, so one
    compiled body serves every ``steps_per_sync`` setting — which is
    also what makes K=1 and K=8 token streams trivially bit-identical.
    The host retires done slots at the next sync (see
    :func:`_make_decode_loop` for the per-step semantics).
    """
    eos = -1 if eos_id is None else int(eos_id)   # -1 never matches a token
    loop = _make_decode_loop(model, page_size, temperature=temperature,
                             top_k=top_k, top_p=top_p, eos=eos)
    return _with_host_hook(jax.jit(loop, donate_argnums=(1,)), host_hook)


def make_prefill_burst(model, page_size: int, chunk_size: int, *,
                       temperature: float, top_k: Optional[int],
                       top_p: Optional[float], eos_id: Optional[int],
                       host_hook=None):
    """Build the jitted prefill-chunk + K-step decode burst — the
    sync-floor fix.

    ``pburst(params, kv, tables, state, base_key, p) -> (kv, state)``
    feeds ONE fixed-size chunk of one request's prompt through
    ``LM.prefill_chunk`` and then runs the same fused decode loop as
    :func:`make_continuous_burst`, all in one dispatch / one host sync.
    ``p`` carries the chunk: ``tokens`` (1, C), scalars ``start`` /
    ``length`` / ``slot`` / ``uid`` / ``max_new``, and ``pos0`` — the
    activation write position (the prompt length), or -1 when the host
    could not map a page for the slot's first decode write (the slot
    then activates *frozen*: token 0 is still recorded, decode waits
    for the next sync's capacity pass, exactly like per-step mode).

    On the FINAL chunk (``start + C >= length``, decided on device) the
    slot is activated in the state blob: token 0 is sampled from the
    chunk's last-position logits under the per-(uid, 0) key — the very
    same draw the host-side path made — recorded into the output ring,
    and the slot's ``tok``/``pos``/``uid``/``n_tok``/``max_new`` fields
    are set so the decode loop picks the request up on its first
    iteration.  EOS / ``max_new <= 1`` on token 0 mark the slot done
    immediately.  Non-final chunks touch no state and the decode loop
    serves the already-running slots for the full burst — prefill-heavy
    load no longer clamps bursts to K=1.
    """
    eos = -1 if eos_id is None else int(eos_id)
    loop = _make_decode_loop(model, page_size, temperature=temperature,
                             top_k=top_k, top_p=top_p, eos=eos)

    def pburst(params, kv, tables, state, base_key, p):
        slot = p["slot"]
        bt = jax.lax.dynamic_slice_in_dim(tables, slot, 1, axis=0)
        logits, kv = model.prefill_chunk(
            params, {"tokens": p["tokens"]}, kv, p["start"], p["length"],
            slot, bt, page_size=page_size)
        # token 0: the final chunk's last-position logits, drawn under
        # the per-(uid, step=0) key — sample_rows is the single
        # implementation shared with the decode loop and the old
        # host-side draw (garbage on non-final chunks, never recorded)
        tok0 = sample_rows(
            logits, jnp.reshape(p["uid"], (1,)), jnp.zeros((1,), jnp.int32),
            base_key, temperature=temperature, top_k=top_k, top_p=top_p)[0]
        is_final = p["start"] + chunk_size >= p["length"]
        done0 = (tok0 == eos) | (p["max_new"] <= 1)

        def act(arr, val):
            return arr.at[slot].set(
                jnp.where(is_final, val, arr[slot]).astype(arr.dtype))

        state = dict(state)
        state["tok"] = act(state["tok"], tok0)
        state["pos"] = act(state["pos"], jnp.where(done0, -1, p["pos0"]))
        state["uid"] = act(state["uid"], p["uid"])
        state["n_tok"] = act(state["n_tok"], 1)
        state["max_new"] = act(state["max_new"], p["max_new"])
        state["done"] = act(state["done"], done0)
        state["out"] = state["out"].at[slot, 0].set(
            jnp.where(is_final, tok0, state["out"][slot, 0]))
        state["n_out"] = act(state["n_out"], 1)
        return loop(params, kv, tables, state, base_key)

    return _with_host_hook(jax.jit(pburst, donate_argnums=(1,)),
                           host_hook)


# ----------------------------------------------------------------------
# static mode: the fused bucket loop
# ----------------------------------------------------------------------
def make_static_burst(model, *, temperature: float, top_k: Optional[int],
                      top_p: Optional[float], eos_id: Optional[int],
                      early_exit: bool):
    """Build the jitted static-bucket decode loop.

    ``burst(params, cache, logits, key, max_new, pos0) ->
    (out, n_emitted, steps_run)`` consumes the bucket's prefill logits
    and runs the whole sample/record/advance loop on device — the host
    syncs once per bucket instead of once per step.  ``out`` width (the
    bucket's max ``max_new_tokens``) fixes the trip count.

    ``early_exit=False`` is the satellite fast path for buckets where
    the done scan can never fire early (``eos_id is None`` and every
    request shares one ``max_new_tokens``): a plain ``fori_loop`` with
    no done/emit bookkeeping at all.  Both variants replay the host
    loop's exact op and ``jax.random.split`` sequence, so tokens are
    unchanged.
    """
    eos = -1 if eos_id is None else int(eos_id)

    def step_sample(logits, key):
        key, sk = jax.random.split(key)
        tok = sample_batch(logits, sk, temperature=temperature,
                           top_k=top_k, top_p=top_p)
        return tok, key

    if not early_exit:
        # fori variant: no done scan, no emit masks, no n_emitted — the
        # early exit could never fire, so none of that bookkeeping runs
        def fori(params, cache, logits, key, pos0, width):
            b = logits.shape[0]

            def body(i, carry):
                cache, logits, key, out = carry
                tok, key = step_sample(logits, key)
                out = out.at[:, i].set(tok)
                logits, cache = model.decode_step(params, tok, cache,
                                                  pos0 + i)
                return cache, logits, key, out

            out = jnp.zeros((b, width), jnp.int32)
            _, _, _, out = jax.lax.fori_loop(0, width, body,
                                             (cache, logits, key, out))
            return out

        # no donation: the bucket cache dies with the loop (it is not an
        # output, so a donated buffer would be unusable anyway)
        jitted = jax.jit(fori, static_argnums=(5,))

        def call_fori(params, cache, logits, key, max_new_arr, pos0, width):
            width = int(width)
            out = jitted(params, cache, logits, key,
                         jnp.asarray(pos0, jnp.int32), width)
            b = logits.shape[0]
            return (out, np.full((b,), width, np.int32), width)

        return call_fori

    def loop(params, cache, logits, key, max_new, pos0, width):
        b = logits.shape[0]

        def cond(carry):
            _, _, _, st = carry
            return (st["step"] < width) & ~jnp.all(st["done"])

        def body(carry):
            cache, logits, key, st = carry
            tok, key = step_sample(logits, key)
            step = st["step"]
            emit = (~st["done"]) & (step < max_new)
            out = st["out"].at[:, step].set(
                jnp.where(emit, tok, st["out"][:, step]))
            done = st["done"] | (emit & (tok == eos)) | (step >= max_new)
            logits, cache = model.decode_step(params, tok, cache,
                                              pos0 + step)
            st = {"out": out, "done": done,
                  "n_emitted": st["n_emitted"] + emit.astype(jnp.int32),
                  "step": step + 1}
            return cache, logits, key, st

        st0 = {"out": jnp.zeros((b, width), jnp.int32),
               "done": jnp.zeros((b,), bool),
               "n_emitted": jnp.zeros((b,), jnp.int32),
               "step": jnp.asarray(0, jnp.int32)}
        _, _, _, st = jax.lax.while_loop(cond, body,
                                         (cache, logits, key, st0))
        return st["out"], st["n_emitted"], st["step"]

    jitted = jax.jit(loop, static_argnums=(6,))

    def call_while(params, cache, logits, key, max_new_arr, pos0, width):
        out, n_emitted, steps = jitted(
            params, cache, logits, key,
            jnp.asarray(max_new_arr, jnp.int32),
            jnp.asarray(pos0, jnp.int32), int(width))
        return out, n_emitted, steps

    return call_while
