"""2:4-sparse weight packing for serving (the TPU 2:4 payoff).

After N:M pruning, matrices are 50% zeros in every 4-row group along the
input dim — exactly the layout ``kernels.compress_24`` packs.  Packed
leaves become {"vals": (K/2, N), "idx": (K/2, N) int8}; models.layers.
linear dispatches them to the nm_spmm kernel transparently, so the SAME
model code serves dense or sparse checkpoints.

Weight bytes: K·N·2B → K/2·N·(2+1)B = 0.75× … with idx packed to 2 bits
on real TPU (int8 here for interpret-mode clarity) → 0.5625×; decode-time
weight traffic drops accordingly (EXPERIMENTS.md §Perf quantifies it).
"""

from __future__ import annotations

import re
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

# matmuls worth packing by default: the big FFN + attention projections
DEFAULT_SPARSE_PATTERNS = (
    r"(mlp|moe/shared)/(wi|wg|wo)$",
    r"attn/(wq|wk|wv|wo)$",
)


@jax.jit
def _groups_24(w: jax.Array) -> jax.Array:
    """≤2 nonzeros in every 4-row group along the input dim → scalar
    bool, reduced ON DEVICE (one trace per leaf shape)."""
    k, n = w.shape[-2], w.shape[-1]
    g = w.reshape(*w.shape[:-2], k // 4, 4, n)
    return jnp.all((g != 0).sum(axis=-2) <= 2)


def _is_24_sparse(w) -> bool:
    """2:4 along the input dim — 2-D (K, N) or layer-stacked (L, K, N).

    The check is a jitted device reduction fetching only the scalar
    verdict — sparsifying a large checkpoint never pulls candidate
    weight matrices through host memory (the old ``device_get``-then-
    numpy scan serialized every leaf over the wire)."""
    if w.ndim not in (2, 3) or w.shape[-2] % 4:
        return False
    return bool(jax.device_get(_groups_24(jnp.asarray(w))))


def sparsify_params(
    params: Any,
    patterns: Sequence[str] = DEFAULT_SPARSE_PATTERNS,
    verify: bool = True,
) -> Any:
    """Pack every matching 2:4-sparse leaf. Non-matching / non-2:4 leaves
    pass through unchanged (so a half-pruned model still serves).

    Layer-stacked leaves (L, K, N) pack to stacked {"vals": (L, K/2, N),
    "idx": …} — the scan's tree-slice then yields per-layer packed dicts
    that models.layers.linear dispatches to the nm_spmm kernel."""
    regs = [re.compile(p) for p in patterns]
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = []
    for keypath, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        match = any(r.search(path) for r in regs)
        if match and (not verify or _is_24_sparse(leaf)):
            leaves.append(pack_24(leaf))
        else:
            leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def pack_24(leaf: jax.Array) -> dict:
    """One dense 2:4 leaf (K, N) or layer-stacked (L, K, N) → the packed
    {"vals", "idx"} dict models.layers.linear dispatches on."""
    if leaf.ndim == 3:
        vals, idx = jax.vmap(kops.compress_24)(jnp.asarray(leaf))
    else:
        vals, idx = kops.compress_24(leaf)
    return {"vals": vals, "idx": idx}


def is_packed(leaf) -> bool:
    """True for a pack_24 output (the dict layout linear dispatches on)."""
    return isinstance(leaf, dict) and set(leaf) == {"vals", "idx"}


def count_packed(params: Any) -> int:
    """Number of packed {"vals","idx"} leaves in a param tree (the
    engine's load-time sparse-detection summary + the obs gauge)."""
    n = 0

    def visit(node):
        nonlocal n
        if is_packed(node):
            n += 1
            return
        if isinstance(node, dict):
            for v in node.values():
                visit(v)

    visit(params)
    return n


def compressed_param_tree(
    params: Any,
    patterns: Sequence[str] = DEFAULT_SPARSE_PATTERNS,
) -> Any:
    """The serve-engine load hook: detect 2:4 leaves ONCE and return the
    tree with every such leaf packed, so HBM holds only (vals, idx).

    Idempotent — already-packed {"vals","idx"} dicts pass through
    untouched (a checkpoint pre-packed by :func:`sparsify_params`, or a
    re-entrant call), dense leaves that match ``patterns`` AND verify as
    2:4 get packed, and everything else (biases, norms, embeddings,
    non-2:4 matmuls of an unpruned model) is returned as-is.  The
    decompress in kernels.ref is an exact inverse of the pack, so f32
    token streams are bit-identical either way."""
    regs = [re.compile(p) for p in patterns]

    # walk dict nodes by hand: packed leaves are themselves dicts, so a
    # tree_map would descend into them and re-pack the vals
    def walk(node, path):
        if is_packed(node):
            return node
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}" if path else str(k))
                    for k, v in node.items()}
        if any(r.search(path) for r in regs) and _is_24_sparse(node):
            return pack_24(node)
        return node

    return walk(params, "")
