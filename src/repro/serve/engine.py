"""Serving engine: continuous batching over a paged KV pool.

Default mode ``"continuous"`` (docs/serving.md) runs a step loop over
serve.scheduler: requests join the running batch the moment a slot and
prompt pages are free (one paged prefill each), every decode step
advances *all* running requests one token against the shared page pool
(kernels.paged_attn / its jnp oracle), and a request retiring at EOS or
``max_new_tokens`` returns its slot and pages the same step — no decode
is ever burned into a scrap position.  When the pool runs dry the
youngest request is preempted (recompute-style) and re-queued.

``mode="static"`` is the legacy escape hatch (PR 2's ``pipeline="off"``
pattern): requests bucketed by prompt length, one batched prefill + a
decode loop per bucket, finished requests decoding into scrap until the
whole bucket drains.  Archs the paged path can't serve (enc-dec,
modality frontends, recurrent-state mixers) fall back to it
automatically.

Both paths are greedy-token-identical: paged attention is bit-equal to
the dense cache math (kernels.ref.paged_attn_ref), and sampling is keyed
per (request uid, step) in continuous mode so results are independent of
batch composition and survive preemption-recompute.

On a mesh — passed explicitly or resolved from the active ``repro.dist``
context — params are sharded by dist.sharding rules (tensor-parallel
resident, no FSDP: serving re-reads weights every step), the paged pool
is placed by the paged cache rules (pages replicated over data, KV heads
over ``model``), and static-bucket batches are placed over the data axes
when they divide.  Without a mesh everything stays single-device.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LM


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (L,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray                   # generated tokens (≤ max_new)
    prompt_len: int
    decode_steps: int = 0                # sampling opportunities the
    #                                      request's slot was live for
    preemptions: int = 0                 # times recomputed (continuous)

    @property
    def utilization(self) -> float:
        """Emitted tokens / slot-steps occupied: 1.0 means every step
        the request held a slot produced a token; static bucketing
        drops it by whatever was burned into scrap positions (and
        continuous preemption by the recomputed prefix)."""
        if self.decode_steps <= 0:
            return 0.0
        return len(self.tokens) / self.decode_steps


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        max_batch: int = 8,
        max_len: int = 256,
        eos_id: Optional[int] = None,
        temperature: float = 0.0,
        extra_batch: Optional[Dict[str, jax.Array]] = None,
        mesh=None,
        mode: str = "continuous",
        page_size: int = 16,
        num_pages: Optional[int] = None,
    ):
        from repro.dist import current_ctx, dp_axes_of, shard_params

        if mode not in ("continuous", "static"):
            raise ValueError(f"unknown serve mode {mode!r}")
        self.model = model
        if mesh is None:
            ctx = current_ctx()
            mesh = ctx.mesh if ctx is not None else None
        self.mesh = mesh
        self.dp_axes = dp_axes_of(mesh) if mesh is not None else ()
        self._dp = 1
        self._batch_sharding = None
        if self.dp_axes:
            from repro.dist import batch_sharding

            for a in self.dp_axes:
                self._dp *= mesh.shape[a]
            self._batch_sharding = batch_sharding(mesh, self.dp_axes)
        # resident serving: tensor-parallel only (fsdp_axes=()) — an FSDP
        # all-gather per decode step would dominate the wire.  head_dim
        # keeps whole heads per model shard (rope-safe, see param_specs)
        self.params = (shard_params(params, mesh, fsdp_axes=(),
                                    head_dim=model.cfg.hd)
                       if mesh is not None else params)
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.extra_batch = extra_batch or {}
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))

        cfg = model.cfg
        # MoE is excluded: expert-capacity dropping makes each row's
        # logits depend on batch composition, which breaks the greedy
        # parity and bit-exact preemption-recompute guarantees below
        paged_ok = (not cfg.encdec and cfg.frontend is None
                    and not self.extra_batch and cfg.moe is None
                    and all(k in ("attn", "attn_local")
                            for k in (*cfg.prefix, *cfg.period)))
        self.mode = mode if paged_ok else "static"
        self.pool = None
        if self.mode == "continuous":
            from repro.serve.kvpool import PagedKVPool

            self.page_size = page_size
            if num_pages is None:
                # same token capacity as the dense static cache, + scrap
                num_pages = max_batch * (-(-max_len // page_size)) + 1
            self.pool = PagedKVPool(
                model, num_pages=num_pages, page_size=page_size,
                max_slots=max_batch, max_len=max_len, mesh=mesh)
            self._decode_paged = jax.jit(
                functools.partial(model.decode_step, page_size=page_size),
                donate_argnums=(2,))
            self._prefill_paged = jax.jit(
                functools.partial(model.prefill_paged, page_size=page_size),
                donate_argnums=(2,))

    def _place_batch(self, batch: Dict[str, jax.Array]
                     ) -> Dict[str, jax.Array]:
        """Shard a bucket's batch over the data axes when it divides."""
        if self._batch_sharding is None:
            return batch
        b = next(iter(batch.values())).shape[0]
        if b % self._dp:
            return batch
        return {k: jax.device_put(v, self._batch_sharding)
                for k, v in batch.items()}

    # ------------------------------------------------------------------
    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature).astype(jnp.int32)

    def _pos_offset(self) -> int:
        cfg = self.model.cfg
        if cfg.frontend is not None and not cfg.encdec:
            return cfg.frontend_len
        return 0

    def _run_bucket(self, reqs: List[Request], key) -> List[Result]:
        b = len(reqs)
        plen = len(reqs[0].prompt)
        off = self._pos_offset()
        max_new = max(r.max_new_tokens for r in reqs)
        assert off + plen + max_new <= self.max_len, "bucket exceeds max_len"

        toks = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        batch = {"tokens": toks}
        for k, v in self.extra_batch.items():
            batch[k] = v[:b] if v.shape[0] >= b else jnp.broadcast_to(
                v[:1], (b, *v.shape[1:]))
        batch = self._place_batch(batch)
        cache = self.model.init_cache(b, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)

        out = np.zeros((b, max_new), np.int32)
        done = np.zeros((b,), bool)
        n_emitted = np.zeros((b,), np.int32)
        steps_run = 0
        tok = None
        for step in range(max_new):
            key, sk = jax.random.split(key)
            tok = self._sample(logits, sk)
            tok_np = np.asarray(jax.device_get(tok))
            steps_run = step + 1
            for i in range(b):
                if not done[i] and step < reqs[i].max_new_tokens:
                    out[i, step] = tok_np[i]
                    n_emitted[i] += 1
                    if self.eos_id is not None and tok_np[i] == self.eos_id:
                        done[i] = True
                elif step >= reqs[i].max_new_tokens:
                    done[i] = True
            if done.all():
                break
            pos = jnp.asarray(off + plen + step, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)

        # every request occupies its slot for the whole bucket run —
        # the difference vs n_emitted is the scrap-position waste that
        # continuous batching recovers
        return [
            Result(uid=r.uid, tokens=out[i, :n_emitted[i]], prompt_len=plen,
                   decode_steps=steps_run)
            for i, r in enumerate(reqs)
        ]

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------
    def _sample_seq(self, logits_row: jax.Array, seq, base_key) -> int:
        """Sample one token for one sequence. Temperature sampling is
        keyed per (uid, step): independent of batch composition, and a
        preempted request's recompute replays the identical stream."""
        if self.temperature <= 0.0:
            return int(jnp.argmax(logits_row))
        key = jax.random.fold_in(
            jax.random.fold_in(base_key, seq.req.uid), len(seq.tokens))
        return int(jax.random.categorical(
            key, logits_row / self.temperature))

    def _sample_running(self, logits, running, base_key) -> np.ndarray:
        """One batched sample for every running slot (single device
        round-trip per step).  The vmapped per-row (uid, step) keys draw
        the same stream as :meth:`_sample_seq` row by row."""
        if self.temperature <= 0.0:
            return np.asarray(jax.device_get(
                jnp.argmax(logits, axis=-1).astype(jnp.int32)))[
                    [seq.slot for seq in running]]
        rows = logits[jnp.asarray([seq.slot for seq in running])]
        uids = jnp.asarray([seq.req.uid for seq in running], jnp.int32)
        steps = jnp.asarray([len(seq.tokens) for seq in running], jnp.int32)

        def draw(uid, step, row):
            key = jax.random.fold_in(jax.random.fold_in(base_key, uid), step)
            return jax.random.categorical(key, row / self.temperature)

        return np.asarray(jax.device_get(
            jax.vmap(draw)(uids, steps, rows).astype(jnp.int32)))

    def _record(self, seq, tok: int, sched) -> None:
        seq.tokens.append(tok)
        done = (len(seq.tokens) >= seq.req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id))
        if done:
            sched.finish(seq)

    def _generate_continuous(self, requests: Sequence[Request], seed: int
                             ) -> List[Result]:
        from repro.serve.scheduler import Scheduler

        pool = self.pool
        pool.reset()
        sched = Scheduler(pool, self.max_batch)
        seqs = []
        for r in requests:
            if len(r.prompt) + r.max_new_tokens > self.max_len:
                raise ValueError(f"request {r.uid} exceeds max_len")
            seqs.append(sched.submit(r))
        base_key = jax.random.key(seed)
        ps = self.page_size

        while sched.has_work():
            # 1) join-at-prefill: new requests take free slots/pages now
            for seq in sched.admit():
                if seq.req.max_new_tokens <= 0:   # nothing to emit
                    sched.finish(seq)
                    continue
                plen = len(seq.req.prompt)
                tpad = -(-plen // ps) * ps
                toks = np.zeros((1, tpad), np.int32)
                toks[0, :plen] = seq.req.prompt
                bt = jnp.asarray(pool.block_tables[seq.slot][None])
                logits, pool.kv = self._prefill_paged(
                    self.params, {"tokens": jnp.asarray(toks)}, pool.kv,
                    lengths=jnp.asarray([plen], jnp.int32), block_tables=bt)
                seq.n_written = plen
                seq.occupied_steps += 1
                self._record(seq, self._sample_seq(logits[0], seq, base_key),
                             sched)
            if not sched.running:
                continue
            # 2) extend block tables for this step's writes (may preempt)
            sched.ensure_decode_capacity()
            running = list(sched.running)
            if not running:
                continue
            # 3) one decode step over every running slot
            tok = np.zeros((self.max_batch,), np.int32)
            pos = np.full((self.max_batch,), -1, np.int32)
            for seq in running:
                tok[seq.slot] = seq.tokens[-1]
                pos[seq.slot] = seq.n_written
            logits, pool.kv = self._decode_paged(
                self.params, jnp.asarray(tok), pool.kv, jnp.asarray(pos),
                paged={"block_tables": pool.tables_device()})
            sampled = self._sample_running(logits, running, base_key)
            # 4) advance / retire
            for i, seq in enumerate(running):
                seq.n_written += 1
                seq.occupied_steps += 1
                self._record(seq, int(sampled[i]), sched)

        return sorted(
            (Result(uid=s.req.uid,
                    tokens=np.asarray(s.tokens, np.int32),
                    prompt_len=len(s.req.prompt),
                    decode_steps=s.occupied_steps,
                    preemptions=s.preemptions)
             for s in seqs),
            key=lambda r: r.uid)

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request], seed: int = 0
                 ) -> List[Result]:
        """Serve a set of requests (continuous batching; static mode
        buckets by prompt length)."""
        if self.mode == "continuous":
            return self._generate_continuous(requests, seed)
        buckets: Dict[int, List[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        results: List[Result] = []
        key = jax.random.key(seed)
        for plen in sorted(buckets):
            bucket = buckets[plen]
            for i in range(0, len(bucket), self.max_batch):
                key, bk = jax.random.split(key)
                results.extend(self._run_bucket(
                    bucket[i:i + self.max_batch], bk))
        return sorted(results, key=lambda r: r.uid)
