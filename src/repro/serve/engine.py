"""Serving engine: continuous batching over a paged serve cache.

Default mode ``"continuous"`` (docs/serving.md) runs a step loop over
serve.scheduler: requests join the running batch the moment a slot and
prompt pages are free, their prompts stream in as fixed-size token
chunks (one jitted ``prefill_chunk`` shape, interleaved with everyone
else's decode — no head-of-line blocking from long prompts), every
decode step advances *all* running requests one token against the
shared page pool (kernels.paged_attn / its jnp oracle) and the
slot-recycled recurrent-state pool (Mamba/xLSTM/hybrid mixers,
serve.kvpool.StatePool), and a request retiring at EOS or
``max_new_tokens`` returns its slot and pages the same step — no decode
is ever burned into a scrap position.  When the pool runs dry the
youngest request is preempted (recompute-style) and re-queued.

``mode="static"`` is the legacy escape hatch (PR 2's ``pipeline="off"``
pattern): requests bucketed by prompt length, one batched prefill + a
decode loop per bucket, finished requests decoding into scrap until the
whole bucket drains.  Archs the paged path can't serve (enc-dec,
modality frontends, MoE — expert-capacity dropping makes logits
batch-dependent) fall back to it automatically.

Both paths are greedy-token-identical: paged attention is bit-equal to
the dense cache math (kernels.ref.paged_attn_ref), recurrent-state
chunked prefill is the same recurrence with a different (tested)
reduction tree, and sampling — greedy, temperature, top-k, top-p — is
keyed per (request uid, step) in continuous mode so results are
independent of batch composition and survive preemption-recompute.

On a mesh — passed explicitly or resolved from the active ``repro.dist``
context — params are sharded by dist.sharding rules (tensor-parallel
resident, no FSDP: serving re-reads weights every step), the paged pool
is placed by the paged cache rules (pages/slots replicated over data,
widths over ``model`` on head-aligned splits), and static-bucket batches
are placed over the data axes when they divide.  Without a mesh
everything stays single-device.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LM

# every mixer the paged runtime serves: attention (KV pages) plus the
# recurrent kinds (slot-pooled state — the canonical list lives on LM,
# which init_paged_cache validates against)
PAGED_KINDS = ("attn", "attn_local", *LM.STATE_KINDS)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (L,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray                   # generated tokens (≤ max_new)
    prompt_len: int
    decode_steps: int = 0                # steps the request's slot was
    #                                      live for (chunks + decodes)
    preemptions: int = 0                 # times recomputed (continuous)

    @property
    def utilization(self) -> float:
        """Emitted tokens / slot-steps occupied: 1.0 means every step
        the request held a slot produced a token; static bucketing
        drops it by whatever was burned into scrap positions (and
        continuous mode by multi-chunk prefills and the recomputed
        prefix after a preemption)."""
        if self.decode_steps <= 0:
            return 0.0
        return len(self.tokens) / self.decode_steps


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        max_batch: int = 8,
        max_len: int = 256,
        eos_id: Optional[int] = None,
        temperature: float = 0.0,
        top_k: Optional[int] = None,
        top_p: Optional[float] = None,
        extra_batch: Optional[Dict[str, jax.Array]] = None,
        mesh=None,
        mode: str = "continuous",
        page_size: int = 16,
        num_pages: Optional[int] = None,
        prefill_chunk: int = 32,
    ):
        from repro.dist import current_ctx, dp_axes_of, shard_params

        if mode not in ("continuous", "static"):
            raise ValueError(f"unknown serve mode {mode!r}")
        self.model = model
        if mesh is None:
            ctx = current_ctx()
            mesh = ctx.mesh if ctx is not None else None
        self.mesh = mesh
        self.dp_axes = dp_axes_of(mesh) if mesh is not None else ()
        self._dp = 1
        self._batch_sharding = None
        if self.dp_axes:
            from repro.dist import batch_sharding

            for a in self.dp_axes:
                self._dp *= mesh.shape[a]
            self._batch_sharding = batch_sharding(mesh, self.dp_axes)
        # resident serving: tensor-parallel only (fsdp_axes=()) — an FSDP
        # all-gather per decode step would dominate the wire.  head_dim
        # keeps whole heads per model shard (rope-safe, see param_specs)
        self.params = (shard_params(params, mesh, fsdp_axes=(),
                                    head_dim=model.cfg.hd)
                       if mesh is not None else params)
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.extra_batch = extra_batch or {}
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))

        cfg = model.cfg
        # MoE is excluded: expert-capacity dropping makes each row's
        # logits depend on batch composition, which breaks the greedy
        # parity and bit-exact preemption-recompute guarantees below
        paged_ok = (not cfg.encdec and cfg.frontend is None
                    and not self.extra_batch and cfg.moe is None
                    and all(k in PAGED_KINDS
                            for k in (*cfg.prefix, *cfg.period)))
        self.mode = mode if paged_ok else "static"
        self.pool = None
        self.state_pool = None
        if self.mode == "continuous":
            from repro.serve.kvpool import PagedKVPool, StatePool

            self.page_size = page_size
            self.chunk_size = prefill_chunk
            if num_pages is None:
                # same token capacity as the dense static cache, + scrap
                num_pages = max_batch * (-(-max_len // page_size)) + 1
            self.pool = PagedKVPool(
                model, num_pages=num_pages, page_size=page_size,
                max_slots=max_batch, max_len=max_len, mesh=mesh)
            state = StatePool(model, max_slots=max_batch)
            self.state_pool = state if state.has_state else None
            self._decode_paged = jax.jit(
                functools.partial(model.decode_step, page_size=page_size),
                donate_argnums=(2,))
            self._prefill_chunk = jax.jit(
                functools.partial(model.prefill_chunk, page_size=page_size),
                donate_argnums=(2,))

    def _place_batch(self, batch: Dict[str, jax.Array]
                     ) -> Dict[str, jax.Array]:
        """Shard a bucket's batch over the data axes when it divides."""
        if self._batch_sharding is None:
            return batch
        b = next(iter(batch.values())).shape[0]
        if b % self._dp:
            return batch
        return {k: jax.device_put(v, self._batch_sharding)
                for k, v in batch.items()}

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _filter_logits(self, row: jax.Array) -> jax.Array:
        """Top-k / top-p (nucleus) filtering of one temperature-scaled
        logit row: filtered-out entries go to -inf.  Pure per-row — the
        batched (vmapped) and solo paths run the identical ops, which is
        what keeps the per-(uid, step) streams batch-independent."""
        v = row.shape[-1]
        if self.top_k is not None and 0 < self.top_k < v:
            kth = jax.lax.top_k(row, self.top_k)[0][-1]
            row = jnp.where(row < kth, -jnp.inf, row)
        if self.top_p is not None and 0.0 < self.top_p < 1.0:
            srt = jnp.sort(row)[::-1]                     # descending
            probs = jax.nn.softmax(srt)
            # keep the smallest prefix whose mass reaches top_p (the
            # first token always survives: exclusive cumsum < p)
            keep = (jnp.cumsum(probs) - probs) < self.top_p
            thr = jnp.min(jnp.where(keep, srt, jnp.inf))
            row = jnp.where(row < thr, -jnp.inf, row)
        return row

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        """Static-mode sampling: one batch-keyed draw per step."""
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        rows = jax.vmap(self._filter_logits)(logits / self.temperature)
        return jax.random.categorical(key, rows).astype(jnp.int32)

    def _pos_offset(self) -> int:
        cfg = self.model.cfg
        if cfg.frontend is not None and not cfg.encdec:
            return cfg.frontend_len
        return 0

    def _run_bucket(self, reqs: List[Request], key) -> List[Result]:
        b = len(reqs)
        plen = len(reqs[0].prompt)
        off = self._pos_offset()
        max_new = max(r.max_new_tokens for r in reqs)
        assert off + plen + max_new <= self.max_len, "bucket exceeds max_len"

        toks = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        batch = {"tokens": toks}
        for k, v in self.extra_batch.items():
            batch[k] = v[:b] if v.shape[0] >= b else jnp.broadcast_to(
                v[:1], (b, *v.shape[1:]))
        batch = self._place_batch(batch)
        cache = self.model.init_cache(b, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)

        out = np.zeros((b, max_new), np.int32)
        done = np.zeros((b,), bool)
        n_emitted = np.zeros((b,), np.int32)
        steps_run = 0
        tok = None
        for step in range(max_new):
            key, sk = jax.random.split(key)
            tok = self._sample(logits, sk)
            tok_np = np.asarray(jax.device_get(tok))
            steps_run = step + 1
            for i in range(b):
                if not done[i] and step < reqs[i].max_new_tokens:
                    out[i, step] = tok_np[i]
                    n_emitted[i] += 1
                    if self.eos_id is not None and tok_np[i] == self.eos_id:
                        done[i] = True
                elif step >= reqs[i].max_new_tokens:
                    done[i] = True
            if done.all():
                break
            pos = jnp.asarray(off + plen + step, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)

        # every request occupies its slot for the whole bucket run —
        # the difference vs n_emitted is the scrap-position waste that
        # continuous batching recovers
        return [
            Result(uid=r.uid, tokens=out[i, :n_emitted[i]], prompt_len=plen,
                   decode_steps=steps_run)
            for i, r in enumerate(reqs)
        ]

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------
    def _sample_seq(self, logits_row: jax.Array, seq, base_key) -> int:
        """Sample one token for one sequence.  Sampling is keyed per
        (uid, step): independent of batch composition, and a preempted
        request's recompute replays the identical stream."""
        if self.temperature <= 0.0:
            return int(jnp.argmax(logits_row))
        key = jax.random.fold_in(
            jax.random.fold_in(base_key, seq.req.uid), len(seq.tokens))
        row = self._filter_logits(logits_row / self.temperature)
        return int(jax.random.categorical(key, row))

    def _sample_running(self, logits, running, base_key) -> np.ndarray:
        """One batched sample for every running slot (single device
        round-trip per step).  The vmapped per-row (uid, step) keys and
        per-row top-k/p filter draw the same stream as
        :meth:`_sample_seq` row by row."""
        if self.temperature <= 0.0:
            return np.asarray(jax.device_get(
                jnp.argmax(logits, axis=-1).astype(jnp.int32)))[
                    [seq.slot for seq in running]]
        rows = logits[jnp.asarray([seq.slot for seq in running])]
        uids = jnp.asarray([seq.req.uid for seq in running], jnp.int32)
        steps = jnp.asarray([len(seq.tokens) for seq in running], jnp.int32)

        def draw(uid, step, row):
            key = jax.random.fold_in(jax.random.fold_in(base_key, uid), step)
            return jax.random.categorical(
                key, self._filter_logits(row / self.temperature))

        return np.asarray(jax.device_get(
            jax.vmap(draw)(uids, steps, rows).astype(jnp.int32)))

    def _record(self, seq, tok: int, sched) -> None:
        seq.tokens.append(tok)
        done = (len(seq.tokens) >= seq.req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id))
        if done:
            sched.finish(seq)

    def _run_prefill_chunk(self, seq, sched, base_key) -> None:
        """Feed one fixed-size prompt chunk of the oldest prefilling
        request; the final chunk samples the first token and moves the
        request to decode."""
        from repro.serve.scheduler import SeqState

        pool = self.pool
        plen = len(seq.req.prompt)
        start = seq.n_prefilled
        chunk = np.zeros((1, self.chunk_size), np.int32)
        piece = seq.req.prompt[start:start + self.chunk_size]
        chunk[0, :len(piece)] = piece
        bt = jnp.asarray(pool.block_tables[seq.slot][None])
        logits, pool.kv = self._prefill_chunk(
            self.params, {"tokens": jnp.asarray(chunk)}, pool.kv,
            jnp.asarray(start, jnp.int32), jnp.asarray(plen, jnp.int32),
            jnp.asarray(seq.slot, jnp.int32), bt)
        seq.n_prefilled = min(start + self.chunk_size, plen)
        seq.occupied_steps += 1
        if seq.n_prefilled >= plen:       # final chunk → first token
            seq.n_written = plen
            seq.state = SeqState.RUNNING
            self._record(seq, self._sample_seq(logits[0], seq, base_key),
                         sched)

    def _generate_continuous(self, requests: Sequence[Request], seed: int
                             ) -> List[Result]:
        from repro.serve.scheduler import Scheduler

        pool = self.pool
        pool.reset()
        sched = Scheduler(pool, self.max_batch)
        seqs = []
        for r in requests:
            if len(r.prompt) + r.max_new_tokens > self.max_len:
                raise ValueError(f"request {r.uid} exceeds max_len")
            seqs.append(sched.submit(r))
        base_key = jax.random.key(seed)

        while sched.has_work():
            # 1) join-at-prefill: new requests take free slots/pages now
            #    (recurrent-state slot rows reset to the init state —
            #    stale state can't mask by length like pages do)
            for seq in sched.admit():
                if seq.req.max_new_tokens <= 0:   # nothing to emit
                    sched.finish(seq)
                    continue
                if self.state_pool is not None:
                    pool.kv = self.state_pool.reset_slot(pool.kv, seq.slot)
            # 2) one prompt chunk for the oldest prefilling request,
            #    interleaved with this step's decode
            seq = sched.next_prefill()
            if seq is not None:
                self._run_prefill_chunk(seq, sched, base_key)
            running = sched.decoding()
            if not running:
                continue
            # 3) extend block tables for this step's writes (may preempt)
            sched.ensure_decode_capacity()
            running = sched.decoding()
            if not running:
                continue
            # 4) one decode step over every decoding slot
            tok = np.zeros((self.max_batch,), np.int32)
            pos = np.full((self.max_batch,), -1, np.int32)
            for seq in running:
                tok[seq.slot] = seq.tokens[-1]
                pos[seq.slot] = seq.n_written
            logits, pool.kv = self._decode_paged(
                self.params, jnp.asarray(tok), pool.kv, jnp.asarray(pos),
                paged={"block_tables": pool.tables_device()})
            sampled = self._sample_running(logits, running, base_key)
            # 5) advance / retire
            for i, seq in enumerate(running):
                seq.n_written += 1
                seq.occupied_steps += 1
                self._record(seq, int(sampled[i]), sched)

        return sorted(
            (Result(uid=s.req.uid,
                    tokens=np.asarray(s.tokens, np.int32),
                    prompt_len=len(s.req.prompt),
                    decode_steps=s.occupied_steps,
                    preemptions=s.preemptions)
             for s in seqs),
            key=lambda r: r.uid)

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request], seed: int = 0
                 ) -> List[Result]:
        """Serve a set of requests (continuous batching; static mode
        buckets by prompt length)."""
        if self.mode == "continuous":
            return self._generate_continuous(requests, seed)
        buckets: Dict[int, List[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        results: List[Result] = []
        key = jax.random.key(seed)
        for plen in sorted(buckets):
            bucket = buckets[plen]
            for i in range(0, len(bucket), self.max_batch):
                key, bk = jax.random.split(key)
                results.extend(self._run_bucket(
                    bucket[i:i + self.max_batch], bk))
        return sorted(results, key=lambda r: r.uid)
