"""Batched serving engine: prefill + KV-cache decode with request bucketing.

Design (CPU-testable, TPU-shaped):
  - requests are bucketed by prompt length (a shared scalar decode ``pos``
    keeps every step a single fused dynamic_update_slice — per-request
    positions would force scatter ops on TPU);
  - each bucket runs one batched prefill then a jitted decode loop; done
    requests keep decoding into a scrap position but their output is
    frozen (standard static-batch serving);
  - greedy or temperature sampling;
  - optional 2:4-sparse weights (serve.sparse) — same code path, the
    sparse matmuls dispatch inside models.layers.linear.

On a mesh — passed explicitly or resolved from the active ``repro.dist``
context — params are sharded by dist.sharding rules (tensor-parallel
resident, no FSDP: serving re-reads weights every step) and each
bucket's token batch is placed over the data axes when it divides (see
launch/serve.py + the decode dry-run).  Without a mesh everything stays
single-device.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LM


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (L,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray                   # generated tokens (≤ max_new)
    prompt_len: int


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        max_batch: int = 8,
        max_len: int = 256,
        eos_id: Optional[int] = None,
        temperature: float = 0.0,
        extra_batch: Optional[Dict[str, jax.Array]] = None,
        mesh=None,
    ):
        from repro.dist import current_ctx, dp_axes_of, shard_params

        self.model = model
        if mesh is None:
            ctx = current_ctx()
            mesh = ctx.mesh if ctx is not None else None
        self.mesh = mesh
        self.dp_axes = dp_axes_of(mesh) if mesh is not None else ()
        self._dp = 1
        self._batch_sharding = None
        if self.dp_axes:
            from repro.dist import batch_sharding

            for a in self.dp_axes:
                self._dp *= mesh.shape[a]
            self._batch_sharding = batch_sharding(mesh, self.dp_axes)
        # resident serving: tensor-parallel only (fsdp_axes=()) — an FSDP
        # all-gather per decode step would dominate the wire
        self.params = (shard_params(params, mesh, fsdp_axes=())
                       if mesh is not None else params)
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.extra_batch = extra_batch or {}
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))

    def _place_batch(self, batch: Dict[str, jax.Array]
                     ) -> Dict[str, jax.Array]:
        """Shard a bucket's batch over the data axes when it divides."""
        if self._batch_sharding is None:
            return batch
        b = next(iter(batch.values())).shape[0]
        if b % self._dp:
            return batch
        return {k: jax.device_put(v, self._batch_sharding)
                for k, v in batch.items()}

    # ------------------------------------------------------------------
    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature).astype(jnp.int32)

    def _pos_offset(self) -> int:
        cfg = self.model.cfg
        if cfg.frontend is not None and not cfg.encdec:
            return cfg.frontend_len
        return 0

    def _run_bucket(self, reqs: List[Request], key) -> List[Result]:
        b = len(reqs)
        plen = len(reqs[0].prompt)
        off = self._pos_offset()
        max_new = max(r.max_new_tokens for r in reqs)
        assert off + plen + max_new <= self.max_len, "bucket exceeds max_len"

        toks = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        batch = {"tokens": toks}
        for k, v in self.extra_batch.items():
            batch[k] = v[:b] if v.shape[0] >= b else jnp.broadcast_to(
                v[:1], (b, *v.shape[1:]))
        batch = self._place_batch(batch)
        cache = self.model.init_cache(b, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)

        out = np.zeros((b, max_new), np.int32)
        done = np.zeros((b,), bool)
        n_emitted = np.zeros((b,), np.int32)
        tok = None
        for step in range(max_new):
            key, sk = jax.random.split(key)
            tok = self._sample(logits, sk)
            tok_np = np.asarray(jax.device_get(tok))
            for i in range(b):
                if not done[i] and step < reqs[i].max_new_tokens:
                    out[i, step] = tok_np[i]
                    n_emitted[i] += 1
                    if self.eos_id is not None and tok_np[i] == self.eos_id:
                        done[i] = True
                elif step >= reqs[i].max_new_tokens:
                    done[i] = True
            if done.all():
                break
            pos = jnp.asarray(off + plen + step, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)

        return [
            Result(uid=r.uid, tokens=out[i, :n_emitted[i]], prompt_len=plen)
            for i, r in enumerate(reqs)
        ]

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request], seed: int = 0
                 ) -> List[Result]:
        """Serve a set of requests (bucketed by prompt length)."""
        buckets: Dict[int, List[Request]] = {}
        for r in requests:
            buckets.setdefault(len(r.prompt), []).append(r)
        results: List[Result] = []
        key = jax.random.key(seed)
        for plen in sorted(buckets):
            bucket = buckets[plen]
            for i in range(0, len(bucket), self.max_batch):
                key, bk = jax.random.split(key)
                results.extend(self._run_bucket(
                    bucket[i:i + self.max_batch], bk))
        return sorted(results, key=lambda r: r.uid)
