"""Serving engine: continuous batching over a paged serve cache.

Default mode ``"continuous"`` (docs/serving.md) runs a step loop over
serve.scheduler: requests join the running batch the moment a slot and
prompt pages are free, their prompts stream in as fixed-size token
chunks (one jitted ``prefill_chunk`` shape, interleaved with everyone
else's decode — no head-of-line blocking from long prompts), and the
decode inner loop is **device-resident** (serve.fused): one donated
fused step runs ``decode_step`` + per-(uid, step)-keyed sampling + EOS/
length done-detection + position advance on device, wrapped in an
on-device multi-step burst (``steps_per_sync`` fused steps per host
sync).  The host only wakes to make scheduler decisions — admission,
prefill chunks, retirement, page capacity, preemption — reading back
one small packed state blob per burst instead of per-step logits.
When the pool runs dry the youngest request is preempted
(recompute-style) and re-queued.

``mode="static"`` is the legacy escape hatch (PR 2's ``pipeline="off"``
pattern): requests bucketed by prompt length, one batched prefill + a
fused on-device decode loop per bucket (one host sync per bucket),
finished requests decoding into scrap until the whole bucket drains.
Archs the paged path can't serve (enc-dec, modality frontends, MoE —
expert-capacity dropping makes logits batch-dependent) fall back to it
automatically.

Both paths are greedy-token-identical: paged attention is bit-equal to
the dense cache math (kernels.ref.paged_attn_ref), recurrent-state
chunked prefill is the same recurrence with a different (tested)
reduction tree, and sampling — greedy, temperature, top-k, top-p — is
keyed per (request uid, step) in continuous mode so results are
independent of batch composition, of ``steps_per_sync``, and survive
preemption-recompute (the fused bodies run the per-step path's exact
ops — tests/test_serve_paged.py fused-parity suite).

On a mesh — passed explicitly or resolved from the active ``repro.dist``
context — params are sharded by dist.sharding rules (tensor-parallel
resident, no FSDP: serving re-reads weights every step), the paged pool
is placed by the paged cache rules (pages/slots replicated over data,
widths over ``model`` on head-aligned splits), the device-resident
scheduler-state blob by ``dist.sharding.decode_state_specs``
(replicated), and static-bucket batches are placed over the data axes
when they divide.  Without a mesh everything stays single-device.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LM
from repro.serve import fused

# every mixer the paged runtime serves: attention (KV pages) plus the
# recurrent kinds (slot-pooled state — the canonical list lives on LM,
# which init_paged_cache validates against)
PAGED_KINDS = ("attn", "attn_local", *LM.STATE_KINDS)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (L,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray                   # generated tokens (≤ max_new)
    prompt_len: int
    decode_steps: int = 0                # steps the request's slot was
    #                                      live for (chunks + decodes)
    preemptions: int = 0                 # times recomputed (continuous)

    @property
    def utilization(self) -> float:
        """Emitted tokens / slot-steps occupied: 1.0 means every step
        the request held a slot produced a token; static bucketing
        drops it by whatever was burned into scrap positions (and
        continuous mode by multi-chunk prefills and the recomputed
        prefix after a preemption)."""
        if self.decode_steps <= 0:
            return 0.0
        return len(self.tokens) / self.decode_steps


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        max_batch: int = 8,
        max_len: int = 256,
        eos_id: Optional[int] = None,
        temperature: float = 0.0,
        top_k: Optional[int] = None,
        top_p: Optional[float] = None,
        extra_batch: Optional[Dict[str, jax.Array]] = None,
        mesh=None,
        mode: str = "continuous",
        page_size: int = 16,
        num_pages: Optional[int] = None,
        prefill_chunk: int = 32,
        steps_per_sync: int = 8,
    ):
        from repro.dist import current_ctx, dp_axes_of, shard_params

        if mode not in ("continuous", "static"):
            raise ValueError(f"unknown serve mode {mode!r}")
        self.model = model
        if mesh is None:
            ctx = current_ctx()
            mesh = ctx.mesh if ctx is not None else None
        self.mesh = mesh
        self.dp_axes = dp_axes_of(mesh) if mesh is not None else ()
        self._dp = 1
        self._batch_sharding = None
        if self.dp_axes:
            from repro.dist import batch_sharding

            for a in self.dp_axes:
                self._dp *= mesh.shape[a]
            self._batch_sharding = batch_sharding(mesh, self.dp_axes)
        # resident serving: tensor-parallel only (fsdp_axes=()) — an FSDP
        # all-gather per decode step would dominate the wire.  head_dim
        # keeps whole heads per model shard (rope-safe, see param_specs)
        self.params = (shard_params(params, mesh, fsdp_axes=(),
                                    head_dim=model.cfg.hd)
                       if mesh is not None else params)
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.extra_batch = extra_batch or {}
        self.steps_per_sync = max(1, int(steps_per_sync))
        self._prefill = jax.jit(model.prefill)
        # static-mode fused decode loops, built per early-exit variant on
        # first use (see fused.make_static_burst)
        self._static_bursts: Dict[bool, object] = {}
        # per-generate runtime counters (host_syncs counts BLOCKING
        # device readbacks — the quantity the device-resident loop
        # exists to amortize; device_steps counts fused decode steps;
        # decode_wall_s is wall time inside burst-dispatch→readback
        # windows only — prefill and host scheduling excluded, so
        # decode_wall_s / device_steps is a step-latency signal
        # independent of end-to-end tokens/sec)
        self.stats: Dict[str, float] = {
            "host_syncs": 0, "device_steps": 0, "tokens": 0,
            "decode_wall_s": 0.0}

        cfg = model.cfg
        # MoE is excluded: expert-capacity dropping makes each row's
        # logits depend on batch composition, which breaks the greedy
        # parity and bit-exact preemption-recompute guarantees below
        paged_ok = (not cfg.encdec and cfg.frontend is None
                    and not self.extra_batch and cfg.moe is None
                    and all(k in PAGED_KINDS
                            for k in (*cfg.prefix, *cfg.period)))
        self.mode = mode if paged_ok else "static"
        self.pool = None
        self.state_pool = None
        self._state_shardings = None
        if self.mode == "continuous":
            from repro.serve.kvpool import PagedKVPool, StatePool

            self.page_size = page_size
            self.chunk_size = prefill_chunk
            if num_pages is None:
                # same token capacity as the dense static cache, + scrap
                num_pages = max_batch * (-(-max_len // page_size)) + 1
            self.pool = PagedKVPool(
                model, num_pages=num_pages, page_size=page_size,
                max_slots=max_batch, max_len=max_len, mesh=mesh)
            state = StatePool(model, max_slots=max_batch)
            self.state_pool = state if state.has_state else None
            self._burst = fused.make_continuous_burst(
                model, page_size, temperature=temperature, top_k=top_k,
                top_p=top_p, eos_id=eos_id)
            self._prefill_chunk = jax.jit(
                functools.partial(model.prefill_chunk, page_size=page_size),
                donate_argnums=(2,))
            if mesh is not None:
                from repro.dist import named_shardings
                from repro.dist.sharding import decode_state_specs

                template = fused.init_burst_state(max_batch,
                                                  self.steps_per_sync)
                self._state_shardings = named_shardings(
                    mesh, decode_state_specs(template))

    def _place_batch(self, batch: Dict[str, jax.Array]
                     ) -> Dict[str, jax.Array]:
        """Shard a bucket's batch over the data axes when it divides."""
        if self._batch_sharding is None:
            return batch
        b = next(iter(batch.values())).shape[0]
        if b % self._dp:
            return batch
        return {k: jax.device_put(v, self._batch_sharding)
                for k, v in batch.items()}

    # ------------------------------------------------------------------
    # static mode: one fused on-device decode loop per bucket
    # ------------------------------------------------------------------
    def _static_burst(self, early_exit: bool):
        if early_exit not in self._static_bursts:
            self._static_bursts[early_exit] = fused.make_static_burst(
                self.model, temperature=self.temperature, top_k=self.top_k,
                top_p=self.top_p, eos_id=self.eos_id, early_exit=early_exit)
        return self._static_bursts[early_exit]

    def _pos_offset(self) -> int:
        cfg = self.model.cfg
        if cfg.frontend is not None and not cfg.encdec:
            return cfg.frontend_len
        return 0

    def _run_bucket(self, reqs: List[Request], key) -> List[Result]:
        b = len(reqs)
        plen = len(reqs[0].prompt)
        off = self._pos_offset()
        max_new = max(r.max_new_tokens for r in reqs)
        assert off + plen + max_new <= self.max_len, "bucket exceeds max_len"

        toks = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        batch = {"tokens": toks}
        for k, v in self.extra_batch.items():
            batch[k] = v[:b] if v.shape[0] >= b else jnp.broadcast_to(
                v[:1], (b, *v.shape[1:]))
        batch = self._place_batch(batch)
        cache = self.model.init_cache(b, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)

        max_new_arr = np.asarray([r.max_new_tokens for r in reqs], np.int32)
        # when EOS is off and every request shares one max_new_tokens the
        # done scan can never fire early — the fori variant drops that
        # bookkeeping entirely (satellite: no wasted per-step scan)
        early_exit = not (self.eos_id is None
                          and len(set(max_new_arr.tolist())) == 1)
        t0 = time.monotonic()
        out, n_emitted, steps_run = self._static_burst(early_exit)(
            self.params, cache, logits, key, max_new_arr, off + plen,
            max_new)
        out = np.asarray(jax.device_get(out))          # ONE sync per bucket
        n_emitted = np.asarray(jax.device_get(n_emitted))
        steps_run = int(jax.device_get(steps_run))
        self.stats["decode_wall_s"] += time.monotonic() - t0
        self.stats["host_syncs"] += 1
        self.stats["device_steps"] += steps_run

        # every request occupies its slot for the whole bucket run —
        # the difference vs n_emitted is the scrap-position waste that
        # continuous batching recovers
        return [
            Result(uid=r.uid, tokens=out[i, :n_emitted[i]], prompt_len=plen,
                   decode_steps=steps_run)
            for i, r in enumerate(reqs)
        ]

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------
    def _sample_seq(self, logits_row: jax.Array, seq, base_key) -> int:
        """Sample one token for one sequence (the final prefill chunk —
        a host sync by design: prefill completion is a scheduler event).
        A 1-row fused.sample_rows call, so the per-(uid, step) draw has
        exactly ONE implementation shared with the device burst:
        independent of batch composition, and a preempted request's
        recompute replays the identical stream."""
        self.stats["host_syncs"] += 1
        tok = fused.sample_rows(
            logits_row[None], jnp.asarray([seq.req.uid], jnp.int32),
            jnp.asarray([len(seq.tokens)], jnp.int32), base_key,
            temperature=self.temperature, top_k=self.top_k,
            top_p=self.top_p)
        return int(tok[0])

    def _record(self, seq, tok: int, sched) -> None:
        seq.tokens.append(tok)
        done = (len(seq.tokens) >= seq.req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id))
        if done:
            sched.finish(seq)

    def _run_prefill_chunk(self, seq, sched, base_key) -> None:
        """Feed one fixed-size prompt chunk of the oldest prefilling
        request; the final chunk samples the first token and moves the
        request to decode."""
        from repro.serve.scheduler import SeqState

        pool = self.pool
        plen = len(seq.req.prompt)
        start = seq.n_prefilled
        chunk = np.zeros((1, self.chunk_size), np.int32)
        piece = seq.req.prompt[start:start + self.chunk_size]
        chunk[0, :len(piece)] = piece
        # the slot's table row sliced on device — no host re-upload
        bt = pool.tables_device()[seq.slot][None]
        logits, pool.kv = self._prefill_chunk(
            self.params, {"tokens": jnp.asarray(chunk)}, pool.kv,
            jnp.asarray(start, jnp.int32), jnp.asarray(plen, jnp.int32),
            jnp.asarray(seq.slot, jnp.int32), bt)
        seq.n_prefilled = min(start + self.chunk_size, plen)
        seq.occupied_steps += 1
        if seq.n_prefilled >= plen:       # final chunk → first token
            seq.n_written = plen
            seq.state = SeqState.RUNNING
            self._record(seq, self._sample_seq(logits[0], seq, base_key),
                         sched)

    def _plan_burst(self, sched, running) -> int:
        """Burst length for this sync interval: ``steps_per_sync`` fused
        steps, clamped to (a) 1 while any prompt is still chunk-
        prefilling (the chunk/decode interleave is a host event every
        step), (b) the longest possible remaining emission, and (c) the
        page capacity the pool can map WITHOUT preempting
        (Scheduler.extend_decode_capacity) — burst lookahead must never
        cause a preemption the per-step loop wouldn't have."""
        if sched.next_prefill() is not None:
            return 1
        k = self.steps_per_sync
        if k > 1:
            k = min(k, max(s.req.max_new_tokens - len(s.tokens)
                           for s in running))
            k = sched.extend_decode_capacity(max(1, k))
        return max(1, k)

    def _generate_continuous(self, requests: Sequence[Request], seed: int
                             ) -> List[Result]:
        from repro.serve.scheduler import Scheduler

        pool = self.pool
        pool.reset()
        sched = Scheduler(pool, self.max_batch)
        seqs = []
        for r in requests:
            if len(r.prompt) + r.max_new_tokens > self.max_len:
                raise ValueError(f"request {r.uid} exceeds max_len")
            seqs.append(sched.submit(r))
        base_key = jax.random.key(seed)
        B = self.max_batch
        ring = self.steps_per_sync

        while sched.has_work():
            # 1) join-at-prefill: new requests take free slots/pages now
            #    (recurrent-state slot rows reset to the init state —
            #    stale state can't mask by length like pages do)
            for seq in sched.admit():
                if seq.req.max_new_tokens <= 0:   # nothing to emit
                    sched.finish(seq)
                    continue
                if self.state_pool is not None:
                    pool.kv = self.state_pool.reset_slot(pool.kv, seq.slot)
            # 2) one prompt chunk for the oldest prefilling request,
            #    interleaved with this sync interval's decode burst
            seq = sched.next_prefill()
            if seq is not None:
                self._run_prefill_chunk(seq, sched, base_key)
            running = sched.decoding()
            if not running:
                continue
            # 3) extend block tables for this interval's writes (may
            #    preempt — the same single-step guarantee as before;
            #    burst lookahead only ever shortens the burst)
            sched.ensure_decode_capacity()
            running = sched.decoding()
            if not running:
                continue
            k = self._plan_burst(sched, running)
            # 4) one device-resident burst over every decoding slot: up
            #    to k fused decode/sample/record/advance steps, no host
            #    round-trip inside
            state = fused.init_burst_state(B, ring)
            for s in running:
                state["tok"][s.slot] = s.tokens[-1]
                state["pos"][s.slot] = s.n_written
                state["uid"][s.slot] = s.req.uid
                state["n_tok"][s.slot] = len(s.tokens)
                state["max_new"][s.slot] = s.req.max_new_tokens
            state["steps_left"] = np.asarray(k, np.int32)
            if self._state_shardings is not None:
                state = jax.device_put(state, self._state_shardings)
            t0 = time.monotonic()
            pool.kv, state = self._burst(
                self.params, pool.kv, pool.tables_device(), state, base_key)
            st = jax.device_get(state)     # the ONE host sync per burst
            self.stats["decode_wall_s"] += time.monotonic() - t0
            self.stats["host_syncs"] += 1
            self.stats["device_steps"] += k - int(st["steps_left"])
            # 5) advance / retire from the packed state blob
            for s in list(running):
                n = int(st["n_out"][s.slot])
                if n:
                    s.tokens.extend(int(t) for t in st["out"][s.slot, :n])
                    s.n_written += n
                    s.occupied_steps += n
                if bool(st["done"][s.slot]):
                    sched.finish(s)

        return sorted(
            (Result(uid=s.req.uid,
                    tokens=np.asarray(s.tokens, np.int32),
                    prompt_len=len(s.req.prompt),
                    decode_steps=s.occupied_steps,
                    preemptions=s.preemptions)
             for s in seqs),
            key=lambda r: r.uid)

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request], seed: int = 0
                 ) -> List[Result]:
        """Serve a set of requests (continuous batching; static mode
        buckets by prompt length).  ``self.stats`` afterwards holds the
        run's host-sync / fused-device-step / token counters."""
        self.stats = {"host_syncs": 0, "device_steps": 0, "tokens": 0,
                      "decode_wall_s": 0.0}
        if self.mode == "continuous":
            results = self._generate_continuous(requests, seed)
        else:
            buckets: Dict[int, List[Request]] = {}
            for r in requests:
                buckets.setdefault(len(r.prompt), []).append(r)
            results = []
            key = jax.random.key(seed)
            for plen in sorted(buckets):
                bucket = buckets[plen]
                for i in range(0, len(bucket), self.max_batch):
                    key, bk = jax.random.split(key)
                    results.extend(self._run_bucket(
                        bucket[i:i + self.max_batch], bk))
            results = sorted(results, key=lambda r: r.uid)
        self.stats["tokens"] = sum(len(r.tokens) for r in results)
        return results
