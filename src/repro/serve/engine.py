"""Serving engine: continuous batching over a paged serve cache.

Default mode ``"continuous"`` (docs/serving.md) runs a step loop over
serve.scheduler: requests join the running batch the moment a slot and
prompt pages are free, their prompts stream in as fixed-size token
chunks (one jitted ``prefill_chunk`` shape, interleaved with everyone
else's decode — no head-of-line blocking from long prompts), and the
decode inner loop is **device-resident** (serve.fused): one donated
fused step runs ``decode_step`` + per-(uid, step)-keyed sampling + EOS/
length done-detection + position advance on device, wrapped in an
on-device multi-step burst (``steps_per_sync`` fused steps per host
sync).  The host only wakes to make scheduler decisions — admission,
prefill chunks, retirement, page capacity, preemption — reading back
one small packed state blob per burst instead of per-step logits.
When the pool runs dry the youngest request is preempted and re-queued
— preserve-KV swap to the host arena when it has room (tokens kept,
resume mid-stream), recompute otherwise.  Admission consults the
pool's prefix index when enabled: cached prompt pages attach shared
(no prefill) with copy-on-write on divergence — see kvpool.

``mode="static"`` is the legacy escape hatch (PR 2's ``pipeline="off"``
pattern): requests bucketed by prompt length, one batched prefill + a
fused on-device decode loop per bucket (one host sync per bucket),
finished requests decoding into scrap until the whole bucket drains.
Archs the paged path can't serve (enc-dec, modality frontends, MoE —
expert-capacity dropping makes logits batch-dependent) fall back to it
automatically.

Both paths are greedy-token-identical: paged attention is bit-equal to
the dense cache math (kernels.ref.paged_attn_ref), recurrent-state
chunked prefill is the same recurrence with a different (tested)
reduction tree, and sampling — greedy, temperature, top-k, top-p — is
keyed per (request uid, step) in continuous mode so results are
independent of batch composition, of ``steps_per_sync``, and survive
preemption-recompute (the fused bodies run the per-step path's exact
ops — tests/test_serve_paged.py fused-parity suite).

On a mesh — passed explicitly or resolved from the active ``repro.dist``
context — params are sharded by dist.sharding rules (tensor-parallel
resident, no FSDP: serving re-reads weights every step), the paged pool
is placed by the paged cache rules (pages/slots replicated over data,
widths over ``model`` on head-aligned splits), the device-resident
scheduler-state blob by ``dist.sharding.decode_state_specs``
(replicated), and static-bucket batches are placed over the data axes
when they divide.  Without a mesh everything stays single-device.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LM
from repro.obs import Obs
from repro.serve import fused
from repro.serve.config import ServeConfig
from repro.serve.metrics import ServeMetrics

# every mixer the paged runtime serves: attention (KV pages) plus the
# recurrent kinds (slot-pooled state — the canonical list lives on LM,
# which init_paged_cache validates against)
PAGED_KINDS = ("attn", "attn_local", *LM.STATE_KINDS)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                   # (L,) int32
    max_new_tokens: int = 16
    # SLA fields (serve.scheduler wait-queue order: higher priority
    # first, then earlier deadline, then arrival; both optional — all-
    # default requests admit in exact FIFO).  ``deadline`` is an
    # absolute time.monotonic() timestamp; it always orders admission.
    # With ``deadline_hard`` set (the wire path: a client-supplied
    # ``deadline_ms``) an expired request is also RETIRED at the next
    # sync interval — terminal event with ``finish_reason="timeout"``,
    # pages/slot released (ISSUE-10); unset, it stays ordering-only.
    priority: int = 0
    deadline: Optional[float] = None
    deadline_hard: bool = False


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray                   # generated tokens (≤ max_new)
    prompt_len: int
    decode_steps: int = 0                # steps the request's slot was
    #                                      live for (chunks + decodes)
    preemptions: int = 0                 # times recomputed (continuous)

    @property
    def utilization(self) -> float:
        """Emitted tokens / slot-steps occupied: 1.0 means every step
        the request held a slot produced a token; static bucketing
        drops it by whatever was burned into scrap positions (and
        continuous mode by multi-chunk prefills and the recomputed
        prefix after a preemption)."""
        if self.decode_steps <= 0:
            return 0.0
        return len(self.tokens) / self.decode_steps


@dataclasses.dataclass
class StreamEvent:
    """One request's incremental progress at one host sync — the unit
    the streaming front end forwards as an SSE chunk.  ``tokens`` holds
    only the NEWLY emitted tokens (after a preemption the recompute
    replays the identical prefix, and the session suppresses the
    already-delivered portion, so a streaming consumer never sees a
    duplicate).  ``result`` is set on the final event, and
    ``finish_reason`` says why it ended: ``"stop"`` (EOS), ``"length"``
    (max_new_tokens), ``"timeout"`` (hard deadline, ISSUE-10) or
    ``"cancelled"`` (client disconnect / explicit cancel)."""

    uid: int
    tokens: List[int]
    finished: bool = False
    result: Optional[Result] = None
    finish_reason: Optional[str] = None


class ServeEngine:
    def __init__(
        self,
        model: LM,
        params,
        config: Optional[ServeConfig] = None,
        *,
        extra_batch: Optional[Dict[str, jax.Array]] = None,
        mesh=None,
        obs: Optional[Obs] = None,
        **knobs,
    ):
        """``config`` is the one knob surface (serve.config.ServeConfig).
        Bare keywords still work — ``ServeEngine(model, params,
        max_batch=4, mode="static")`` builds a config from them, and
        keywords override fields of an explicit config — so pre-ISSUE-7
        call sites are untouched.  Validation happens exactly once, in
        ``ServeConfig.validate``."""
        from repro.dist import current_ctx, dp_axes_of, shard_params

        if config is None:
            config = ServeConfig(**knobs)
        elif knobs:
            config = dataclasses.replace(config, **knobs)
        config.validate()
        self.config = config
        max_batch, max_len = config.max_batch, config.max_len
        self.model = model
        if mesh is None:
            ctx = current_ctx()
            mesh = ctx.mesh if ctx is not None else None
        self.mesh = mesh
        self.dp_axes = dp_axes_of(mesh) if mesh is not None else ()
        self._dp = 1
        self._batch_sharding = None
        if self.dp_axes:
            from repro.dist import batch_sharding

            for a in self.dp_axes:
                self._dp *= mesh.shape[a]
            self._batch_sharding = batch_sharding(mesh, self.dp_axes)
        # compressed-weight serving (ISSUE-9): detect 2:4-prunable
        # leaves ONCE at load and keep only (vals, idx) in HBM — dense
        # leaves that verify as 2:4 pack here, pre-packed checkpoints
        # pass through, everything else is untouched.  The decompress
        # is an exact inverse, so f32 token streams are unchanged.
        from repro.serve.sparse import compressed_param_tree, count_packed

        if config.sparse_weights == "auto":
            params = compressed_param_tree(params)
        self.n_sparse_leaves = count_packed(params)
        # resident serving: tensor-parallel only (fsdp_axes=()) — an FSDP
        # all-gather per decode step would dominate the wire.  head_dim
        # keeps whole heads per model shard (rope-safe, see param_specs)
        self.params = (shard_params(params, mesh, fsdp_axes=(),
                                    head_dim=model.cfg.hd)
                       if mesh is not None else params)
        # attribute aliases onto the config (the pre-ISSUE-7 surface —
        # call sites and subclasses read these freely)
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = config.eos_id
        self.temperature = config.temperature
        self.top_k = config.top_k
        self.top_p = config.top_p
        self.extra_batch = extra_batch or {}
        self.steps_per_sync = max(1, int(config.steps_per_sync))
        self._prefill = jax.jit(model.prefill)
        # static-mode fused decode loops, built per early-exit variant on
        # first use (see fused.make_static_burst)
        self._static_bursts: Dict[bool, object] = {}
        # runtime counters live in the obs registry (ISSUE-8): one
        # thread-safe namespace the engine, scheduler, pool and frontend
        # all write — host_syncs counts BLOCKING device readbacks (the
        # quantity the device-resident loop exists to amortize),
        # device_steps counts fused decode steps, prefill_chunks counts
        # chunk dispatches (each fused into its interval's burst — the
        # sync-floor fix means chunks no longer clamp bursts to K=1),
        # and decode_wall_s is wall time inside burst-dispatch→readback
        # windows only, so decode_wall_s / device_steps is a step-
        # latency signal independent of end-to-end tokens/sec.  The
        # pre-ISSUE-8 ``self.stats`` dict survives as a property over
        # the registry; ``generate()`` re-bases it per run.
        if obs is None:
            obs = Obs.create(metrics=config.metrics, trace=config.trace)
        self.obs = obs
        self.m = ServeMetrics(obs)
        self._stats_base: Dict[str, float] = {}
        # fault injection (ISSUE-10, serve.faults): the burst wrappers
        # get a host-side hook firing the engine_step / slow_burst
        # sites; the pool takes the plan for pool_alloc / swap_error
        self.faults = config.faults
        fault_hook = None
        if self.faults is not None:
            label = obs.label
            fault_hook = lambda: self.faults.burst_hook(label)  # noqa: E731

        cfg = model.cfg
        # MoE is excluded: expert-capacity dropping makes each row's
        # logits depend on batch composition, which breaks the greedy
        # parity and bit-exact preemption-recompute guarantees below
        paged_ok = (not cfg.encdec and cfg.frontend is None
                    and not self.extra_batch and cfg.moe is None
                    and all(k in PAGED_KINDS
                            for k in (*cfg.prefix, *cfg.period)))
        # self.mode is the EFFECTIVE mode (config.mode stays as asked)
        self.mode = config.mode if paged_ok else "static"
        self.pool = None
        self.state_pool = None
        self._state_shardings = None
        self._swap_ok = False
        if self.mode == "continuous":
            from repro.serve.kvpool import PagedKVPool, StatePool

            page_size = self.page_size = config.page_size
            self.chunk_size = config.prefill_chunk
            self.pool = PagedKVPool(
                model, num_pages=config.resolved_num_pages(),
                page_size=page_size, max_slots=max_batch, max_len=max_len,
                dtype=jnp.int8 if config.kv_dtype == "int8" else None,
                mesh=mesh, prefix_cache=config.prefix_cache,
                host_swap_pages=config.resolved_swap_pages(),
                obs=self.obs, faults=self.faults)
            state = StatePool(model, max_slots=max_batch)
            self.state_pool = state if state.has_state else None
            # swap preemption preserves KV pages only — recurrent-state
            # rows live outside the page pool, so hybrid/recurrent archs
            # keep recompute preemption (StatePool docstring)
            self._swap_ok = (self.state_pool is None
                             and self.pool.arena is not None)
            # output ring: burst length + 1 cell for the token a
            # prefill-fused burst's activation emits (fused module doc)
            self._ring = self.steps_per_sync + 1
            self._burst = fused.make_continuous_burst(
                model, page_size, temperature=self.temperature,
                top_k=self.top_k, top_p=self.top_p, eos_id=self.eos_id,
                host_hook=fault_hook)
            self._prefill_burst = fused.make_prefill_burst(
                model, page_size, self.chunk_size,
                temperature=self.temperature, top_k=self.top_k,
                top_p=self.top_p, eos_id=self.eos_id,
                host_hook=fault_hook)
            if mesh is not None:
                from repro.dist import named_shardings
                from repro.dist.sharding import decode_state_specs

                template = fused.init_burst_state(max_batch, self._ring)
                self._state_shardings = named_shardings(
                    mesh, decode_state_specs(template))

    @property
    def stats(self) -> Dict[str, float]:
        """Legacy flat counter dict (engine + scheduler + pool slices),
        assembled from the obs registry: cumulative since construction,
        re-based at each ``generate()`` so batch callers still read
        per-run numbers.  Reading is race-free — the frontend worker
        threads bump atomic registry counters, not a shared dict."""
        cur = self.m.snapshot()
        base = self._stats_base
        return {k: v - base.get(k, 0) for k, v in cur.items()}

    def _place_batch(self, batch: Dict[str, jax.Array]
                     ) -> Dict[str, jax.Array]:
        """Shard a bucket's batch over the data axes when it divides."""
        if self._batch_sharding is None:
            return batch
        b = next(iter(batch.values())).shape[0]
        if b % self._dp:
            return batch
        return {k: jax.device_put(v, self._batch_sharding)
                for k, v in batch.items()}

    # ------------------------------------------------------------------
    # static mode: one fused on-device decode loop per bucket
    # ------------------------------------------------------------------
    def _static_burst(self, early_exit: bool):
        if early_exit not in self._static_bursts:
            self._static_bursts[early_exit] = fused.make_static_burst(
                self.model, temperature=self.temperature, top_k=self.top_k,
                top_p=self.top_p, eos_id=self.eos_id, early_exit=early_exit)
        return self._static_bursts[early_exit]

    def _pos_offset(self) -> int:
        cfg = self.model.cfg
        if cfg.frontend is not None and not cfg.encdec:
            return cfg.frontend_len
        return 0

    def _run_bucket(self, reqs: List[Request], key) -> List[Result]:
        b = len(reqs)
        plen = len(reqs[0].prompt)
        off = self._pos_offset()
        max_new = max(r.max_new_tokens for r in reqs)
        assert off + plen + max_new <= self.max_len, "bucket exceeds max_len"

        toks = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        batch = {"tokens": toks}
        for k, v in self.extra_batch.items():
            batch[k] = v[:b] if v.shape[0] >= b else jnp.broadcast_to(
                v[:1], (b, *v.shape[1:]))
        batch = self._place_batch(batch)
        cache = self.model.init_cache(b, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)

        max_new_arr = np.asarray([r.max_new_tokens for r in reqs], np.int32)
        # when EOS is off and every request shares one max_new_tokens the
        # done scan can never fire early — the fori variant drops that
        # bookkeeping entirely (satellite: no wasted per-step scan)
        early_exit = not (self.eos_id is None
                          and len(set(max_new_arr.tolist())) == 1)
        t0 = time.monotonic()
        out, n_emitted, steps_run = self._static_burst(early_exit)(
            self.params, cache, logits, key, max_new_arr, off + plen,
            max_new)
        out = np.asarray(jax.device_get(out))          # ONE sync per bucket
        n_emitted = np.asarray(jax.device_get(n_emitted))
        steps_run = int(jax.device_get(steps_run))
        t1 = time.monotonic()
        self.m.decode_wall.inc(t1 - t0)
        self.m.host_syncs.inc()
        self.m.device_steps.inc(steps_run)
        self.m.burst_steps.observe(steps_run)
        self.m.requests.inc(b)
        self.m.tokens.inc(int(n_emitted.sum()))
        self.m.slot_steps.inc(steps_run * b)
        self.obs.tracer.complete(
            "static_bucket", t0, t1, track=self.obs.label,
            args={"batch": b, "prompt_len": plen, "steps": steps_run})

        # every request occupies its slot for the whole bucket run —
        # the difference vs n_emitted is the scrap-position waste that
        # continuous batching recovers
        return [
            Result(uid=r.uid, tokens=out[i, :n_emitted[i]], prompt_len=plen,
                   decode_steps=steps_run)
            for i, r in enumerate(reqs)
        ]

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------
    def session(self, seed: int = 0, max_waiting: Optional[int] = None
                ) -> "ContinuousSession":
        """Open an incremental serving session (continuous mode only):
        requests join at any time (``submit``), every ``step()`` is one
        host-sync interval yielding per-request :class:`StreamEvent`
        increments — the entry point the async streaming front end
        (serve.frontend) drives.  ``max_waiting`` caps the scheduler
        wait-queue depth (``scheduler.QueueFull`` → HTTP 429)."""
        if self.mode != "continuous":
            raise RuntimeError(
                "streaming sessions need the continuous paged runtime "
                f"(engine is mode={self.mode!r})")
        return ContinuousSession(self, seed=seed, max_waiting=max_waiting)

    def _generate_continuous(self, requests: Sequence[Request], seed: int
                             ) -> List[Result]:
        session = self.session(seed=seed)
        for r in requests:
            session.submit(r)
        results: List[Result] = []
        while session.has_work():
            for ev in session.step():
                if ev.finished:
                    results.append(ev.result)
        return sorted(results, key=lambda r: r.uid)

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request], seed: int = 0
                 ) -> List[Result]:
        """Serve a set of requests (continuous batching; static mode
        buckets by prompt length).  ``self.stats`` afterwards holds the
        run's host-sync / fused-device-step / token counters."""
        # registry counters are monotonic; re-base the legacy per-run
        # stats view instead of zeroing them
        self._stats_base = self.m.snapshot()
        if self.mode == "continuous":
            results = self._generate_continuous(requests, seed)
        else:
            buckets: Dict[int, List[Request]] = {}
            for r in requests:
                buckets.setdefault(len(r.prompt), []).append(r)
            results = []
            key = jax.random.key(seed)
            for plen in sorted(buckets):
                bucket = buckets[plen]
                for i in range(0, len(bucket), self.max_batch):
                    key, bk = jax.random.split(key)
                    results.extend(self._run_bucket(
                        bucket[i:i + self.max_batch], bk))
            results = sorted(results, key=lambda r: r.uid)
        return results


class ContinuousSession:
    """Incremental, step-driven view of the continuous-batching loop.

    ``generate()`` is a batch convenience wrapper around this: a session
    accepts requests at ANY time (:meth:`submit` — the serving front
    end's admission point, wait-queue ordered by priority/deadline and
    capped by ``max_waiting``), and every :meth:`step` advances the
    engine by exactly one host-sync interval, returning the
    :class:`StreamEvent` increments — new tokens per live request,
    finish events carrying the :class:`Result` — that accrued in it.

    One sync interval = admit waiting requests into free slots, map
    page capacity (may preempt, exactly as before), then ONE device
    dispatch: either the plain K-step decode burst, or — when a prompt
    is mid-prefill — the prefill-FUSED burst (``fused.
    make_prefill_burst``): one prompt chunk, on-device token-0
    activation if it was the final chunk, and the K decode steps, all
    without an intermediate host sync.  That fusion is the sync-floor
    fix: prefill-heavy load used to clamp bursts to K=1 (one blocking
    readback per decoded token); now a chunk rides along and
    ``stats["device_steps"] / stats["host_syncs"]`` stays at the burst
    level under mixed load.

    Token streams are bit-identical to the pre-session loop: admission
    order, burst length and preemption timing can move WHEN a token is
    computed, but the per-(uid, step) key contract fixes WHICH token
    every draw yields.
    """

    def __init__(self, engine: ServeEngine, seed: int = 0,
                 max_waiting: Optional[int] = None):
        from repro.serve.scheduler import Scheduler

        self.engine = engine
        engine.pool.reset()
        # scheduler + pool + engine all write the engine's obs registry
        # — one flat (thread-safe) namespace at /stats and /metrics
        self.sched = Scheduler(engine.pool, engine.max_batch,
                               max_waiting=max_waiting,
                               swap=engine._swap_ok,
                               obs=engine.obs)
        self.base_key = jax.random.key(seed)
        self._emitted: Dict[int, int] = {}    # uid -> tokens delivered

    # ------------------------------------------------------------ intake
    def submit(self, req: Request):
        """Queue a request (join-at-prefill happens at the next step).
        Raises ``ValueError`` on a request that can never fit and
        ``scheduler.QueueFull`` past the ``max_waiting`` depth cap."""
        if len(req.prompt) + req.max_new_tokens > self.engine.max_len:
            raise ValueError(f"request {req.uid} exceeds max_len")
        return self.sched.submit(req)

    def has_work(self) -> bool:
        return self.sched.has_work()

    @property
    def depth(self) -> int:
        """Requests in flight (waiting + slotted) — the router's
        least-loaded signal."""
        return len(self.sched.waiting) + len(self.sched.running)

    # ----------------------------------------------------- event helpers
    def _event(self, seq) -> Optional[StreamEvent]:
        from repro.serve.scheduler import SeqState

        sent = self._emitted.get(seq.req.uid, 0)
        new = [int(t) for t in seq.tokens[sent:]]
        fin = seq.state is SeqState.FINISHED
        if not new and not fin:
            return None
        self._emitted[seq.req.uid] = sent + len(new)
        m = self.engine.m
        if new and sent == 0 and seq.first_tok_ts == 0.0:
            # first DELIVERED token (preemption replays are suppressed
            # above, so this fires exactly once per request)
            seq.first_tok_ts = time.monotonic()
            m.ttft.observe(seq.first_tok_ts - seq.submit_ts)
            m.obs.tracer.instant("first_token", track=m.label,
                                 args={"uid": seq.req.uid})
        result = None
        reason = None
        if fin:
            self._emitted.pop(seq.req.uid, None)
            now = time.monotonic()
            if seq.first_tok_ts and len(seq.tokens) > 1:
                m.tpot.observe((now - seq.first_tok_ts)
                               / (len(seq.tokens) - 1))
            m.obs.tracer.async_end("request", seq.req.uid, track=m.label,
                                   args={"tokens": len(seq.tokens),
                                         "preemptions": seq.preemptions})
            result = Result(uid=seq.req.uid,
                            tokens=np.asarray(seq.tokens, np.int32),
                            prompt_len=len(seq.req.prompt),
                            decode_steps=seq.occupied_steps,
                            preemptions=seq.preemptions)
            reason = ("stop" if len(seq.tokens) < seq.req.max_new_tokens
                      else "length")
        return StreamEvent(uid=seq.req.uid, tokens=new, finished=fin,
                           result=result, finish_reason=reason)

    def cancel(self, uid: int, reason: str = "cancelled"
               ) -> Optional[StreamEvent]:
        """Retire a request anywhere in its lifecycle (ISSUE-10):
        waiting, mid-prefill, mid-decode or swapped-out.  Pages, slot
        and swap-arena space are released immediately (the pool's
        ``check_invariants`` holds afterwards) and the terminal
        :class:`StreamEvent` — empty token delta, ``finish_reason`` =
        ``reason`` — is returned for delivery.  None when the uid is
        unknown (already finished, or never submitted)."""
        seq = self.sched.cancel(uid)
        if seq is None:
            return None
        m = self.engine.m
        (m.deadline_exceeded if reason == "timeout"
         else m.cancelled).inc()
        m.obs.tracer.instant("cancel", track=m.label,
                             args={"uid": uid, "reason": reason,
                                   "tokens": len(seq.tokens)})
        m.obs.tracer.async_end("request", uid, track=m.label,
                               args={"tokens": len(seq.tokens),
                                     "finish_reason": reason})
        self._emitted.pop(uid, None)
        result = Result(uid=uid,
                        tokens=np.asarray(seq.tokens, np.int32),
                        prompt_len=len(seq.req.prompt),
                        decode_steps=seq.occupied_steps,
                        preemptions=seq.preemptions)
        return StreamEvent(uid=uid, tokens=[], finished=True,
                           result=result, finish_reason=reason)

    def _expire_deadlines(self) -> List[StreamEvent]:
        """Hard-deadline sweep, run once per sync interval: every
        sequence whose ``deadline_hard`` timestamp has passed — waiting,
        swapped-out or slotted — is cancelled with
        ``finish_reason="timeout"`` (the front end's HTTP 504)."""
        now = time.monotonic()
        expired = [s.req.uid
                   for s in (*self.sched.running, *self.sched.waiting)
                   if s.req.deadline_hard and s.req.deadline is not None
                   and now >= s.req.deadline]
        return [ev for uid in expired
                if (ev := self.cancel(uid, reason="timeout")) is not None]

    # ------------------------------------------------- one sync interval
    def step(self) -> List[StreamEvent]:
        from repro.serve.scheduler import SeqState

        eng, sched, pool = self.engine, self.sched, self.engine.pool
        # 0) hard-deadline sweep: expired requests retire with a clean
        #    terminal event BEFORE any capacity they hold can shape
        #    this interval's admission (ISSUE-10)
        events: List[StreamEvent] = self._expire_deadlines()
        # 1) join-at-prefill: new requests take free slots/pages now
        #    (recurrent-state slot rows reset to the init state —
        #    stale state can't mask by length like pages do)
        for seq in sched.admit():
            if seq.req.max_new_tokens <= 0:       # nothing to emit
                sched.finish(seq)
                ev = self._event(seq)
                if ev is not None:
                    events.append(ev)
                continue
            if eng.state_pool is not None:
                pool.kv = eng.state_pool.reset_slot(pool.kv, seq.slot)
        if sched.next_prefill() is None and not sched.decoding():
            return events                          # blocked on slots/pages
        # 2) page capacity for this interval's first write (may preempt
        #    — the same single-step guarantee as the per-step loop)
        sched.ensure_decode_capacity()
        running = sched.decoding()
        pseq = sched.next_prefill()
        if pseq is None and not running:
            return events
        # 3) burst length: steps_per_sync clamped to the longest
        #    possible remaining emission and to the page capacity the
        #    pool can map WITHOUT preempting (lookahead only ever
        #    shortens the burst)
        plen = len(pseq.req.prompt) if pseq is not None else 0
        will_activate = (pseq is not None
                         and pseq.n_prefilled + eng.chunk_size >= plen)
        k = 1
        if running:
            k = min(eng.steps_per_sync,
                    max(s.req.max_new_tokens - len(s.tokens)
                        for s in running))
        can_decode = True
        if will_activate:
            k = max(k, min(eng.steps_per_sync,
                           max(1, pseq.req.max_new_tokens - 1)))
        if pseq is not None and k > 1:
            # ramp-up throttle: while MORE prompt work is queued and the
            # batch still has room, decode-ahead is a false economy — a
            # long burst burns the current (small) running set's tokens
            # at low occupancy while the prompts that would have filled
            # the batch sit waiting, so short requests serialize.  Clamp
            # to one fused decode step (still chunk+decode in ONE sync)
            # and let activations accumulate; once the batch is full —
            # the oversubscribed steady state — or this is the last
            # queued chunk, full bursts resume with the chunk riding
            # along (the sync-floor fix proper).
            chunks_left = -(-(plen - pseq.n_prefilled) // eng.chunk_size)
            backlog = (chunks_left > 1
                       or any(s is not pseq and s.state is SeqState.PREFILL
                              for s in sched.running)
                       or len(sched.waiting) > 0)
            room = (len(running) + (1 if will_activate else 0)
                    < eng.max_batch)
            if backlog and room:
                k = 1
        if will_activate:
            # the chunk is the request's last: the burst activates it on
            # device — pre-position its write head for the page math
            pseq.n_written = plen
            k, can_decode = sched.extend_with_activation(max(1, k), pseq)
        elif running:
            k = sched.extend_decode_capacity(max(1, k))
        k = max(1, k)
        # 4) ONE device dispatch for the whole interval: decode burst,
        #    with this interval's prefill chunk fused in front when a
        #    prompt is streaming in
        state = fused.init_burst_state(eng.max_batch, eng._ring)
        for s in running:
            state["tok"][s.slot] = s.tokens[-1]
            state["pos"][s.slot] = s.n_written
            state["uid"][s.slot] = s.req.uid
            state["n_tok"][s.slot] = len(s.tokens)
            state["max_new"][s.slot] = s.req.max_new_tokens
        state["steps_left"] = np.asarray(k, np.int32)
        if eng._state_shardings is not None:
            state = jax.device_put(state, eng._state_shardings)
        t0 = time.monotonic()
        if pseq is not None:
            start = pseq.n_prefilled
            chunk = np.zeros((1, eng.chunk_size), np.int32)
            piece = pseq.req.prompt[start:start + eng.chunk_size]
            chunk[0, :len(piece)] = piece
            p = {"tokens": jnp.asarray(chunk),
                 "start": jnp.asarray(start, jnp.int32),
                 "length": jnp.asarray(plen, jnp.int32),
                 "slot": jnp.asarray(pseq.slot, jnp.int32),
                 "uid": jnp.asarray(pseq.req.uid, jnp.int32),
                 "max_new": jnp.asarray(pseq.req.max_new_tokens, jnp.int32),
                 "pos0": jnp.asarray(plen if can_decode else -1, jnp.int32)}
            pool.kv, state = eng._prefill_burst(
                eng.params, pool.kv, pool.tables_device(), state,
                self.base_key, p)
            pseq.n_prefilled = min(start + eng.chunk_size, plen)
            pseq.occupied_steps += 1
            eng.m.prefill_chunks.inc()
            eng.m.slot_steps.inc()
        else:
            pool.kv, state = eng._burst(
                eng.params, pool.kv, pool.tables_device(), state,
                self.base_key)
        st = jax.device_get(state)        # the ONE host sync per interval
        t1 = time.monotonic()
        steps_run = k - int(st["steps_left"])
        eng.m.decode_wall.inc(t1 - t0)
        eng.m.host_syncs.inc()
        eng.m.device_steps.inc(steps_run)
        if eng.n_sparse_leaves:
            # every dispatch of this interval routed its packed QKV/MLP
            # projections through the compressed nm_spmm path
            eng.m.sparse_dispatch.inc()
        eng.m.burst_steps.observe(steps_run)
        eng.obs.tracer.complete(
            "prefill_burst" if pseq is not None else "decode_burst",
            t0, t1, track=eng.obs.label,
            args={"k": k, "steps": steps_run, "decoding": len(running),
                  **({"chunk_uid": int(pseq.req.uid)}
                     if pseq is not None else {})})
        # 5) advance / retire from the packed state blob
        live = list(running)
        if will_activate:
            pseq.state = SeqState.RUNNING
            live.append(pseq)
            if pool.prefix is not None:
                # the prompt's full pages are now written and immutable
                # (decode writes land past them) — index them so the
                # next identical prefix attaches instead of prefilling
                pool.prefix.register(pseq.req.prompt,
                                     pool.slot_pages(pseq.slot))
        for s in live:
            n = int(st["n_out"][s.slot])
            if n:
                s.tokens.extend(int(t) for t in st["out"][s.slot, :n])
                # the activated request's token 0 rode the chunk — only
                # its remaining n-1 tokens took decode writes
                adv = n - 1 if (will_activate and s is pseq) else n
                s.n_written += adv
                s.occupied_steps += adv
                eng.m.slot_steps.inc(adv)
            if bool(st["done"][s.slot]):
                if pool.prefix is not None:
                    # retirement: index the generated continuation too
                    # (full pages + the partial tail as a CoW source).
                    # KV covers positions < n_written — the final
                    # sampled token never wrote its entry
                    kv_toks = np.concatenate([
                        np.asarray(s.req.prompt, np.int32),
                        np.asarray(s.tokens, np.int32)])[:s.n_written]
                    pool.prefix.register(kv_toks, pool.slot_pages(s.slot),
                                         include_partial=True)
                sched.finish(s)
            ev = self._event(s)
            if ev is not None:
                events.append(ev)
        eng.m.tokens.inc(sum(len(e.tokens) for e in events))
        return events
