"""Asyncio HTTP front end: OpenAI-style completions over SSE.

Stdlib only (asyncio + hand-rolled HTTP/1.1 — no new deps).  Endpoints:

  POST /v1/completions   JSON :class:`protocol.CompletionRequest`.
                         ``"stream": true`` answers ``text/event-stream``
                         — one ``data:`` frame per engine sync interval
                         carrying that request's NEW tokens, then a
                         terminal frame (``finished``) and ``[DONE]``.
                         Otherwise a single JSON
                         :class:`protocol.CompletionResponse`.
  GET  /healthz          router health {replica: {healthy, load}}.
  GET  /stats            per-replica engine counters, plus a
                         ``_summary`` block of TTFT/TPOT/queue-wait
                         aggregates derived from the obs registry's
                         histograms (docs/observability.md).
  GET  /metrics          Prometheus text exposition of every serve
                         series (counters, gauges, histograms) across
                         all replica registries — the scrape endpoint.

Status mapping: scheduler ``QueueFull`` → **429** (backpressure — the
wait queue is at its depth cap; retry later), validation → 400,
unknown route → 404, draining → 503, every replica down → **503 with a
``Retry-After`` hint** (transient while the supervisor restarts
workers), hard deadline exceeded → **504** with
``finish_reason="timeout"`` (non-streaming; a stream carries the
reason on its terminal chunk).

Cancellation (ISSUE-10): each completion handler watches its client
connection for EOF while it waits on engine events; a client that
disconnects mid-stream triggers ``router.cancel(uid)``, which retires
the sequence at any phase and frees its KV pages immediately — no
orphaned decode burning pool capacity.

Streaming bridge: the replica worker thread fires per-request callbacks
(`replica.py`); the handler wraps each in ``loop.call_soon_threadsafe``
pushing onto an ``asyncio.Queue`` the response writer awaits — tokens
hit the wire the same sync interval the device reports them.  Responses
set ``Connection: close`` (stream length is unknown up front; clients
read to EOF).

``Server.shutdown`` drains the router (finish in flight, refuse new)
before closing the listener — the CLI's SIGINT path.
"""

from __future__ import annotations

import asyncio
import json
from typing import Dict, Optional, Tuple

from repro.serve.engine import StreamEvent
from repro.serve.frontend.protocol import (SSE_DONE, CompletionChunk,
                                           CompletionRequest,
                                           CompletionResponse, sse_encode)
from repro.serve.frontend.replica import ReplicaDraining
from repro.serve.frontend.router import NoHealthyReplicas, Router
from repro.serve.scheduler import QueueFull

_MAX_BODY = 8 << 20


def _response(status: int, body: bytes,
              ctype: str = "application/json",
              headers: Optional[Dict[str, str]] = None) -> bytes:
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              429: "Too Many Requests", 500: "Internal Server Error",
              503: "Service Unavailable", 504: "Gateway Timeout"}
    extra = "".join(f"{k}: {v}\r\n" for k, v in (headers or {}).items())
    return (f"HTTP/1.1 {status} {reason.get(status, 'Error')}\r\n"
            f"Content-Type: {ctype}\r\n"
            f"Content-Length: {len(body)}\r\n{extra}"
            f"Connection: close\r\n\r\n").encode() + body


def _error(status: int, msg: str,
           headers: Optional[Dict[str, str]] = None) -> bytes:
    return _response(status, json.dumps({"error": msg}).encode(),
                     headers=headers)


class Server:
    def __init__(self, router: Router, host: str = "127.0.0.1",
                 port: int = 0):
        self.router = router
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    # --------------------------------------------------------- lifecycle
    async def start(self) -> Tuple[str, int]:
        """Bind and start accepting; returns (host, port) — port 0 in
        the constructor picks a free one (tests/CI)."""
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self.host, self.port

    async def serve_forever(self) -> None:
        if self._server is None:
            await self.start()
        async with self._server:
            await self._server.serve_forever()

    async def shutdown(self, timeout: Optional[float] = 30.0) -> None:
        """Drain-on-shutdown: refuse new requests, let in-flight ones
        finish streaming, then close the listener."""
        await asyncio.get_running_loop().run_in_executor(
            None, lambda: self.router.drain(timeout=timeout))
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()

    # ------------------------------------------------------------- HTTP
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            line = await reader.readline()
            parts = line.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, path = parts[0], parts[1]
            headers: Dict[str, str] = {}
            while True:
                h = await reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
                k, _, v = h.decode("latin-1").partition(":")
                headers[k.strip().lower()] = v.strip()
            clen = int(headers.get("content-length", "0"))
            body = await reader.readexactly(min(clen, _MAX_BODY))

            if method == "POST" and path == "/v1/completions":
                await self._completions(body, reader, writer)
            elif method == "GET" and path == "/healthz":
                writer.write(_response(
                    200, json.dumps(self.router.health()).encode()))
            elif method == "GET" and path == "/stats":
                stats = self.router.stats()
                stats["_summary"] = self.router.summary()
                writer.write(_response(200, json.dumps(stats).encode()))
            elif method == "GET" and path == "/metrics":
                writer.write(_response(
                    200, self.router.metrics_text().encode(),
                    ctype="text/plain; version=0.0.4"))
            else:
                writer.write(_error(404, f"no route {method} {path}"))
            await writer.drain()
        except (asyncio.IncompleteReadError, ConnectionError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except ConnectionError:
                pass

    # ------------------------------------------------------ completions
    async def _completions(self, body: bytes,
                           reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            creq = CompletionRequest.from_json(body)
        except ValueError as e:
            writer.write(_error(400, str(e)))
            return

        loop = asyncio.get_running_loop()
        q: asyncio.Queue = asyncio.Queue()

        def on_event(ev: StreamEvent) -> None:   # replica worker thread
            loop.call_soon_threadsafe(q.put_nowait, ev)

        uid = self.router.assign_uid(creq)
        try:
            rep = self.router.submit(creq, on_event, uid=uid)
        except QueueFull as e:
            writer.write(_error(429, str(e)))
            return
        except ReplicaDraining:
            writer.write(_error(503, "server is draining"))
            return
        except NoHealthyReplicas as e:
            writer.write(_error(
                503, str(e),
                headers={"Retry-After":
                         str(max(1, int(round(e.retry_after_s))))}))
            return
        except ValueError as e:
            writer.write(_error(400, str(e)))
            return

        # client-disconnect watcher (ISSUE-10): the request body is
        # fully read and responses are Connection: close, so the next
        # byte a well-behaved client sends is EOF — reader.read()
        # returning means the peer hung up and we cancel the request,
        # freeing its pages instead of decoding into the void.
        eof_task = asyncio.ensure_future(reader.read())

        async def next_event() -> Optional[StreamEvent]:
            """Engine event, or None on client disconnect."""
            get = asyncio.ensure_future(q.get())
            done, _ = await asyncio.wait(
                {get, eof_task}, return_when=asyncio.FIRST_COMPLETED)
            if get in done:
                return get.result()
            get.cancel()
            return None

        try:
            if creq.stream:
                writer.write(b"HTTP/1.1 200 OK\r\n"
                             b"Content-Type: text/event-stream\r\n"
                             b"Cache-Control: no-cache\r\n"
                             b"Connection: close\r\n\r\n")
                await writer.drain()
                while True:
                    ev = await next_event()
                    if ev is None:
                        self.router.cancel(uid)
                        return
                    writer.write(sse_encode(CompletionChunk(
                        uid=ev.uid, tokens=ev.tokens, finished=ev.finished,
                        finish_reason=ev.finish_reason)))
                    await writer.drain()  # per-interval flush: tokens
                    if ev.finished:       # stream as they decode
                        break
                writer.write(SSE_DONE)
            else:
                while True:
                    ev = await next_event()
                    if ev is None:
                        self.router.cancel(uid)
                        return
                    if ev.finished:
                        break
                if ev.finish_reason == "timeout":
                    writer.write(_error(
                        504, f"deadline exceeded for request {uid}"))
                    return
                resp = CompletionResponse.from_result(
                    ev.result, replica=rep.name,
                    finish_reason=ev.finish_reason)
                writer.write(
                    _response(200, json.dumps(resp.to_json()).encode()))
        except ConnectionError:
            # write-side failure is the same client disconnect
            self.router.cancel(uid)
            raise
        finally:
            eof_task.cancel()


async def run_server(router: Router, host: str = "127.0.0.1",
                     port: int = 8000) -> None:
    """CLI entry: serve until cancelled, then drain."""
    srv = Server(router, host, port)
    await srv.start()
    print(f"serving on http://{srv.host}:{srv.port}  "
          f"(replicas: {[r.name for r in router.replicas]})")
    try:
        await srv.serve_forever()
    except asyncio.CancelledError:
        pass
    finally:
        await srv.shutdown()
