"""Least-loaded router over N data-parallel ServeEngine replicas.

Topology (docs/serving_frontend.md): every replica holds a full copy of
the (pruned) model and its own paged KV pool / session / worker thread;
the router owns uid assignment and dispatch.  Dispatch is least-loaded
over HEALTHY replicas (ties broken by replica order, so a single
replica degenerates to plain pass-through); a replica whose wait queue
is at its depth cap makes ``submit`` raise ``QueueFull`` and the router
fails over to the next-least-loaded one, raising only when EVERY
healthy replica is full — that terminal ``QueueFull`` is the server's
429.

Parity contract: replicas are built with one shared seed, and sampling
is keyed per (uid, step) inside the engine — a request's token stream
is bit-identical no matter which replica serves it, so least-loaded
placement is purely a latency decision.

``drain()`` is the rolling-shutdown primitive: stop intake everywhere,
wait for in-flight requests to finish, park the workers.
"""

from __future__ import annotations

import itertools
import random
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.obs.metrics import merge_histograms
from repro.serve.engine import Request, StreamEvent
from repro.serve.frontend.protocol import (CompletionRequest,
                                           CompletionResponse,
                                           to_engine_request)
from repro.serve.frontend.replica import Replica, ReplicaDraining
from repro.serve.scheduler import QueueFull


class NoHealthyReplicas(RuntimeError):
    """Every replica is down (crashed/stalled, none merely draining) —
    transient while the supervisor restarts workers, so the server
    surfaces it as HTTP 503 with a ``Retry-After`` hint instead of a
    500-shaped handler crash (ISSUE-10 satellite)."""

    retry_after_s: float = 1.0


class Router:
    def __init__(self, replicas: List[Replica],
                 submit_retries: int = 0,
                 retry_backoff_s: float = 0.05):
        if not replicas:
            raise ValueError("router needs at least one replica")
        self.replicas = list(replicas)
        # bounded jittered-backoff retries (ISSUE-10): how many times
        # submit re-sweeps the replicas when every one is transiently
        # full/draining/down — 0 keeps the original fail-fast behavior
        # (QueueFull -> 429); the supervisor's failover re-submission
        # passes its own budget to ride out the restart window
        self.submit_retries = submit_retries
        self.retry_backoff_s = retry_backoff_s
        self._uids = itertools.count()
        self._uid_lock = threading.Lock()

    # --------------------------------------------------------- dispatch
    def _candidates(self) -> List[Replica]:
        up = [r for r in self.replicas if r.healthy]
        if not up:
            if any(r.draining for r in self.replicas):
                raise ReplicaDraining("all replicas draining")
            raise NoHealthyReplicas("no healthy replicas")
        return sorted(up, key=lambda r: r.load)

    def assign_uid(self, creq: CompletionRequest) -> int:
        if creq.uid is not None:
            return creq.uid
        with self._uid_lock:
            return next(self._uids)

    def submit(self, creq: CompletionRequest,
               on_event: Callable[[StreamEvent], None],
               uid: Optional[int] = None,
               retries: Optional[int] = None) -> Replica:
        """Place one wire request on the least-loaded healthy replica,
        failing over across full ones.  Returns the replica that took
        it; raises ``QueueFull`` when every healthy replica is at its
        depth cap (HTTP 429), :class:`NoHealthyReplicas` when none is
        up (HTTP 503) and ``ValueError`` on an unservable request."""
        if uid is None:
            uid = self.assign_uid(creq)
        return self.submit_request(to_engine_request(creq, uid), on_event,
                                   retries=retries)

    def submit_request(self, req: Request,
                       on_event: Callable[[StreamEvent], None],
                       retries: Optional[int] = None) -> Replica:
        """Engine-level submit (the supervisor's failover entry): sweep
        the healthy replicas least-loaded-first, and on a fully
        full/draining/down sweep retry up to ``retries`` times with
        bounded jittered exponential backoff — transient windows during
        a crash/restart (ISSUE-10) resolve instead of bouncing the
        request.  ``retries=None`` uses the router default (0)."""
        if retries is None:
            retries = self.submit_retries
        attempt = 0
        while True:
            last: Optional[Exception] = None
            try:
                cands = self._candidates()
            except (NoHealthyReplicas, ReplicaDraining) as e:
                cands, last = [], e
            for rep in cands:
                try:
                    rep.submit(req, on_event)
                    return rep
                except (QueueFull, ReplicaDraining) as e:
                    last = e
            if attempt >= retries:
                if not cands:       # nobody to even try: typed signal
                    raise last      # (503 / draining) straight through
                raise QueueFull(f"all replicas at capacity ({last})")
            attempt += 1
            # jittered exponential backoff, capped at 1s per wait
            delay = min(1.0, self.retry_backoff_s * (2 ** (attempt - 1)))
            time.sleep(delay * (0.5 + 0.5 * random.random()))

    def cancel(self, uid: int, reason: str = "cancelled") -> bool:
        """Cancel an in-flight request wherever it landed (after a
        failover that may not be the replica that first took it) —
        the server's client-disconnect path.  False when no replica
        knows the uid (already finished)."""
        return any(r.cancel(uid, reason=reason) for r in self.replicas)

    # ----------------------------------------------------- batch client
    def complete(self, creqs: List[CompletionRequest]
                 ) -> List[CompletionResponse]:
        """Blocking batch entry point (the CLI's code path): stream all
        requests through the replicas, return terminal responses in uid
        order."""
        done = threading.Event()
        out: Dict[int, CompletionResponse] = {}
        lock = threading.Lock()
        names: Dict[int, str] = {}
        remaining = len(creqs)
        if not remaining:
            return []

        def make_cb(uid: int):
            def cb(ev: StreamEvent) -> None:
                nonlocal remaining
                if not ev.finished:
                    return
                with lock:
                    out[uid] = CompletionResponse.from_result(
                        ev.result, replica=names.get(uid))
                    remaining -= 1
                    if remaining == 0:
                        done.set()
            return cb

        for creq in creqs:
            uid = self.assign_uid(creq)
            rep = self.submit(creq, make_cb(uid), uid=uid)
            names[uid] = rep.name
        done.wait()
        return [out[k] for k in sorted(out)]

    # --------------------------------------------------------- lifecycle
    def health(self) -> Dict[str, Dict[str, float]]:
        return {r.name: {"healthy": r.healthy, "load": r.load}
                for r in self.replicas}

    def stats(self) -> Dict[str, Dict[str, float]]:
        return {r.name: r.stats() for r in self.replicas}

    # ----------------------------------------------------- observability
    def registries(self) -> List:
        """The distinct enabled metrics registries behind the replicas
        — ONE when the launcher shares a bundle across replicas (each
        replica then writes its own ``replica``-labelled children), one
        per replica when engines were built independently."""
        regs: List = []
        for r in self.replicas:
            reg = r.engine.obs.metrics
            if reg.enabled and all(reg is not x for x in regs):
                regs.append(reg)
        return regs

    def metrics_text(self) -> str:
        """Prometheus text exposition across every replica registry —
        the body of the server's ``GET /metrics``."""
        return "".join(reg.render() for reg in self.registries())

    def summary(self) -> Dict[str, float]:
        """Request-latency aggregates derived from the registry's
        histograms (all replicas merged) — the ``_summary`` block on
        the trace-enriched ``/stats``."""
        out: Dict[str, float] = {}
        regs = self.registries()
        for key, name in (("ttft", "serve_ttft_seconds"),
                          ("tpot", "serve_tpot_seconds"),
                          ("queue_wait", "serve_queue_wait_seconds")):
            fams = [f for f in (reg.get(name) for reg in regs)
                    if f is not None]
            h = merge_histograms(fams)
            if h is None or h.count == 0:
                continue
            out[f"{key}_count"] = h.count
            out[f"{key}_ms_p50"] = h.quantile(0.5) * 1e3
            out[f"{key}_ms_p95"] = h.quantile(0.95) * 1e3
        return out

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Stop intake on every replica, then wait for all in-flight
        work to finish.  True only if every replica went idle."""
        ok = True
        for r in self.replicas:
            ok = r.drain(timeout=timeout) and ok
        return ok

    def close(self) -> None:
        for r in self.replicas:
            r.close()
