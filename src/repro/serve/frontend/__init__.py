"""Async serving front end over the continuous-batching engine.

  protocol   wire objects: CompletionRequest/Chunk/Response + SSE
             framing — shared by the HTTP server AND the batch CLI
  replica    one ServeEngine session on a worker thread: thread-safe
             submit, callback token delivery, drain/health/load
  router     least-loaded dispatch over N data-parallel replicas,
             QueueFull failover, bounded-backoff retries,
             drain-on-shutdown
  server     stdlib-asyncio HTTP/1.1: POST /v1/completions (JSON or
             SSE streaming), /healthz, /stats; 429 backpressure,
             client-disconnect cancellation, 503 + Retry-After, 504
             deadline mapping
  supervisor replica crash/stall detection, worker restart, and
             in-flight failover with replay suppression

See docs/serving_frontend.md for the API surface and contracts
(including the failure model).
"""

from repro.serve.frontend.protocol import (CompletionChunk,
                                           CompletionRequest,
                                           CompletionResponse, sse_decode,
                                           sse_encode, to_engine_request)
from repro.serve.frontend.replica import Replica, ReplicaDraining
from repro.serve.frontend.router import NoHealthyReplicas, Router
from repro.serve.frontend.server import Server, run_server
from repro.serve.frontend.supervisor import Supervisor

__all__ = [
    "CompletionChunk",
    "CompletionRequest",
    "CompletionResponse",
    "NoHealthyReplicas",
    "Replica",
    "ReplicaDraining",
    "Router",
    "Server",
    "Supervisor",
    "run_server",
    "sse_decode",
    "sse_encode",
    "to_engine_request",
]
