"""Async serving front end over the continuous-batching engine.

  protocol   wire objects: CompletionRequest/Chunk/Response + SSE
             framing — shared by the HTTP server AND the batch CLI
  replica    one ServeEngine session on a worker thread: thread-safe
             submit, callback token delivery, drain/health/load
  router     least-loaded dispatch over N data-parallel replicas,
             QueueFull failover, drain-on-shutdown
  server     stdlib-asyncio HTTP/1.1: POST /v1/completions (JSON or
             SSE streaming), /healthz, /stats; 429 backpressure

See docs/serving_frontend.md for the API surface and contracts.
"""

from repro.serve.frontend.protocol import (CompletionChunk,
                                           CompletionRequest,
                                           CompletionResponse, sse_decode,
                                           sse_encode, to_engine_request)
from repro.serve.frontend.replica import Replica, ReplicaDraining
from repro.serve.frontend.router import Router
from repro.serve.frontend.server import Server, run_server

__all__ = [
    "CompletionChunk",
    "CompletionRequest",
    "CompletionResponse",
    "Replica",
    "ReplicaDraining",
    "Router",
    "Server",
    "run_server",
    "sse_decode",
    "sse_encode",
    "to_engine_request",
]
