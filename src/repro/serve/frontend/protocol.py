"""Wire objects for the serving front end (docs/serving_frontend.md).

OpenAI-completions-shaped, minus a tokenizer: the repo has none, so
``prompt`` is a token-id array (the OpenAI API accepts exactly that
form) and responses carry token ids.  One set of request/response
objects serves every entry point — the HTTP server, the router, and
``launch/serve.py``'s batch path — so there is no parallel prompt-list
plumbing to drift.

``CompletionRequest.deadline_ms`` is a *relative* SLA budget (ms from
arrival); :func:`to_engine_request` converts it to the absolute
``time.monotonic()`` timestamp ``serve.scheduler`` orders admission by.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serve.engine import Request, Result


@dataclasses.dataclass
class CompletionRequest:
    """One completion call, as posted to ``/v1/completions``."""

    prompt: List[int]                    # token ids (no tokenizer in repo)
    max_tokens: int = 16
    stream: bool = False
    priority: int = 0                    # higher admits first
    deadline_ms: Optional[float] = None  # SLA budget relative to arrival
    uid: Optional[int] = None            # client-chosen id; router assigns
    #                                      a fresh one when omitted

    @classmethod
    def from_json(cls, body: bytes) -> "CompletionRequest":
        try:
            obj = json.loads(body)
        except (ValueError, UnicodeDecodeError) as e:
            raise ValueError(f"body is not valid JSON: {e}") from None
        if not isinstance(obj, dict):
            raise ValueError("body must be a JSON object")
        prompt = obj.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) for t in prompt)):
            raise ValueError("'prompt' must be a non-empty list of "
                             "token ids (ints)")
        req = cls(
            prompt=prompt,
            max_tokens=int(obj.get("max_tokens", 16)),
            stream=bool(obj.get("stream", False)),
            priority=int(obj.get("priority", 0)),
            deadline_ms=(float(obj["deadline_ms"])
                         if obj.get("deadline_ms") is not None else None),
            uid=(int(obj["uid"]) if obj.get("uid") is not None else None),
        )
        if req.max_tokens < 1:
            raise ValueError("'max_tokens' must be >= 1")
        return req


def to_engine_request(creq: CompletionRequest, uid: int,
                      now: Optional[float] = None) -> Request:
    """Lower a wire request to the engine's :class:`Request`, pinning
    the relative ``deadline_ms`` to an absolute monotonic timestamp at
    admission time.  A wire deadline is HARD (ISSUE-10): past it the
    engine retires the request with ``finish_reason="timeout"`` — the
    server's HTTP 504 — instead of silently truncating."""
    if now is None:
        now = time.monotonic()
    return Request(
        uid=uid,
        prompt=np.asarray(creq.prompt, np.int32),
        max_new_tokens=creq.max_tokens,
        priority=creq.priority,
        deadline=(now + creq.deadline_ms / 1e3
                  if creq.deadline_ms is not None else None),
        deadline_hard=creq.deadline_ms is not None,
    )


@dataclasses.dataclass
class CompletionChunk:
    """One SSE event: the NEW tokens a request accrued at one engine
    sync (never a replay — the session dedups preemption recompute)."""

    uid: int
    tokens: List[int]
    finished: bool = False
    finish_reason: Optional[str] = None   # stop|length|timeout|cancelled
    #                                       on the terminal chunk

    def to_json(self) -> Dict[str, Any]:
        return {"id": self.uid, "object": "completion.chunk",
                "tokens": self.tokens, "finished": self.finished,
                "finish_reason": self.finish_reason}


@dataclasses.dataclass
class CompletionResponse:
    """Terminal response (non-streaming call, or the summary a client
    can reassemble from its chunks)."""

    uid: int
    tokens: List[int]
    prompt_len: int
    decode_steps: int = 0
    preemptions: int = 0
    replica: Optional[str] = None        # which replica served it
    finish_reason: Optional[str] = None  # stop|length|timeout|cancelled

    @classmethod
    def from_result(cls, r: Result, replica: Optional[str] = None,
                    finish_reason: Optional[str] = None
                    ) -> "CompletionResponse":
        return cls(uid=r.uid, tokens=[int(t) for t in r.tokens],
                   prompt_len=r.prompt_len, decode_steps=r.decode_steps,
                   preemptions=r.preemptions, replica=replica,
                   finish_reason=finish_reason)

    def to_json(self) -> Dict[str, Any]:
        return {"id": self.uid, "object": "completion",
                "tokens": self.tokens, "prompt_len": self.prompt_len,
                "decode_steps": self.decode_steps,
                "preemptions": self.preemptions, "replica": self.replica,
                "finish_reason": self.finish_reason}


# ---------------------------------------------------------------- SSE
SSE_DONE = b"data: [DONE]\n\n"


def sse_encode(chunk: CompletionChunk) -> bytes:
    """One server-sent event frame (``data: <json>\\n\\n``)."""
    return b"data: " + json.dumps(chunk.to_json()).encode() + b"\n\n"


def sse_decode(stream: bytes) -> List[CompletionChunk]:
    """Parse a full SSE byte stream back into chunks (test/client
    helper; stops at the ``[DONE]`` sentinel)."""
    chunks: List[CompletionChunk] = []
    for frame in stream.split(b"\n\n"):
        frame = frame.strip()
        if not frame.startswith(b"data: "):
            continue
        payload = frame[len(b"data: "):]
        if payload == b"[DONE]":
            break
        obj = json.loads(payload)
        chunks.append(CompletionChunk(
            uid=obj["id"], tokens=obj["tokens"], finished=obj["finished"],
            finish_reason=obj.get("finish_reason")))
    return chunks
