"""One serving replica: a ContinuousSession driven by a worker thread.

The engine's step loop is synchronous and device-bound; the HTTP server
is an asyncio event loop.  A :class:`Replica` bridges them with the
smallest possible surface: a dedicated worker thread owns the session
and runs ``step()`` whenever there is work, and every public method is
safe to call from any thread (one mutex guards the scheduler state; the
worker holds it across a step, so a concurrent ``submit`` lands between
sync intervals — exactly where the engine admits anyway).

Delivery is callback-based: ``submit(req, on_event)`` registers a
per-request callback that the WORKER thread invokes with each
:class:`StreamEvent` (new tokens only — the session already suppresses
preemption replays).  The asyncio server wraps its callback with
``loop.call_soon_threadsafe``; the batch path just appends to a list.

Backpressure is synchronous: ``submit`` raises ``scheduler.QueueFull``
in the caller's thread when the wait queue is at its depth cap, so the
server can answer 429 without a round trip through the worker.

Lifecycle: a replica is born accepting.  ``drain()`` stops intake
(``ReplicaDraining`` on submit) but finishes everything in flight, then
parks the worker — the router's rolling-shutdown building block.
``close()`` abandons in-flight work (tests / hard shutdown only).

Fault tolerance (ISSUE-10): a worker that dies — an engine-step raise,
an injected ``serve.faults`` failure — is captured in :attr:`crashed`
instead of vanishing silently, and ``healthy`` goes False (thread dead,
or stalled past ``stall_s``).  The supervisor's recovery pair is
:meth:`take_inflight` (snapshot the per-request event log: engine
request + tokens already handed to delivery) and :meth:`restart`
(rebuild the session — which resets the shared pool — and start a
fresh worker generation; a stalled previous worker exits at its next
loop check and can no longer deliver into the new generation's
subscriptions).  Per-request delivered-token counts are what failover
replay-suppression trims, so a re-submitted request's client stream
continues exactly where it stopped.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

from repro.serve.engine import Request, ServeEngine, StreamEvent
from repro.serve.faults import FaultError
from repro.serve.frontend.protocol import (CompletionRequest,
                                           CompletionResponse,
                                           to_engine_request)

# a replica whose worker hasn't completed a step (or an idle check) in
# this long while work is pending is reported unhealthy
HEALTH_STALL_S = 60.0

_UNSET = object()   # distinguishes "use engine.config" from explicit None


class ReplicaDraining(RuntimeError):
    """Raised by :meth:`Replica.submit` after :meth:`Replica.drain` —
    the replica finishes in-flight work but accepts nothing new."""


class Replica:
    def __init__(self, engine: ServeEngine, name: str = "r0",
                 seed: int = 0, max_waiting=_UNSET,
                 stall_s: float = HEALTH_STALL_S):
        # NOTE: router parity contract — every replica must be built
        # with the same seed, so a request's stream is bit-identical
        # regardless of which replica serves it (per-(uid, step) keys).
        #
        # max_waiting defaults to the engine's ServeConfig.queue_depth —
        # one knob surface; pass an explicit value (or None = unbounded)
        # to override per replica.
        if max_waiting is _UNSET:
            max_waiting = engine.config.queue_depth
        self.name = name
        self.engine = engine
        self._seed = seed
        self._max_waiting = max_waiting
        self.stall_s = stall_s
        self.session = engine.session(seed=seed, max_waiting=max_waiting)
        # health/queue-depth gauges: callback-backed, evaluated at
        # /metrics collection time (no writes from the worker loop)
        m = engine.m
        m.queue_depth.set_fn(lambda: self.session.depth)
        m.replica_healthy.set_fn(lambda: 1.0 if self.healthy else 0.0)
        if engine.pool is not None:
            m.free_pages.set_fn(lambda: engine.pool.free_pages)
        self._lock = threading.Lock()
        self._subs: Dict[int, Callable[[StreamEvent], None]] = {}
        # the per-request event log (ISSUE-10 failover): the engine
        # request plus how many tokens were already handed to delivery
        # — what take_inflight() snapshots for re-submission and what
        # replay-suppression trims on the failed-over stream
        self._inflight: Dict[int, Request] = {}
        self._delivered: Dict[int, int] = {}
        self._wake = threading.Event()
        self._idle = threading.Event()
        self._idle.set()
        self._draining = False
        self._closed = False
        self.crashed: Optional[BaseException] = None
        self._gen = 0            # worker generation (restart fencing)
        self.last_step = time.monotonic()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"replica-{name}")
        self._thread.start()

    # ------------------------------------------------------------ intake
    def submit(self, req: Request,
               on_event: Callable[[StreamEvent], None]) -> None:
        """Queue a request; ``on_event`` fires from the worker thread
        with each incremental :class:`StreamEvent`.  Raises
        ``QueueFull`` (depth cap), ``ValueError`` (can never fit) or
        :class:`ReplicaDraining` — all synchronously."""
        if self._draining or self._closed:
            raise ReplicaDraining(f"replica {self.name} is draining")
        with self._lock:
            if req.uid in self._subs:
                raise ValueError(f"uid {req.uid} already in flight")
            self.session.submit(req)     # may raise QueueFull/ValueError
            self._subs[req.uid] = on_event
            self._inflight[req.uid] = req
        self._idle.clear()
        self._wake.set()

    @property
    def load(self) -> int:
        """Requests in flight (the router's least-loaded signal)."""
        return self.session.depth

    @property
    def draining(self) -> bool:
        return self._draining

    @property
    def healthy(self) -> bool:
        """Worker alive and not stalled mid-step."""
        if self._closed or not self._thread.is_alive():
            return False
        return time.monotonic() - self.last_step < self.stall_s

    def stats(self) -> Dict[str, float]:
        # ``engine.stats`` is a property assembled from the obs
        # registry's atomic counters — reading it here (server thread)
        # no longer races the worker thread's increments (ISSUE-8; the
        # old per-engine dict was mutated mid-read)
        return self.engine.stats

    # ------------------------------------------------------------ worker
    def _run(self) -> None:
        gen = self._gen
        faults = self.engine.faults
        try:
            while not self._closed and gen == self._gen:
                if faults is not None and faults.hit(
                        "replica_worker", self.name):
                    raise FaultError(
                        f"injected replica_worker death ({self.name})")
                with self._lock:
                    if gen != self._gen:   # restarted under the lock wait
                        return
                    busy = self.session.has_work()
                    events: List[StreamEvent] = (self.session.step()
                                                 if busy else [])
                    subs = [(self._subs.get(ev.uid), ev) for ev in events]
                    for ev in events:
                        # delivered-token accounting happens at the
                        # hand-off to delivery: once recorded here the
                        # tokens are the client's, and a later failover
                        # replay suppresses exactly this many
                        if ev.finished:
                            self._subs.pop(ev.uid, None)
                            self._inflight.pop(ev.uid, None)
                            self._delivered.pop(ev.uid, None)
                        elif ev.tokens:
                            self._delivered[ev.uid] = (
                                self._delivered.get(ev.uid, 0)
                                + len(ev.tokens))
                self.last_step = time.monotonic()
                for cb, ev in subs:
                    if cb is not None:
                        cb(ev)
                if not busy:
                    self._idle.set()
                    self._wake.wait(timeout=0.05)
                    self._wake.clear()
        except BaseException as e:          # worker death (ISSUE-10):
            # capture instead of vanishing — healthy goes False (dead
            # thread) and the supervisor drives restart + failover
            self.crashed = e
            self.engine.obs.tracer.instant(
                "replica_crash", track=self.engine.obs.label,
                args={"replica": self.name, "error": repr(e)})

    # ---------------------------------------------------- fault recovery
    def cancel(self, uid: int, reason: str = "cancelled") -> bool:
        """Retire one in-flight request (client disconnect / explicit
        cancel, ISSUE-10): the session releases its pages/slot/swap
        immediately and the terminal event (``finish_reason`` =
        ``reason``) is delivered to the subscriber if one is still
        registered.  False when the uid is unknown here."""
        with self._lock:
            ev = self.session.cancel(uid, reason=reason)
            if ev is None:
                return False
            cb = self._subs.pop(uid, None)
            self._inflight.pop(uid, None)
            self._delivered.pop(uid, None)
        if cb is not None:
            cb(ev)
        return True

    def take_inflight(self):
        """Snapshot and clear the in-flight registrations — the
        supervisor's failover intake after a crash.  Returns
        ``[(engine_request, tokens_already_delivered, on_event), ...]``
        in uid order; afterwards this replica owns none of them."""
        with self._lock:
            out = [(self._inflight[uid], self._delivered.get(uid, 0),
                    self._subs.get(uid))
                   for uid in sorted(self._inflight)]
            self._inflight.clear()
            self._subs.clear()
            self._delivered.clear()
        return out

    def restart(self) -> None:
        """Rebuild the session (resetting the pool) and start a fresh
        worker generation — the supervisor's recovery step after
        :meth:`take_inflight`.  A merely-stalled previous worker is
        given a short grace to finish its step; either way the
        generation bump fences it out of the new session (it exits at
        its next loop check, and its late events find no subscribers)."""
        self._gen += 1
        old = self._thread
        if old.is_alive():
            old.join(timeout=2.0)
        self.crashed = None
        self.session = self.engine.session(seed=self._seed,
                                           max_waiting=self._max_waiting)
        self._subs = {}
        self._inflight = {}
        self._delivered = {}
        self._draining = False
        self._closed = False
        self._idle.set()
        self.last_step = time.monotonic()
        self.engine.m.replica_restarts.inc()
        self.engine.obs.tracer.instant(
            "replica_restart", track=self.engine.obs.label,
            args={"replica": self.name})
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"replica-{self.name}")
        self._thread.start()

    # --------------------------------------------------------- lifecycle
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Stop intake, finish in-flight requests, park the worker.
        Returns True once idle (False on timeout — work still live)."""
        self._draining = True
        self._wake.set()
        done = self._idle.wait(timeout=timeout)
        if done:
            self._closed = True
            self._wake.set()
            self._thread.join(timeout=5.0)
        return done

    def close(self) -> None:
        """Hard stop: the worker exits after its current step; in-flight
        requests are abandoned (their callbacks never complete)."""
        self._draining = True
        self._closed = True
        self._wake.set()
        self._thread.join(timeout=5.0)

    # ----------------------------------------------------- batch client
    def complete(self, creqs: List[CompletionRequest],
                 uid_start: int = 0) -> List[CompletionResponse]:
        """Blocking convenience used by the batch CLI path: run wire
        requests through the SAME submit/stream machinery the server
        uses and collect terminal responses (uid order)."""
        done = threading.Event()
        out: Dict[int, CompletionResponse] = {}
        remaining = len(creqs)
        lock = threading.Lock()

        def make_cb(uid: int):
            def cb(ev: StreamEvent) -> None:
                nonlocal remaining
                if not ev.finished:
                    return
                with lock:
                    out[uid] = CompletionResponse.from_result(
                        ev.result, replica=self.name)
                    remaining -= 1
                    if remaining == 0:
                        done.set()
            return cb

        for i, creq in enumerate(creqs):
            uid = creq.uid if creq.uid is not None else uid_start + i
            self.submit(to_engine_request(creq, uid), make_cb(uid))
        done.wait()
        return [out[k] for k in sorted(out)]
