"""Replica supervision: crash/stall detection, worker restart, and
in-flight failover (the ISSUE-10 tentpole).

The :class:`Supervisor` watches a :class:`~repro.serve.frontend.router.
Router`'s replicas.  When one goes unhealthy — worker thread dead (an
engine-step raise, an injected ``serve.faults`` failure) or stalled
past the replica's ``stall_s`` — recovery is three deterministic steps:

  1. **snapshot** the dead replica's in-flight requests and their
     delivered-token counts (:meth:`Replica.take_inflight` — the
     per-request event log);
  2. **restart** its worker with a rebuilt session
     (:meth:`Replica.restart` — the shared engine's pool is reset, so
     the new generation starts from consistent state);
  3. **re-submit** every in-flight request through
     :meth:`Router.submit_request` — least-loaded placement over the
     healthy siblings AND the just-restarted replica, with bounded
     jittered-backoff retries riding out the restart window.

Client streams are token-identical to an uninjected run: the per-(uid,
step) sampling key contract makes the re-run reproduce exactly the
original tokens (prefix-cache reuse on a sibling makes the replayed
prefill cheap when the prefix was shared), and the replay-suppression
wrapper drops the prefix the client already received — the same
dedup discipline the session applies to preemption recompute.

Counters/trace (docs/observability.md): ``replica_restarts_total``,
``requests_failed_over_total``, the ``serve_recovery_seconds``
histogram, and ``replica_crash`` / ``replica_restart`` / ``failover``
trace instants.

``check_once()`` is the whole algorithm and is directly callable —
tests and the chaos benchmark drive recovery deterministically without
the polling thread; ``start()``/``stop()`` wrap it in a daemon poller
for real serving.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional

import numpy as np

from repro.serve.engine import Result, StreamEvent
from repro.serve.frontend.router import Router
from repro.serve.scheduler import QueueFull


def _suppress_replay(cb: Callable[[StreamEvent], None],
                     skip: int) -> Callable[[StreamEvent], None]:
    """Wrap a per-request callback so the first ``skip`` replayed
    tokens — the prefix the client already received before the crash —
    are dropped; the stream resumes exactly where it stopped."""
    if skip <= 0:
        return cb
    seen = 0

    def wrapped(ev: StreamEvent) -> None:
        nonlocal seen
        toks = ev.tokens
        if seen < skip:
            drop = min(skip - seen, len(toks))
            toks = toks[drop:]
        seen += len(ev.tokens)
        if toks or ev.finished:
            cb(StreamEvent(uid=ev.uid, tokens=toks, finished=ev.finished,
                           result=ev.result,
                           finish_reason=ev.finish_reason))

    return wrapped


class Supervisor:
    def __init__(self, router: Router, poll_s: float = 0.5,
                 failover_retries: int = 8):
        self.router = router
        self.poll_s = poll_s
        self.failover_retries = failover_retries
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------- recovery
    def check_once(self) -> List[str]:
        """One supervision pass: recover every unhealthy, non-draining
        replica.  Returns the recovered replica names (tests/bench call
        this directly for deterministic chaos runs)."""
        recovered: List[str] = []
        for rep in self.router.replicas:
            if rep.healthy or rep.draining:
                continue
            t0 = time.monotonic()
            m = rep.engine.m
            inflight = rep.take_inflight()
            rep.restart()
            for req, delivered, cb in inflight:
                if cb is None:
                    continue
                wrapped = _suppress_replay(cb, delivered)
                try:
                    target = self.router.submit_request(
                        req, wrapped, retries=self.failover_retries)
                except (QueueFull, RuntimeError) as e:
                    # the retry budget ran dry: unblock the client with
                    # a terminal error event instead of a silent hang
                    cb(StreamEvent(
                        uid=req.uid, tokens=[], finished=True,
                        result=Result(uid=req.uid,
                                      tokens=np.zeros(0, np.int32),
                                      prompt_len=len(req.prompt)),
                        finish_reason="error"))
                    m.obs.tracer.instant(
                        "failover_failed", track=m.label,
                        args={"uid": req.uid, "error": repr(e)})
                    continue
                m.failed_over.inc()
                m.obs.tracer.instant(
                    "failover", track=m.label,
                    args={"uid": req.uid, "from": rep.name,
                          "to": target.name, "delivered": delivered})
            m.recovery.observe(time.monotonic() - t0)
            recovered.append(rep.name)
        return recovered

    # ---------------------------------------------------------- lifecycle
    def start(self) -> None:
        """Run :meth:`check_once` on a daemon poller every ``poll_s``
        seconds until :meth:`stop`."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(self.poll_s):
                self.check_once()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="replica-supervisor")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
