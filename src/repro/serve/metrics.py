"""Serve-stack metric bindings (docs/observability.md).

:class:`ServeMetrics` binds every serve-runtime series against one
:class:`repro.obs.Obs` bundle and exposes the bound children as plain
attributes — the engine/scheduler/pool hot paths do one ``child.inc()``
with no name lookups.  Binding is get-or-create on the registry, so an
engine, its pool and its scheduler built from the same bundle share the
same child objects, and N replicas sharing one registry (the launcher)
each get their own children via the ``replica`` label.

This module also carries the LEGACY key mapping: the hand-rolled
``ServeEngine.stats`` / ``PagedKVPool.stats`` dicts the registry
absorbed (ISSUE-8) survive as properties assembled from
:meth:`ServeMetrics.snapshot`, so every pre-existing reader — tests,
benchmarks, the frontend ``/stats`` endpoint — keeps its flat
dict-of-counters shape while the data lives in one thread-safe place.
"""

from __future__ import annotations

from typing import Dict

from repro.obs import COUNT_BUCKETS, Obs

# legacy ServeEngine.stats keys that are wall-clock seconds (kept float
# in snapshots; everything else renders as int)
_WALL_KEYS = ("decode_wall_s", "swap_in_wall_s")

# the PagedKVPool.stats / Scheduler.stats slices of the legacy namespace
POOL_KEYS = ("cow_copies", "prefix_evictions", "swap_out_pages",
             "swap_in_pages", "swap_in_wall_s")
SCHED_KEYS = ("preempt_swap", "preempt_recompute", "prefix_hit_tokens",
              "prefill_tok")


class ServeMetrics:
    """Bound serve-series children for one replica label."""

    def __init__(self, obs: Obs):
        self.obs = obs
        reg = obs.metrics
        lbl = {"replica": obs.label}

        def c(name: str, help: str):
            return reg.counter(name, help, ("replica",)).labels(**lbl)

        def g(name: str, help: str):
            return reg.gauge(name, help, ("replica",)).labels(**lbl)

        def h(name: str, help: str, **kw):
            return reg.histogram(name, help, ("replica",),
                                 **kw).labels(**lbl)

        # ---- step loop -------------------------------------------------
        self.host_syncs = c(
            "serve_host_syncs_total",
            "Blocking device readbacks (one per burst interval)")
        self.device_steps = c(
            "serve_device_steps_total",
            "Fused on-device decode steps executed")
        self.prefill_chunks = c(
            "serve_prefill_chunks_total",
            "Prompt chunk dispatches (fused into their interval's burst)")
        self.tokens = c(
            "serve_tokens_total", "Tokens emitted to consumers")
        self.decode_wall = c(
            "serve_decode_wall_seconds_total",
            "Wall time inside burst dispatch->readback windows")
        self.slot_steps = c(
            "serve_slot_steps_total",
            "Slot-steps occupied (chunks + decode writes) — "
            "tokens/slot_steps is aggregate utilization")
        # ---- admission -------------------------------------------------
        self.requests = c(
            "serve_requests_total", "Requests accepted into the scheduler")
        self.rejected = c(
            "serve_requests_rejected_total",
            "Requests refused at the wait-queue depth cap (QueueFull/429)")
        # ---- preemption / paging --------------------------------------
        self.preempt_swap = c(
            "serve_preempt_swap_total",
            "Preserve-KV preemptions (pages swapped to the host arena)")
        self.preempt_recompute = c(
            "serve_preempt_recompute_total",
            "Drop-and-replay preemptions")
        self.prefix_hit_tokens = c(
            "serve_prefix_hit_tokens_total",
            "Prompt tokens covered by the prefix index at admission")
        self.prefill_tok = c(
            "serve_prefill_tokens_total",
            "Prompt tokens actually chunk-prefilled")
        self.prefix_pages_reused = c(
            "serve_prefix_pages_reused_total",
            "KV pages attached from the prefix index (shared + CoW tail)")
        self.cow_copies = c(
            "serve_cow_copies_total", "Copy-on-write page copies")
        self.prefix_evictions = c(
            "serve_prefix_evictions_total",
            "Prefix-index entries evicted to refill the pool")
        self.swap_out_pages = c(
            "serve_swap_out_pages_total",
            "Pages gathered to the host arena")
        self.swap_in_pages = c(
            "serve_swap_in_pages_total",
            "Pages restored from the host arena")
        self.swap_in_wall = c(
            "serve_swap_in_seconds_total",
            "Wall time inside swap-in restores")
        # ---- compressed weights / quantized KV (ISSUE-9) ---------------
        self.sparse_dispatch = c(
            "sparse_dispatch_total",
            "Burst dispatches routed through the compressed 2:4 "
            "weight path (packed QKV/MLP projections)")
        self.kv_quant_pages = c(
            "kv_quant_pages_total",
            "int8 KV pages allocated (quantize-on-write pools only)")
        # ---- fault tolerance (ISSUE-10) --------------------------------
        self.replica_restarts = c(
            "replica_restarts_total",
            "Replica workers restarted by the supervisor after a "
            "crash/stall")
        self.failed_over = c(
            "requests_failed_over_total",
            "In-flight requests re-submitted after a replica "
            "crash (already-streamed prefixes replay-suppressed)")
        self.cancelled = c(
            "requests_cancelled_total",
            "Requests cancelled mid-flight (client disconnect / "
            "explicit cancel) — pages and slot released immediately")
        self.deadline_exceeded = c(
            "requests_deadline_exceeded_total",
            "Requests retired at their hard deadline "
            "(finish_reason=timeout / HTTP 504)")
        # ---- latency histograms ---------------------------------------
        self.ttft = h(
            "serve_ttft_seconds",
            "Submit -> first token (time to first token)")
        self.tpot = h(
            "serve_tpot_seconds",
            "Per-token decode latency after the first token")
        self.queue_wait = h(
            "serve_queue_wait_seconds", "Submit -> admission wait")
        self.burst_steps = h(
            "serve_burst_steps", "Decode steps per device burst",
            buckets=COUNT_BUCKETS)
        self.recovery = h(
            "serve_recovery_seconds",
            "Crash/stall detection -> worker restarted and every "
            "in-flight request re-submitted")
        # ---- gauges (replica.py binds the callbacks) -------------------
        self.queue_depth = g(
            "serve_queue_depth", "Requests in flight (waiting + slotted)")
        self.replica_healthy = g(
            "serve_replica_healthy",
            "1 while the replica worker is alive and not stalled")
        self.free_pages = g(
            "serve_free_pages", "KV pool free-list length")

        # legacy flat-dict namespace (ServeEngine.stats et al.)
        self._legacy = {
            "host_syncs": self.host_syncs,
            "device_steps": self.device_steps,
            "prefill_chunks": self.prefill_chunks,
            "tokens": self.tokens,
            "decode_wall_s": self.decode_wall,
            "preempt_swap": self.preempt_swap,
            "preempt_recompute": self.preempt_recompute,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefill_tok": self.prefill_tok,
            "cow_copies": self.cow_copies,
            "prefix_evictions": self.prefix_evictions,
            "swap_out_pages": self.swap_out_pages,
            "swap_in_pages": self.swap_in_pages,
            "swap_in_wall_s": self.swap_in_wall,
            "sparse_dispatch": self.sparse_dispatch,
            "kv_quant_pages": self.kv_quant_pages,
            "replica_restarts": self.replica_restarts,
            "failed_over": self.failed_over,
            "cancelled": self.cancelled,
            "deadline_exceeded": self.deadline_exceeded,
        }

    @property
    def tracer(self):
        return self.obs.tracer

    @property
    def label(self) -> str:
        return self.obs.label

    def snapshot(self) -> Dict[str, float]:
        """Current cumulative values under the legacy key names.  The
        per-run ``ServeEngine.stats`` view is ``snapshot() - base``
        with the base taken at ``generate()`` start."""
        return {k: (child.value if k in _WALL_KEYS
                    else int(child.value))
                for k, child in self._legacy.items()}
