"""Serving: continuous-batching paged runtime + 2:4-sparse weights.

  config     ServeConfig — the ONE dataclass carrying every serve
             knob (mode/batch/sampling/paging/prefix/swap/frontend),
             validated in one place and threaded engine → replicas →
             router → benchmarks
  engine     ServeEngine — continuous batching (static-bucket escape
             hatch), chunked paged prefill, greedy/temperature/top-k/
             top-p sampling, mesh-resident params
  fused      the device-resident decode inner loop: fused sample/
             record/advance step + multi-step burst (steps_per_sync)
  kvpool     PagedKVPool — refcounted fixed-size KV pages, free-list
             allocator, per-request block tables (dist-sharded pool),
             copy-on-write sharing; PrefixCache — hash-chained prompt
             prefix index (attach cached pages instead of prefilling);
             HostArena — host-memory swap tier for preserve-KV
             preemption; StatePool — slot-recycled recurrent-state
             pool for Mamba/xLSTM/hybrid mixers
  scheduler  Scheduler — join-at-prefill / chunked prefill / retire-at-
             EOS / swap-or-recompute preemption; SLA-aware wait queue
             (priority/deadline) with a QueueFull depth cap
  frontend   async serving layer: OpenAI-style streaming HTTP server,
             worker-thread replicas, least-loaded multi-replica router,
             replica supervision + in-flight failover
             (docs/serving_frontend.md)
  faults     FaultPlan — deterministic chaos injection at named host
             seams (engine step, replica worker, pool alloc, swap,
             slow burst) for tests/smoke/bench
  sparse     2:4 weight packing → kernels.nm_spmm serve path
"""

from repro.serve.config import ServeConfig
from repro.serve.faults import FaultError, FaultPlan, FaultSpec
from repro.serve.engine import (ServeEngine, Request, Result, StreamEvent,
                                ContinuousSession)
from repro.serve.kvpool import (PagedKVPool, StatePool, PrefixCache,
                                HostArena, SwapRecord)
from repro.serve.scheduler import Scheduler, Sequence, SeqState, QueueFull
from repro.serve.sparse import sparsify_params, DEFAULT_SPARSE_PATTERNS

__all__ = [
    "ServeConfig",
    "ServeEngine",
    "Request",
    "Result",
    "StreamEvent",
    "ContinuousSession",
    "QueueFull",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "PagedKVPool",
    "PrefixCache",
    "HostArena",
    "SwapRecord",
    "StatePool",
    "Scheduler",
    "Sequence",
    "SeqState",
    "sparsify_params",
    "DEFAULT_SPARSE_PATTERNS",
]
