"""Serving: batched KV-cache decode engine + 2:4-sparse weight path."""

from repro.serve.engine import ServeEngine, Request, Result
from repro.serve.sparse import sparsify_params, DEFAULT_SPARSE_PATTERNS

__all__ = [
    "ServeEngine",
    "Request",
    "Result",
    "sparsify_params",
    "DEFAULT_SPARSE_PATTERNS",
]
