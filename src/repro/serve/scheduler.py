"""Step-level request scheduler for continuous batching.

State machine per request (docs/serving.md):

    WAITING --admit--> PREFILL --last chunk--> RUNNING --finish--> FINISHED
       ^                  |                       |
       +----------------- + ------ preempt ------+
         (swap: exclusive pages to the host arena, streamed back on
          resume · recompute: pages released, prefix replayed on re-admit)

Every engine step the scheduler (1) **admits** waiting requests into
free slots while the pool can back their prompts — join-at-prefill, so a
retiring request's slot is refilled the very next step instead of
burning decode into scrap positions; admitted requests enter PREFILL and
the engine feeds their prompt through in fixed-size token *chunks*
(one jitted shape), one chunk per step, interleaved with everyone else's
decode — a long prompt can no longer head-of-line-block the running
batch; (2) **ensures decode capacity** — each decoding request about to
cross a page boundary gets one more page, preempting the *youngest*
admitted request (recompute-style: its pages and slot are released and
it re-queues at the front) when the pool is exhausted; (3) **retires**
requests at EOS / ``max_new_tokens``, recycling slot and pages
immediately.

Admission order is **SLA-aware** (docs/serving_frontend.md): the wait
queue sorts by ``(-priority, deadline, arrival)`` — higher
``Request.priority`` first, earlier ``Request.deadline`` first within a
priority class, submission order last — and degenerates to exact FIFO
when neither field is set.  The queue *head* still blocks admission
when the pool can't back its prompt (no bypass within the order, so a
large request cannot be starved by small ones behind it).  A
``max_waiting`` depth cap makes ``submit`` raise :class:`QueueFull`
instead of buffering unboundedly — the serving front end maps that to
HTTP 429 backpressure.  Preemption re-queues are exempt from the cap
(the request already holds its place) and re-enter with their original
arrival number, so a victim resumes ahead of everything submitted after
it.

Preemption comes in two flavors (ISSUE-7).  **Swap** (preferred when
the pool's host arena has room and the arch carries no recurrent state):
the victim's exclusive pages are gathered to the host tier
(:meth:`PagedKVPool.swap_out`), shared pages stay device-resident with
the victim's reference pinned in its :class:`~repro.serve.kvpool.
SwapRecord`, and tokens/prefill progress are KEPT — resume streams the
pages back and continues decoding where it stopped, no recompute.
**Recompute** (the fallback, and the only mode for recurrent-state
archs): pages and generated tokens are dropped and the prefix is
replayed on re-admission.  The two are split in :attr:`Scheduler.stats`
as ``preempt_swap`` / ``preempt_recompute`` and surfaced through
``ServeEngine.stats`` and the frontend ``/stats`` endpoint.

Admission consults the pool's prefix index
(:class:`~repro.serve.kvpool.PrefixCache`) when enabled: matching full
pages of the prompt attach read-only shared (no prefill), a matching
divergent tail attaches through an eager copy-on-write, and the request
starts prefill at the first uncovered position.  Matched pages are
*pinned* (retained) before the fresh-page alloc so the alloc's own LRU
eviction can never recycle them out from under the admission.

Sampling in the engine is keyed per (request uid, step), so a preempted
request's recompute reproduces its original tokens exactly — preemption
is a capacity event, never a quality event — and admission *order*
(priority vs FIFO) can move when a request runs but never which tokens
it gets.  Swap-resume is bit-exact for the stronger reason that nothing
is recomputed at all.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import time
from typing import Dict, List, Optional, Tuple

from repro.obs import Obs
from repro.serve.kvpool import PagedKVPool, SwapRecord
from repro.serve.metrics import SCHED_KEYS, ServeMetrics


class SeqState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    RUNNING = "running"
    FINISHED = "finished"


class QueueFull(RuntimeError):
    """Raised by :meth:`Scheduler.submit` when the wait queue is at its
    ``max_waiting`` depth cap — the backpressure signal the serving
    front end turns into HTTP 429."""


@dataclasses.dataclass
class Sequence:
    """Scheduler-side tracking of one request's lifecycle."""

    req: "repro.serve.engine.Request"              # noqa: F821
    state: SeqState = SeqState.WAITING
    slot: int = -1
    n_prefilled: int = 0        # prompt tokens already chunk-prefilled
    n_written: int = 0          # KV entries written (prompt + decoded)
    tokens: List[int] = dataclasses.field(default_factory=list)
    occupied_steps: int = 0     # steps while slotted (chunks + decodes)
    preemptions: int = 0
    arrival: int = 0            # submission order (keeps sort stable;
    #                             preserved across preemption re-queue)
    swap: Optional[SwapRecord] = None   # set while swapped to the host
    #                                     arena (WAITING with KV intact)
    # observability stamps (time.monotonic; 0.0 = not yet): queue-wait
    # is submit→first admission, TTFT is submit→first emitted token —
    # both survive preemption (re-queues keep the original stamps)
    submit_ts: float = 0.0
    first_tok_ts: float = 0.0
    admitted_once: bool = False

    def sort_key(self) -> Tuple[float, float, int]:
        pr = getattr(self.req, "priority", 0) or 0
        dl = getattr(self.req, "deadline", None)
        return (-pr, dl if dl is not None else float("inf"), self.arrival)


class _WaitQueue:
    """Priority/deadline/arrival-ordered wait queue.

    Exposes the small surface the scheduler (and its tests) use:
    truthiness/len, ``q[0]`` (the head — the next request admission will
    consider), pop-head, and ordered iteration.  All-default requests
    sort purely by arrival, i.e. exact FIFO.
    """

    def __init__(self):
        self._heap: List[Tuple[Tuple[float, float, int], int, Sequence]] = []
        self._tie = itertools.count()

    def push(self, seq: Sequence) -> None:
        heapq.heappush(self._heap, (seq.sort_key(), next(self._tie), seq))

    def pop(self) -> Sequence:
        return heapq.heappop(self._heap)[-1]

    def remove(self, uid: int) -> Optional[Sequence]:
        """Drop (and return) the entry for ``uid`` wherever it sits in
        the heap — the cancellation path.  None when absent."""
        for i, entry in enumerate(self._heap):
            if entry[-1].req.uid == uid:
                self._heap[i] = self._heap[-1]
                self._heap.pop()
                heapq.heapify(self._heap)
                return entry[-1]
        return None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __getitem__(self, i: int) -> Sequence:
        if i == 0:
            return self._heap[0][-1]
        return sorted(self._heap)[i][-1]

    def __iter__(self):
        return (e[-1] for e in sorted(self._heap))


class Scheduler:
    def __init__(self, pool: PagedKVPool, max_slots: int,
                 max_waiting: Optional[int] = None,
                 swap: bool = False,
                 obs: Optional[Obs] = None):
        self.pool = pool
        self.max_slots = max_slots
        self.max_waiting = max_waiting
        # swap preemption needs the pool's host arena AND no recurrent
        # state rows (those live outside the page pool the arena tiers)
        # — the engine sets this; a bare Scheduler stays recompute-only
        self.swap_enabled = swap and pool.arena is not None
        # counters live in the obs registry (ISSUE-8); a bare Scheduler
        # inherits its pool's bundle so both write one namespace
        self.obs = obs if obs is not None else pool.obs
        self.m = ServeMetrics(self.obs)
        self.waiting = _WaitQueue()
        # admission-ordered (PREFILL + RUNNING): append on admit, remove
        # on finish/preempt — running[-1] is always the youngest (the
        # preemption victim)
        self.running: List[Sequence] = []
        self._free_slots = list(range(max_slots - 1, -1, -1))
        self._arrivals = itertools.count()

    @property
    def stats(self) -> Dict[str, float]:
        """Legacy preemption/prefix counter view (cumulative slice of
        the obs registry)."""
        cur = self.m.snapshot()
        return {k: cur[k] for k in SCHED_KEYS}

    # ------------------------------------------------------------ intake
    def submit(self, req) -> Sequence:
        if (self.max_waiting is not None
                and len(self.waiting) >= self.max_waiting):
            self.m.rejected.inc()
            raise QueueFull(
                f"wait queue at its depth cap ({self.max_waiting}) — "
                f"retry later")
        seq = Sequence(req=req, arrival=next(self._arrivals),
                       submit_ts=time.monotonic())
        self.m.requests.inc()
        self.obs.tracer.async_begin("request", req.uid,
                                    track=self.obs.label,
                                    args={"prompt_len": len(req.prompt),
                                          "max_new": req.max_new_tokens})
        self.waiting.push(seq)
        return seq

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # --------------------------------------------------------- admission
    def _prompt_pages(self, seq: Sequence) -> int:
        return self.pool.pages_for(len(seq.req.prompt))

    def _note_admitted(self, seq: Sequence) -> None:
        """Queue-wait accounting on FIRST admission only (a preemption
        re-queue is a capacity event, not another queue wait)."""
        if seq.admitted_once:
            return
        seq.admitted_once = True
        now = time.monotonic()
        self.m.queue_wait.observe(now - seq.submit_ts)
        self.obs.tracer.complete("queue_wait", seq.submit_ts, now,
                                 track=self.obs.label,
                                 args={"uid": seq.req.uid})

    def admit(self) -> List[Sequence]:
        """Join-at-prefill: move waiting requests into free slots while
        the pool can back their prompts, in wait-queue order —
        (-priority, deadline, arrival), exact FIFO when neither SLA
        field is set.  The queue head blocking on pages stalls admission
        (no head-of-line bypass within the order, so a large request
        cannot starve).

        A swapped-out head resumes instead: its host-tier pages stream
        back into fresh pages (:meth:`PagedKVPool.swap_in`), kept shared
        pages remap in place, and it re-enters PREFILL or RUNNING
        exactly where it was preempted.  A fresh head first consults the
        prefix index: matched full pages attach shared, a matched tail
        attaches via copy-on-write, and ``n_prefilled`` starts at the
        covered length — the engine only chunk-prefills the remainder.
        Admitted requests enter PREFILL; the engine feeds their prompt
        chunks."""
        admitted: List[Sequence] = []
        while self.waiting and self._free_slots:
            seq = self.waiting[0]
            if seq.swap is not None:
                slot = self._free_slots[-1]
                if not self.pool.swap_in(slot, seq.swap):
                    break                  # pool can't back the resume yet
                self._free_slots.pop()
                seq.slot = slot
                seq.swap = None
                plen = len(seq.req.prompt)
                seq.state = (SeqState.RUNNING if seq.n_prefilled >= plen
                             else SeqState.PREFILL)
                self.waiting.pop()
                self.running.append(seq)
                admitted.append(seq)
                self._note_admitted(seq)
                self.obs.tracer.instant(
                    "swap_resume", track=self.obs.label,
                    args={"uid": seq.req.uid})
                continue
            need = self._prompt_pages(seq)
            if need > self.pool.capacity:
                raise RuntimeError(
                    f"request {seq.req.uid}: prompt needs {need} pages but "
                    f"the pool only has {self.pool.capacity} — raise "
                    f"num_pages or max_len")
            shared: List[int] = []
            cow_src: Optional[int] = None
            n_reuse = 0
            if self.pool.prefix is not None and need > 0:
                shared, cow_src, n_reuse = self.pool.prefix.match(
                    seq.req.prompt)
            # pin matched pages BEFORE alloc — alloc's LRU eviction may
            # drop their index entries, but pinned pages can't recycle
            pins = shared + ([cow_src] if cow_src is not None else [])
            for p in pins:
                self.pool.retain(p)
            # only the shared pages skip allocation: the CoW
            # DESTINATION is one of the fresh pages (the source stays
            # with the index — this slot gets its own copy to write)
            fresh = self.pool.alloc(need - len(shared))
            if fresh is None:
                self.pool.release(pins)
                break
            self.waiting.pop()
            seq.slot = self._free_slots.pop()
            if shared:       # pins become the slot's read-only references
                self.pool.assign(seq.slot, shared)
            if cow_src is not None:
                cow_page, fresh = fresh[0], fresh[1:]
                self.pool.assign(seq.slot, [cow_page])
                self.pool.copy_page(cow_src, cow_page)
                self.pool.release([cow_src])        # unpin the source
            if fresh:
                self.pool.assign(seq.slot, fresh)
            seq.state = SeqState.PREFILL
            seq.n_prefilled = n_reuse
            self.m.prefix_hit_tokens.inc(n_reuse)
            self.m.prefill_tok.inc(len(seq.req.prompt) - n_reuse)
            if n_reuse:
                reused = len(shared) + (1 if cow_src is not None else 0)
                self.m.prefix_pages_reused.inc(reused)
                self.obs.tracer.instant(
                    "prefix_attach", track=self.obs.label,
                    args={"uid": seq.req.uid, "pages": reused,
                          "tokens": n_reuse})
            self.running.append(seq)
            admitted.append(seq)
            self._note_admitted(seq)
        return admitted

    def next_prefill(self) -> Optional[Sequence]:
        """The oldest admitted request with prompt chunks left to feed."""
        for seq in self.running:
            if seq.state is SeqState.PREFILL:
                return seq
        return None

    def decoding(self) -> List[Sequence]:
        """Admitted requests past prefill (advanced by decode steps)."""
        return [s for s in self.running if s.state is SeqState.RUNNING]

    # -------------------------------------------------- decode capacity
    def ensure_decode_capacity(self) -> None:
        """Before a decode step: every decoding request writing position
        ``n_written`` must have page ``n_written // page_size`` mapped
        AND exclusively owned (copy-on-write via
        :meth:`PagedKVPool.ensure_writable` if a shared page ever backs
        a write position — the eager CoW at admission makes that the
        exception, not the rule).  Pool exhausted → preempt the youngest
        admitted request and retry (its pages come back to the free
        list).  No-op for pure recurrent-state archs (nothing pages)."""
        if not self.pool.has_kv_pages:
            return
        ps = self.pool.page_size
        for seq in list(self.running):       # oldest first
            if seq.state is not SeqState.RUNNING:
                continue                     # prefilling, or preempted
            while seq.state is SeqState.RUNNING:
                if self.pool.slot_page_count(seq.slot) <= seq.n_written // ps:
                    page = self.pool.alloc(1)
                    if page is not None:
                        self.pool.assign(seq.slot, page)
                        continue
                elif self.pool.ensure_writable(seq.slot, seq.n_written):
                    break                    # mapped and exclusive
                # page alloc failed (extension or CoW): make room
                victim = self.running[-1]    # youngest
                if victim is seq and len(self.running) == 1:
                    raise RuntimeError(
                        "kv pool exhausted by a single request — raise "
                        "num_pages")
                self.preempt(victim)
                if victim is seq:
                    break                    # re-queued; stop extending

    def extend_decode_capacity(self, k: int) -> int:
        """Burst lookahead (the device-resident decode loop): map pages
        so every decoding request can write up to ``k`` more tokens
        without a host sync.  Non-preempting — when the free list runs
        short the burst SHORTENS instead of evicting anyone (a
        preemption the per-step loop wouldn't have caused is never
        worth saving a sync).  Two passes: size the largest burst the
        free list can back for EVERY decoding request, then allocate
        exactly that lookahead — nobody hoards pages a clamped burst
        won't use (hoarded lookahead would drain the pool and cause
        preemptions at the NEXT sync that per-step mode never sees).
        Returns the safe burst length ≤ ``k``; call after
        :meth:`ensure_decode_capacity`, which guarantees step one.
        No-op (full ``k``) for pure recurrent-state archs."""
        if not self.pool.has_kv_pages:
            return k
        decoding = [s for s in self.running
                    if s.state is SeqState.RUNNING]
        k_safe, _ = self._extend(k, decoding, activating=None)
        return k_safe

    def extend_with_activation(self, k: int, activating: Sequence
                               ) -> Tuple[int, bool]:
        """Burst lookahead when this interval's prefill chunk is the
        request's FINAL one (the prefill-fused burst, docs/serving.md):
        size and map pages as :meth:`extend_decode_capacity` does, but
        with the about-to-activate request in the decoding set — it
        samples token 0 from the chunk logits (no page needed) and then
        decodes alongside everyone else.  The engine must have set its
        ``n_written`` to the prompt length already.

        Returns ``(k_safe, can_decode)``.  ``can_decode`` is False when
        even one decode write for the activating slot cannot be backed
        — running requests have their step-one page guaranteed by
        :meth:`ensure_decode_capacity`, the activating one does not —
        in which case the slot activates *frozen* (``pos0`` -1): it
        keeps token 0 and waits for the next sync's capacity pass, the
        same outcome per-step mode reaches one step later.  Still never
        preempts."""
        if not self.pool.has_kv_pages:
            return k, True
        decoding = [s for s in self.running
                    if s.state is SeqState.RUNNING]
        return self._extend(k, decoding, activating)

    def _extend(self, k: int, decoding: List[Sequence],
                activating: Optional[Sequence]) -> Tuple[int, bool]:
        ps = self.pool.page_size
        if activating is not None:
            decoding = decoding + [activating]

        def extra_pages(seq: Sequence, kk: int) -> int:
            # tokens already drawn: the activating seq's token 0 comes
            # from the chunk logits this burst, before any decode write
            drawn = len(seq.tokens) + (1 if seq is activating else 0)
            want = max(0, min(kk, seq.req.max_new_tokens - drawn))
            need = -(-(seq.n_written + want) // ps)
            return max(0, need - self.pool.slot_page_count(seq.slot))

        def total(kk: int) -> int:
            return sum(extra_pages(s, kk) for s in decoding)

        k_safe = k
        while k_safe > 1 and total(k_safe) > self.pool.free_pages:
            k_safe -= 1
        can_decode = True
        if (activating is not None and total(k_safe)
                > self.pool.free_pages):
            # k_safe == 1 and even that overdraws: the running seqs'
            # step-one pages are guaranteed, the activation's is not —
            # freeze the new slot instead of overdrawing (or preempting)
            decoding.remove(activating)
            can_decode = False
        for seq in decoding:
            n = extra_pages(seq, k_safe)
            if n:
                self.pool.assign(seq.slot, self.pool.alloc(n))
        return k_safe, can_decode

    # --------------------------------------------------------- lifecycle
    def preempt(self, seq: Sequence) -> None:
        """Preempt ``seq``, preferring preserve-KV swap over recompute.

        **Swap** (``swap_enabled`` and the host arena has room for the
        victim's exclusive pages): pages move to the host tier, shared
        pages stay pinned by the returned record, and prefill progress
        + generated tokens are KEPT — resume continues mid-stream.
        **Recompute** otherwise: drop slot+pages+generated tokens; the
        deterministic per-(uid, step) sampling keys regenerate the
        identical prefix on re-admission, and re-admission also resets
        any recurrent-state slot rows, so the replayed prefill starts
        from the same fresh state.

        Either way the victim re-queues with its ORIGINAL arrival
        number — within its priority class it sorts ahead of everything
        submitted after it — and is exempt from ``max_waiting`` (the
        request already holds its place)."""
        if self.swap_enabled:
            record = self.pool.swap_out(seq.slot)
            if record is not None:
                # swap_out already cleared the table row; free the slot
                # without releasing the kept refs (the record owns them)
                self._free_slots.append(seq.slot)
                self.running.remove(seq)
                seq.slot = -1
                seq.swap = record
                seq.state = SeqState.WAITING
                seq.preemptions += 1
                self.m.preempt_swap.inc()
                self.obs.tracer.instant(
                    "preempt_swap", track=self.obs.label,
                    args={"uid": seq.req.uid,
                          "host_pages": record.n_host})
                self.waiting.push(seq)
                return
        self._release(seq)
        seq.state = SeqState.WAITING
        seq.n_prefilled = 0
        seq.n_written = 0
        seq.tokens = []
        seq.preemptions += 1
        self.m.preempt_recompute.inc()
        self.obs.tracer.instant("preempt_recompute", track=self.obs.label,
                                args={"uid": seq.req.uid})
        self.waiting.push(seq)

    def finish(self, seq: Sequence) -> None:
        self._release(seq)
        seq.state = SeqState.FINISHED

    def cancel(self, uid: int) -> Optional[Sequence]:
        """Retire one request wherever it is in the state machine
        (ISSUE-10): slotted (mid-prefill or mid-decode) releases slot +
        pages immediately, waiting just leaves the queue, swapped-out
        additionally frees its host-arena slots and kept page refs
        (:meth:`PagedKVPool.drop_swap`).  Returns the sequence (now
        FINISHED) or None when the uid is unknown — already finished,
        or never submitted.  ``check_invariants`` holds afterwards: a
        cancel can never leak a page."""
        for seq in self.running:
            if seq.req.uid == uid:
                self._release(seq)
                seq.state = SeqState.FINISHED
                return seq
        seq = self.waiting.remove(uid)
        if seq is None:
            return None
        if seq.swap is not None:
            self.pool.drop_swap(seq.swap)
            seq.swap = None
        seq.state = SeqState.FINISHED
        return seq

    def _release(self, seq: Sequence) -> None:
        self.pool.clear_slot(seq.slot)
        self._free_slots.append(seq.slot)
        self.running.remove(seq)
        seq.slot = -1
