"""Step-level request scheduler for continuous batching.

State machine per request (docs/serving.md):

    WAITING --admit--> PREFILL --last chunk--> RUNNING --finish--> FINISHED
       ^                  |                       |
       +----------------- + ------ preempt ------+
                 (pages released, recompute on re-admit)

Every engine step the scheduler (1) **admits** waiting requests into
free slots while the pool can back their prompts — join-at-prefill, so a
retiring request's slot is refilled the very next step instead of
burning decode into scrap positions; admitted requests enter PREFILL and
the engine feeds their prompt through in fixed-size token *chunks*
(one jitted shape), one chunk per step, interleaved with everyone else's
decode — a long prompt can no longer head-of-line-block the running
batch; (2) **ensures decode capacity** — each decoding request about to
cross a page boundary gets one more page, preempting the *youngest*
admitted request (recompute-style: its pages and slot are released and
it re-queues at the front) when the pool is exhausted; (3) **retires**
requests at EOS / ``max_new_tokens``, recycling slot and pages
immediately.

Sampling in the engine is keyed per (request uid, step), so a preempted
request's recompute reproduces its original tokens exactly — preemption
is a capacity event, never a quality event.
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Deque, List, Optional

from repro.serve.kvpool import PagedKVPool


class SeqState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Sequence:
    """Scheduler-side tracking of one request's lifecycle."""

    req: "repro.serve.engine.Request"              # noqa: F821
    state: SeqState = SeqState.WAITING
    slot: int = -1
    n_prefilled: int = 0        # prompt tokens already chunk-prefilled
    n_written: int = 0          # KV entries written (prompt + decoded)
    tokens: List[int] = dataclasses.field(default_factory=list)
    occupied_steps: int = 0     # steps while slotted (chunks + decodes)
    preemptions: int = 0


class Scheduler:
    def __init__(self, pool: PagedKVPool, max_slots: int):
        self.pool = pool
        self.max_slots = max_slots
        self.waiting: Deque[Sequence] = deque()
        # admission-ordered (PREFILL + RUNNING): append on admit, remove
        # on finish/preempt — running[-1] is always the youngest (the
        # preemption victim)
        self.running: List[Sequence] = []
        self._free_slots = list(range(max_slots - 1, -1, -1))

    # ------------------------------------------------------------ intake
    def submit(self, req) -> Sequence:
        seq = Sequence(req=req)
        self.waiting.append(seq)
        return seq

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # --------------------------------------------------------- admission
    def _prompt_pages(self, seq: Sequence) -> int:
        return self.pool.pages_for(len(seq.req.prompt))

    def admit(self) -> List[Sequence]:
        """Join-at-prefill: move waiting requests into free slots while
        the pool can back their prompts.  FIFO — the queue head blocking
        on pages stalls admission (no head-of-line bypass, so a large
        request cannot starve).  Admitted requests enter PREFILL; the
        engine feeds their prompt chunks."""
        admitted: List[Sequence] = []
        while self.waiting and self._free_slots:
            seq = self.waiting[0]
            need = self._prompt_pages(seq)
            if need > self.pool.capacity:
                raise RuntimeError(
                    f"request {seq.req.uid}: prompt needs {need} pages but "
                    f"the pool only has {self.pool.capacity} — raise "
                    f"num_pages or max_len")
            pages = self.pool.alloc(need)
            if pages is None:
                break
            self.waiting.popleft()
            seq.slot = self._free_slots.pop()
            self.pool.assign(seq.slot, pages)
            seq.state = SeqState.PREFILL
            seq.n_prefilled = 0
            self.running.append(seq)
            admitted.append(seq)
        return admitted

    def next_prefill(self) -> Optional[Sequence]:
        """The oldest admitted request with prompt chunks left to feed."""
        for seq in self.running:
            if seq.state is SeqState.PREFILL:
                return seq
        return None

    def decoding(self) -> List[Sequence]:
        """Admitted requests past prefill (advanced by decode steps)."""
        return [s for s in self.running if s.state is SeqState.RUNNING]

    # -------------------------------------------------- decode capacity
    def ensure_decode_capacity(self) -> None:
        """Before a decode step: every decoding request writing position
        ``n_written`` must have page ``n_written // page_size`` mapped.
        Pool exhausted → preempt the youngest admitted request and retry
        (its pages come back to the free list).  No-op for pure
        recurrent-state archs (nothing pages)."""
        if not self.pool.has_kv_pages:
            return
        ps = self.pool.page_size
        for seq in list(self.running):       # oldest first
            if seq.state is not SeqState.RUNNING:
                continue                     # prefilling, or preempted
            while self.pool.slot_page_count(seq.slot) <= seq.n_written // ps:
                page = self.pool.alloc(1)
                if page is not None:
                    self.pool.assign(seq.slot, page)
                    continue
                victim = self.running[-1]    # youngest
                if victim is seq and len(self.running) == 1:
                    raise RuntimeError(
                        "kv pool exhausted by a single request — raise "
                        "num_pages")
                self.preempt(victim)
                if victim is seq:
                    break                    # re-queued; stop extending

    def extend_decode_capacity(self, k: int) -> int:
        """Burst lookahead (the device-resident decode loop): map pages
        so every decoding request can write up to ``k`` more tokens
        without a host sync.  Non-preempting — when the free list runs
        short the burst SHORTENS instead of evicting anyone (a
        preemption the per-step loop wouldn't have caused is never
        worth saving a sync).  Two passes: size the largest burst the
        free list can back for EVERY decoding request, then allocate
        exactly that lookahead — nobody hoards pages a clamped burst
        won't use (hoarded lookahead would drain the pool and cause
        preemptions at the NEXT sync that per-step mode never sees).
        Returns the safe burst length ≤ ``k``; call after
        :meth:`ensure_decode_capacity`, which guarantees step one.
        No-op (full ``k``) for pure recurrent-state archs."""
        if not self.pool.has_kv_pages:
            return k
        ps = self.pool.page_size
        decoding = [s for s in self.running
                    if s.state is SeqState.RUNNING]

        def extra_pages(seq: Sequence, kk: int) -> int:
            want = min(kk, seq.req.max_new_tokens - len(seq.tokens))
            need = -(-(seq.n_written + want) // ps)
            return max(0, need - self.pool.slot_page_count(seq.slot))

        k_safe = k
        while k_safe > 1 and (sum(extra_pages(s, k_safe)
                                  for s in decoding)
                              > self.pool.free_pages):
            k_safe -= 1
        for seq in decoding:
            n = extra_pages(seq, k_safe)
            if n:
                self.pool.assign(seq.slot, self.pool.alloc(n))
        return k_safe

    # --------------------------------------------------------- lifecycle
    def preempt(self, seq: Sequence) -> None:
        """Recompute-style preemption: drop slot+pages+generated tokens
        and re-queue at the FRONT (deterministic per-uid sampling keys
        regenerate the identical prefix on re-admission; re-admission
        also resets any recurrent-state slot rows, so the replayed
        prefill starts from the same fresh state)."""
        self._release(seq)
        seq.state = SeqState.WAITING
        seq.n_prefilled = 0
        seq.n_written = 0
        seq.tokens = []
        seq.preemptions += 1
        self.waiting.appendleft(seq)

    def finish(self, seq: Sequence) -> None:
        self._release(seq)
        seq.state = SeqState.FINISHED

    def _release(self, seq: Sequence) -> None:
        self.pool.clear_slot(seq.slot)
        self._free_slots.append(seq.slot)
        self.running.remove(seq)
        seq.slot = -1
