"""Paged serve cache: KV pages + slot-recycled recurrent-state pool.

The continuous-batching serve runtime (docs/serving.md) stores every
request's attention KV cache in fixed-size pages drawn from one global
pool — a pytree of (num_pages, page_size, KV, hd) arrays mirroring the
model's block layout (``LM.init_paged_cache``).  A request owns a
*block table* row mapping its logical token positions to physical page
ids; pages are recycled through a host-side free list the moment a
request retires or is preempted, so cache capacity tracks *live tokens*
instead of ``max_batch × max_len``.

Page 0 is the reserved **scrap page**: never allocated, it absorbs the
writes of padded prefill positions and idle decode slots (attention
masks by length, so scrap contents are never read).

Recurrent mixers (mamba/mlstm/slstm) carry O(1) per-request state, not
per-token KV — their leaves in the same cache tree form a
**slot-recycled fixed-state pool** (:class:`StatePool`): the dense
cache with batch = max_slots, one row per serve slot.  Pages mask
stale contents by length; state rows cannot, so :class:`StatePool`
overwrites a slot's rows with the block's init state at admission.

On a mesh the cache is placed by the ``dist.sharding`` rules
(:func:`repro.dist.sharding.paged_kv_block_specs` /
:func:`~repro.dist.sharding.paged_state_block_specs` via
``LM.paged_cache_specs``): page/slot dims replicated over the data axes,
widths over ``model`` only on head-aligned splits (deliberately no
sub-head fallback — see the rules functions).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PagedKVPool:
    """Free-list page allocator + the device-resident page arrays.

    The device pytree lives in :attr:`kv` and is updated *functionally*:
    the engine passes it through the jitted prefill/decode steps
    (donated) and stores the returned tree back.  Allocation state
    (free list, block tables, per-slot page counts) is host-side numpy —
    the scheduler mutates it synchronously between steps.
    """

    def __init__(
        self,
        model,
        *,
        num_pages: int,
        page_size: int,
        max_slots: int,
        max_len: int,
        dtype=None,
        mesh=None,
    ):
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is scrap)")
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_slots = max_slots
        self.pages_per_slot = -(-max_len // page_size)
        cfg = model.cfg
        # pure recurrent-state archs have no KV pages: prompts cost 0
        # pages and decode never extends a block table
        self.has_kv_pages = any(
            k in ("attn", "attn_local") for k in (*cfg.prefix, *cfg.period))
        self.kv = model.init_paged_cache(num_pages, page_size, dtype,
                                         max_slots=max_slots)
        if mesh is not None:
            from repro.dist import named_shardings

            self.kv = jax.device_put(
                self.kv, named_shardings(mesh, model.paged_cache_specs(mesh)))
        self.block_tables = np.zeros(
            (max_slots, self.pages_per_slot), np.int32)
        self._n_pages = np.zeros((max_slots,), np.int32)
        self._free: List[int] = []
        self._tables_dev: Optional[jax.Array] = None
        self._dirty: set = set()          # slot rows changed since upload
        self.reset()

    # ----------------------------------------------------------- alloc
    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the scrap page)."""
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        """Pages backing ``n_tokens`` KV entries — 0 for pure
        recurrent-state archs (no attention layers, nothing to page)."""
        if not self.has_kv_pages:
            return 0
        return -(-n_tokens // self.page_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages off the free list; None if it would overdraw
        (all-or-nothing, so a half-admitted request never holds pages)."""
        if n <= 0:              # [-0:] would slice the WHOLE free list
            return []
        if n > len(self._free):
            return None
        out = self._free[-n:][::-1]
        del self._free[-n:]
        return out

    def release(self, pages: Sequence[int]) -> None:
        assert 0 not in pages, "scrap page is not allocatable"
        self._free.extend(pages)

    # ------------------------------------------------------ block tables
    def assign(self, slot: int, pages: Sequence[int]) -> None:
        """Append ``pages`` to a slot's block table (logical order)."""
        n = int(self._n_pages[slot])
        assert n + len(pages) <= self.pages_per_slot, "slot exceeds max_len"
        self.block_tables[slot, n:n + len(pages)] = pages
        self._n_pages[slot] = n + len(pages)
        self._dirty.add(slot)

    def slot_page_count(self, slot: int) -> int:
        return int(self._n_pages[slot])

    def slot_pages(self, slot: int) -> List[int]:
        return self.block_tables[slot, :self._n_pages[slot]].tolist()

    def clear_slot(self, slot: int) -> None:
        """Release all of a slot's pages and zero its table row."""
        self.release(self.slot_pages(slot))
        self.block_tables[slot] = 0
        self._n_pages[slot] = 0
        self._dirty.add(slot)

    def reset(self) -> None:
        """Recycle every page (between ``generate`` calls).  Device
        arrays keep their stale contents — attention masks by length, so
        stale pages are never read."""
        self.block_tables[:] = 0
        self._n_pages[:] = 0
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._tables_dev = None
        self._dirty.clear()

    def tables_device(self) -> jax.Array:
        """Device-resident mirror of the block tables.  Uploaded whole
        exactly once; after that, table mutations only mark their slot
        row dirty and the next call scatters the few changed rows into
        the resident array (``.at[rows].set``) — steady-state bursts
        reuse the device buffer with zero host traffic, and a retire/
        admit/page-extend event costs one small row upload instead of a
        full-table re-upload."""
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self.block_tables)
            self._dirty.clear()
        elif self._dirty:
            rows = sorted(self._dirty)
            self._tables_dev = self._tables_dev.at[
                jnp.asarray(rows, jnp.int32)].set(
                    jnp.asarray(self.block_tables[rows]))
            self._dirty.clear()
        return self._tables_dev


class StatePool:
    """Slot-recycled fixed-state pool for recurrent mixers.

    Mamba/xLSTM blocks carry O(1) per-request state instead of per-token
    KV, so their continuous-batching cache is simply the dense decode
    cache with batch = ``max_slots`` — slot index == batch row, and
    ``LM.decode_step(paged=...)`` advances every row exactly as dense
    decode does.  What pages get from masking-by-length, state rows need
    explicitly: a retired request's rows would leak into the next
    occupant of the slot, so :meth:`reset_slot` overwrites them with the
    block's init state at admission (join-at-prefill; recompute
    preemption re-admits through the same reset, which is what makes the
    replayed prefix bit-exact).

    The device arrays live in the engine's shared cache tree
    (``PagedKVPool.kv``) — this class only knows *where* the state
    leaves sit in that tree and what a fresh row looks like.  The
    recurrent-kind list is ``LM.STATE_KINDS`` (the one
    ``init_paged_cache`` validates against) — a kind missing here
    would silently skip the admission reset and leak state between
    requests, so there is deliberately no second copy.
    """

    def __init__(self, model, *, max_slots: int, dtype=None):
        from repro.models.transformer import block_cache_init

        cfg = model.cfg
        dt = dtype or model.dtype
        self.max_slots = max_slots
        state_kinds = model.STATE_KINDS
        # (path into the cache tree, single-slot init rows, stacked?)
        self.entries: List[Tuple[Tuple[str, ...], Dict[str, Any], bool]] = []
        for i, kind in enumerate(cfg.prefix):
            if kind in state_kinds:
                self.entries.append((
                    ("prefix", str(i)),
                    block_cache_init(cfg, kind, 1, 0, dt), False))
        for j, kind in enumerate(cfg.period):
            if kind in state_kinds:
                self.entries.append((
                    ("layers", f"s{j}"),
                    block_cache_init(cfg, kind, 1, 0, dt), True))

    @property
    def has_state(self) -> bool:
        return bool(self.entries)

    def reset_slot(self, cache, slot: int):
        """Overwrite slot ``slot``'s state rows with the init state
        (functional — returns the updated cache tree; attention page
        leaves pass through untouched)."""
        for path, rows, stacked in self.entries:
            node = cache
            for key in path[:-1]:
                node = node[key]
            block = node[path[-1]]
            if stacked:     # (n_periods, max_slots, ...) — broadcast row
                new = {k: v.at[:, slot].set(rows[k][0].astype(v.dtype))
                       for k, v in block.items()}
            else:
                new = {k: v.at[slot].set(rows[k][0].astype(v.dtype))
                       for k, v in block.items()}
            cache = _tree_set(cache, path, new)
        return cache


def _tree_set(tree, path, value):
    """Functionally replace ``tree[path[0]][path[1]]...`` with value."""
    if not path:
        return value
    new = dict(tree)
    new[path[0]] = _tree_set(tree[path[0]], path[1:], value)
    return new
