"""Paged KV-cache pool: fixed-size pages + per-slot block tables.

The continuous-batching serve runtime (docs/serving.md) stores every
request's KV cache in fixed-size pages drawn from one global pool — a
pytree of (num_pages, page_size, KV, hd) arrays mirroring the model's
block layout (``LM.init_paged_cache``).  A request owns a *block table*
row mapping its logical token positions to physical page ids; pages are
recycled through a host-side free list the moment a request retires or
is preempted, so cache capacity tracks *live tokens* instead of
``max_batch × max_len``.

Page 0 is the reserved **scrap page**: never allocated, it absorbs the
writes of padded prefill positions and idle decode slots (attention
masks by length, so scrap contents are never read).

On a mesh the pool arrays are placed by the ``dist.sharding`` rules
(:func:`repro.dist.sharding.paged_kv_block_specs` via
``LM.paged_cache_specs``): pages replicated over the data axes, KV heads
over ``model`` when they divide it (deliberately no head_dim fallback —
see the rules function) — closing the ROADMAP cache-sharding item.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class PagedKVPool:
    """Free-list page allocator + the device-resident page arrays.

    The device pytree lives in :attr:`kv` and is updated *functionally*:
    the engine passes it through the jitted prefill/decode steps
    (donated) and stores the returned tree back.  Allocation state
    (free list, block tables, per-slot page counts) is host-side numpy —
    the scheduler mutates it synchronously between steps.
    """

    def __init__(
        self,
        model,
        *,
        num_pages: int,
        page_size: int,
        max_slots: int,
        max_len: int,
        dtype=None,
        mesh=None,
    ):
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is scrap)")
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_slots = max_slots
        self.pages_per_slot = -(-max_len // page_size)
        self.kv = model.init_paged_cache(num_pages, page_size, dtype)
        if mesh is not None:
            from repro.dist import named_shardings

            self.kv = jax.device_put(
                self.kv, named_shardings(mesh, model.paged_cache_specs(mesh)))
        self.block_tables = np.zeros(
            (max_slots, self.pages_per_slot), np.int32)
        self._n_pages = np.zeros((max_slots,), np.int32)
        self._free: List[int] = []
        self.reset()

    # ----------------------------------------------------------- alloc
    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the scrap page)."""
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages off the free list; None if it would overdraw
        (all-or-nothing, so a half-admitted request never holds pages)."""
        if n <= 0:              # [-0:] would slice the WHOLE free list
            return []
        if n > len(self._free):
            return None
        out = self._free[-n:][::-1]
        del self._free[-n:]
        return out

    def release(self, pages: Sequence[int]) -> None:
        assert 0 not in pages, "scrap page is not allocatable"
        self._free.extend(pages)

    # ------------------------------------------------------ block tables
    def assign(self, slot: int, pages: Sequence[int]) -> None:
        """Append ``pages`` to a slot's block table (logical order)."""
        n = int(self._n_pages[slot])
        assert n + len(pages) <= self.pages_per_slot, "slot exceeds max_len"
        self.block_tables[slot, n:n + len(pages)] = pages
        self._n_pages[slot] = n + len(pages)
        self._tables_dev = None

    def slot_page_count(self, slot: int) -> int:
        return int(self._n_pages[slot])

    def slot_pages(self, slot: int) -> List[int]:
        return self.block_tables[slot, :self._n_pages[slot]].tolist()

    def clear_slot(self, slot: int) -> None:
        """Release all of a slot's pages and zero its table row."""
        self.release(self.slot_pages(slot))
        self.block_tables[slot] = 0
        self._n_pages[slot] = 0
        self._tables_dev = None

    def reset(self) -> None:
        """Recycle every page (between ``generate`` calls).  Device
        arrays keep their stale contents — attention masks by length, so
        stale pages are never read."""
        self.block_tables[:] = 0
        self._n_pages[:] = 0
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._tables_dev = None

    def tables_device(self) -> jax.Array:
        """Device mirror of the block tables, re-uploaded only after a
        table mutation — steady-state decode steps reuse it."""
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self.block_tables)
        return self._tables_dev
