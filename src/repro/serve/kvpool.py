"""Paged serve cache: refcounted KV pages, prefix reuse, tiered swap.

The continuous-batching serve runtime (docs/serving.md) stores every
request's attention KV cache in fixed-size pages drawn from one global
pool — a pytree of (num_pages, page_size, KV, hd) arrays mirroring the
model's block layout (``LM.init_paged_cache``).  A request owns a
*block table* row mapping its logical token positions to physical page
ids.

Page ownership is **refcounted** (ISSUE-7): ``alloc`` hands out pages
at refcount 1, ``retain``/``release`` move the count, and a page
returns to the free list only when its last reference drops.  One
physical page can therefore back the same token prefix in many block
tables at once — "shared" is *derived* (refcount > 1), not a flag, and
a shared page is read-only by convention: the first divergent write
goes through :meth:`PagedKVPool.ensure_writable`, which copies the
page's contents into a fresh exclusively-owned page (copy-on-write)
and repoints the writer's table row.

Page 0 is the reserved **scrap page**: never allocated, it absorbs the
writes of padded prefill positions and idle decode slots (attention
masks by length, so scrap contents are never read).

:class:`PrefixCache` is the hash-based prefix index over those shared
pages: prompts are chunk-hashed page-by-page at admission
(``h_i = blake2b(h_{i-1} ‖ tokens_of_page_i)``, token-exact verified —
a hash collision can never serve wrong KV), matching full pages attach
without prefill, and a matching *partial* tail page attaches through an
eager copy-on-write (the divergence point is known at admission, so the
copy happens before the first write instead of mid-burst).  Entries are
evicted LRU-leaf-first, lazily, from inside :meth:`PagedKVPool.alloc` —
cached prefixes only ever occupy pages nobody else is asking for.

:class:`HostArena` is the host-memory swap tier (ISSUE-7): preemption
can evict a victim's *exclusive* pages to a pinned numpy arena
(``jax.device_get`` gather) and stream them back on resume instead of
recomputing — shared pages are kept device-resident (the victim's
reference pins them), so a swap moves only bytes no one else holds.
The per-(uid, step) sampling key contract already makes preemption
invisible in token streams; swap additionally makes it cheap.

Recurrent mixers (mamba/mlstm/slstm) carry O(1) per-request state, not
per-token KV — their leaves in the same cache tree form a
**slot-recycled fixed-state pool** (:class:`StatePool`): the dense
cache with batch = max_slots, one row per serve slot.  Pages mask
stale contents by length; state rows cannot, so :class:`StatePool`
overwrites a slot's rows with the block's init state at admission.

On a mesh the cache is placed by the ``dist.sharding`` rules
(:func:`repro.dist.sharding.paged_kv_block_specs` /
:func:`~repro.dist.sharding.paged_state_block_specs` via
``LM.paged_cache_specs``); swap-in staging uses
:func:`repro.dist.sharding.host_arena_stage_spec`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import Obs
from repro.serve.metrics import POOL_KEYS, ServeMetrics


def _tree_get(tree, path):
    node = tree
    for key in path:
        node = node[key]
    return node


def _tree_set(tree, path, value):
    """Functionally replace ``tree[path[0]][path[1]]...`` with value."""
    if not path:
        return value
    new = dict(tree)
    new[path[0]] = _tree_set(tree[path[0]], path[1:], value)
    return new


def attn_leaf_paths(cfg) -> List[Tuple[Tuple[str, ...], bool]]:
    """Paths of the attention page blocks inside the paged cache tree:
    ``(path, stacked)`` per block — stacked (period) blocks carry a
    leading lax.scan layer dim, so their page dim is axis 1.  The same
    walk :class:`StatePool` does for the recurrent kinds."""
    paths: List[Tuple[Tuple[str, ...], bool]] = []
    for i, kind in enumerate(cfg.prefix):
        if kind in ("attn", "attn_local"):
            paths.append((("prefix", str(i)), False))
    for j, kind in enumerate(cfg.period):
        if kind in ("attn", "attn_local"):
            paths.append((("layers", f"s{j}"), True))
    return paths


class PagedKVPool:
    """Refcounted free-list page allocator + the device page arrays.

    The device pytree lives in :attr:`kv` and is updated *functionally*:
    the engine passes it through the jitted prefill/decode steps
    (donated) and stores the returned tree back.  Allocation state
    (free list, refcounts, block tables, per-slot page counts) is
    host-side numpy — the scheduler mutates it synchronously between
    steps.

    Ownership contract: ``alloc`` → refcount 1 (exclusive, writable);
    ``retain`` adds a reference (the prefix index and every additional
    block-table row each hold one); ``release`` drops one, freeing the
    page at zero.  A page is writable only while its refcount is 1 —
    :meth:`ensure_writable` enforces that with a device-side
    copy-on-write when a write position lands in a shared page.
    """

    def __init__(
        self,
        model,
        *,
        num_pages: int,
        page_size: int,
        max_slots: int,
        max_len: int,
        dtype=None,
        mesh=None,
        prefix_cache: bool = False,
        host_swap_pages: int = 0,
        obs: Optional[Obs] = None,
        faults=None,
    ):
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is scrap)")
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_slots = max_slots
        self.pages_per_slot = -(-max_len // page_size)
        self.mesh = mesh
        cfg = model.cfg
        # pure recurrent-state archs have no KV pages: prompts cost 0
        # pages and decode never extends a block table
        self.has_kv_pages = any(
            k in ("attn", "attn_local") for k in (*cfg.prefix, *cfg.period))
        # int8 KV pages (ServeConfig.kv_dtype="int8"): quantized pages +
        # per-row f32 scale leaves in the same pool tree — every data
        # plane below (CoW copy, host-arena gather/scatter) iterates
        # block.items() generically, so scales ride along untouched
        self.quantized = (self.has_kv_pages and dtype is not None
                          and jnp.dtype(dtype) == jnp.int8)
        self.kv = model.init_paged_cache(num_pages, page_size, dtype,
                                         max_slots=max_slots)
        if mesh is not None:
            from repro.dist import named_shardings

            self.kv = jax.device_put(
                self.kv, named_shardings(
                    mesh, model.paged_cache_specs(
                        mesh, quantized=self.quantized)))
        self.block_tables = np.zeros(
            (max_slots, self.pages_per_slot), np.int32)
        self._n_pages = np.zeros((max_slots,), np.int32)
        self._free: List[int] = []
        self._ref = np.zeros((num_pages,), np.int32)
        self._tables_dev: Optional[jax.Array] = None
        self._dirty: set = set()          # slot rows changed since upload
        self._attn_paths = attn_leaf_paths(cfg) if self.has_kv_pages else []
        self._copy_jit = None             # lazy jitted CoW page copy
        # CoW/eviction/swap counters live in the obs registry (ISSUE-8);
        # a bare pool gets a private metrics-only bundle, the engine
        # hands down its own so everything lands in one namespace.
        # ``self.stats`` survives as a property over the registry.
        self.obs = obs if obs is not None else Obs.create(trace=False)
        # fault injection (ISSUE-10, serve.faults): pool_alloc fires as
        # a forced exhaustion, swap_error as an arena failure — both
        # land on paths real exhaustion already exercises
        self.faults = faults
        self.m = ServeMetrics(self.obs)
        self._stats_base: Dict[str, float] = {}
        self.prefix: Optional[PrefixCache] = (
            PrefixCache(self) if prefix_cache and self.has_kv_pages
            else None)
        self.arena: Optional[HostArena] = (
            HostArena(self, host_swap_pages)
            if host_swap_pages > 0 and self.has_kv_pages else None)
        self.reset()

    # ----------------------------------------------------------- alloc
    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the scrap page)."""
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def stats(self) -> Dict[str, float]:
        """Legacy per-run counter view (CoW / eviction / swap slice of
        the obs registry, re-based at every :meth:`reset`)."""
        cur = self.m.snapshot()
        return {k: cur[k] - self._stats_base.get(k, 0) for k in POOL_KEYS}

    def pool_bytes(self) -> int:
        """HBM bytes of the attention page pool — quantized pools count
        the int8 pages plus their f32 scale leaves (the honest cost).
        The numerator of the benchmark's ``kv_pool_bytes_per_tok``."""
        total = 0
        for path, _ in self._attn_paths:
            block = _tree_get(self.kv, path)
            total += sum(v.size * v.dtype.itemsize for v in block.values())
        return int(total)

    def pages_for(self, n_tokens: int) -> int:
        """Pages backing ``n_tokens`` KV entries — 0 for pure
        recurrent-state archs (no attention layers, nothing to page)."""
        if not self.has_kv_pages:
            return 0
        return -(-n_tokens // self.page_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages off the free list at refcount 1; None if it
        would overdraw (all-or-nothing, so a half-admitted request never
        holds pages).  A short free list first evicts prefix-index
        leaves LRU-first — cached prefixes never block live traffic."""
        if n <= 0:              # [-0:] would slice the WHOLE free list
            return []
        if self.faults is not None and self.faults.hit(
                "pool_alloc", self.obs.label):
            return None         # injected exhaustion (ISSUE-10)
        if self.prefix is not None:
            while n > len(self._free) and self.prefix.evict_lru():
                pass
        if n > len(self._free):
            return None
        out = self._free[-n:][::-1]
        del self._free[-n:]
        self._ref[out] = 1
        if self.quantized:
            self.m.kv_quant_pages.inc(n)
        return out

    def retain(self, page: int) -> None:
        """Add a reference to a live page (sharing it)."""
        assert page != 0, "scrap page is not shareable"
        assert self._ref[page] > 0, f"retain of free page {page}"
        self._ref[page] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; pages free at refcount 0."""
        for p in pages:
            assert p != 0, "scrap page is not allocatable"
            assert self._ref[p] > 0, f"release of free page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def check_invariants(self) -> None:
        """Refcount accounting invariants (tests / the hypothesis state
        machine): free + live partitions the allocatable pages, the
        scrap page is never owned, no count goes negative."""
        assert self._ref[0] == 0
        assert (self._ref >= 0).all()
        free = set(self._free)
        assert len(free) == len(self._free), "double-free"
        live = {int(p) for p in np.nonzero(self._ref)[0]}
        assert free.isdisjoint(live)
        assert len(free) + len(live) == self.capacity

    # ------------------------------------------------------ block tables
    def assign(self, slot: int, pages: Sequence[int]) -> None:
        """Append ``pages`` to a slot's block table (logical order).
        Pure table bookkeeping — the caller owns one reference per page
        (``alloc`` for fresh pages, ``retain`` for shared ones)."""
        n = int(self._n_pages[slot])
        assert n + len(pages) <= self.pages_per_slot, "slot exceeds max_len"
        self.block_tables[slot, n:n + len(pages)] = pages
        self._n_pages[slot] = n + len(pages)
        self._dirty.add(slot)

    def attach(self, slot: int, pages: Sequence[int]) -> None:
        """Map already-live pages into a slot's table read-only
        (prefix sharing): one ``retain`` per page + ``assign``."""
        for p in pages:
            self.retain(p)
        self.assign(slot, pages)

    def slot_page_count(self, slot: int) -> int:
        return int(self._n_pages[slot])

    def slot_pages(self, slot: int) -> List[int]:
        return self.block_tables[slot, :self._n_pages[slot]].tolist()

    def clear_slot(self, slot: int) -> None:
        """Release all of a slot's pages and zero its table row."""
        self.release(self.slot_pages(slot))
        self.block_tables[slot] = 0
        self._n_pages[slot] = 0
        self._dirty.add(slot)

    def reset(self) -> None:
        """Recycle every page (between ``generate`` calls).  Device
        arrays keep their stale contents — attention masks by length, so
        stale pages are never read."""
        self.block_tables[:] = 0
        self._n_pages[:] = 0
        self._free = list(range(self.num_pages - 1, 0, -1))
        self._ref[:] = 0
        self._tables_dev = None
        self._dirty.clear()
        # registry counters are monotonic — resetting the pool re-bases
        # the legacy per-run ``stats`` view instead of zeroing them
        self._stats_base = self.m.snapshot()
        if self.prefix is not None:
            self.prefix.clear()
        if self.arena is not None:
            self.arena.reset()

    def tables_device(self) -> jax.Array:
        """Device-resident mirror of the block tables.  Uploaded whole
        exactly once; after that, table mutations only mark their slot
        row dirty and the next call scatters the few changed rows into
        the resident array (``.at[rows].set``) — steady-state bursts
        reuse the device buffer with zero host traffic, and a retire/
        admit/page-extend/prefix-attach/CoW event costs one small row
        upload instead of a full-table re-upload."""
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self.block_tables)
            self._dirty.clear()
        elif self._dirty:
            rows = sorted(self._dirty)
            self._tables_dev = self._tables_dev.at[
                jnp.asarray(rows, jnp.int32)].set(
                    jnp.asarray(self.block_tables[rows]))
            self._dirty.clear()
        return self._tables_dev

    # ------------------------------------------------------ copy-on-write
    def copy_page(self, src: int, dst: int) -> None:
        """Device-side page copy: every attention leaf's ``dst`` page
        gets ``src``'s contents (one jitted donated dispatch — the CoW
        data plane)."""
        if self._copy_jit is None:
            paths = self._attn_paths

            def copy(kv, s, d):
                for path, stacked in paths:
                    block = _tree_get(kv, path)
                    if stacked:
                        new = {k: v.at[:, d].set(v[:, s])
                               for k, v in block.items()}
                    else:
                        new = {k: v.at[d].set(v[s])
                               for k, v in block.items()}
                    kv = _tree_set(kv, path, new)
                return kv

            self._copy_jit = jax.jit(copy, donate_argnums=(0,))
        self.kv = self._copy_jit(self.kv, np.int32(src), np.int32(dst))
        self.m.cow_copies.inc()
        self.obs.tracer.instant("cow_copy", track=self.obs.label,
                                args={"src": src, "dst": dst})

    def ensure_writable(self, slot: int, pos: int) -> bool:
        """Copy-on-write guard: make the page backing write position
        ``pos`` exclusively owned by ``slot``.  No-op at refcount 1
        (the common case — prefix attachment copies divergent tails
        eagerly at admission, so decode writes normally land in
        exclusive pages already).  On a shared page: alloc a fresh page
        (False when the pool can't back it — the scheduler preempts,
        exactly like a failed page extension), copy contents, drop the
        shared reference, repoint the table row."""
        if not self.has_kv_pages:
            return True
        idx = pos // self.page_size
        page = int(self.block_tables[slot, idx])
        assert idx < self._n_pages[slot] and page != 0, "unmapped write"
        if self._ref[page] == 1:
            return True
        fresh = self.alloc(1)
        if fresh is None:
            return False
        self.copy_page(page, fresh[0])
        self.release([page])
        self.block_tables[slot, idx] = fresh[0]
        self._dirty.add(slot)
        return True

    # ------------------------------------------------------------- swap
    def swap_out(self, slot: int) -> Optional["SwapRecord"]:
        """Preserve-KV preemption, evict side: gather the slot's
        *exclusive* pages into the host arena and release them; shared
        pages (prefix-cached or multi-table) stay device-resident with
        the victim's reference transferred to the returned record — the
        kept pages cannot be freed (or their entries' eviction cannot
        recycle them) while the victim waits.  Returns None when the
        arena can't hold the exclusive set (the scheduler falls back to
        recompute preemption), leaving the slot untouched."""
        if self.arena is None:
            return None
        if self.faults is not None and self.faults.hit(
                "swap_error", self.obs.label):
            return None         # injected arena failure -> recompute
        pages = self.slot_pages(slot)
        host = [p for p in pages if self._ref[p] == 1]
        if not self.arena.has_room(len(host)):
            return None
        arena_slots = self.arena.gather(self.kv, host)
        by_page = dict(zip(host, arena_slots))
        entries: List[Tuple[str, int]] = [
            ("host", by_page[p]) if p in by_page else ("kept", p)
            for p in pages]
        self.release(host)            # data now lives in the arena
        self.block_tables[slot] = 0   # kept refs move to the record
        self._n_pages[slot] = 0
        self._dirty.add(slot)
        self.m.swap_out_pages.inc(len(host))
        self.obs.tracer.instant("swap_out", track=self.obs.label,
                                args={"slot": slot, "pages": len(host)})
        return SwapRecord(entries=entries)

    def swap_in(self, slot: int, record: "SwapRecord") -> bool:
        """Preserve-KV preemption, resume side: alloc fresh pages for
        the host-resident part of ``record`` (False when the pool can't
        back them — the queue head blocks, exactly like a too-big
        prompt), upload the arena contents into them, and rebuild the
        slot's table in logical order — kept pages slot back in place
        with the record's reference becoming the table's.  Nothing is
        mutated on failure."""
        if self.faults is not None and self.faults.hit(
                "swap_error", self.obs.label):
            return False        # injected arena failure -> retry later
        host_slots = [s for tag, s in record.entries if tag == "host"]
        fresh = self.alloc(len(host_slots))
        if fresh is None:
            return False
        t0 = time.monotonic()
        if host_slots:
            self.kv = self.arena.scatter(self.kv, host_slots, fresh)
        it = iter(fresh)
        pages = [s if tag == "kept" else next(it)
                 for tag, s in record.entries]
        self.assign(slot, pages)
        self.arena.free(host_slots)
        self.m.swap_in_pages.inc(len(host_slots))
        self.m.swap_in_wall.inc(time.monotonic() - t0)
        self.obs.tracer.complete("swap_in", t0, time.monotonic(),
                                 track=self.obs.label,
                                 args={"slot": slot,
                                       "pages": len(host_slots)})
        return True

    def drop_swap(self, record: "SwapRecord") -> None:
        """Abandon a swap record (its request was cancelled or falls
        back to recompute): free the arena slots and the kept pages'
        references."""
        host_slots = [s for tag, s in record.entries if tag == "host"]
        self.arena.free(host_slots)
        self.release([p for tag, p in record.entries if tag == "kept"])


@dataclasses.dataclass
class SwapRecord:
    """A swapped-out request's page state, in logical order: ``("host",
    arena_slot)`` for pages gathered to the host tier, ``("kept",
    page)`` for shared pages kept device-resident (the record holds
    their reference)."""

    entries: List[Tuple[str, int]]

    @property
    def n_host(self) -> int:
        return sum(1 for tag, _ in self.entries if tag == "host")


# ----------------------------------------------------------------------
# hash-based prefix index
# ----------------------------------------------------------------------
class _Entry:
    __slots__ = ("digest", "parent", "page", "tokens", "children",
                 "last_use", "partial")

    def __init__(self, digest, parent, page, tokens, partial):
        self.digest = digest
        self.parent = parent
        self.page = page
        self.tokens = tokens
        self.children = 0
        self.last_use = 0
        self.partial = partial


class PrefixCache:
    """Chain-hash index of cached token prefixes over pool pages.

    Full pages chain: ``h_i = blake2b(h_{i-1} ‖ page-i tokens)`` — a
    lookup walks the prompt page by page, so matching is O(prompt) with
    no global scans.  Every entry stores its exact tokens and a match
    re-verifies them, so a digest collision degrades to a cache miss,
    never to wrong KV.  Partial tail pages (< page_size tokens, from
    retired requests) index under their parent's digest and match by
    longest-common-prefix; they attach via copy-on-write (the writer
    gets a fresh copy), full pages attach read-only shared.

    The index holds one pool reference per entry page.  Eviction is
    LRU over *leaf* entries (nothing chains on them), driven lazily by
    :meth:`PagedKVPool.alloc` when the free list runs short — the cache
    soaks up idle pool capacity and gives it back on demand.
    """

    _ROOT = b"root"

    def __init__(self, pool: PagedKVPool):
        self.pool = pool
        self._full: Dict[bytes, _Entry] = {}
        self._partials: Dict[bytes, List[_Entry]] = {}
        self._clock = itertools.count(1)

    def __len__(self) -> int:
        return len(self._full) + sum(len(v) for v in self._partials.values())

    def clear(self) -> None:
        """Drop every entry WITHOUT releasing pages — only for
        :meth:`PagedKVPool.reset`, which recycles the whole pool."""
        self._full.clear()
        self._partials.clear()

    @staticmethod
    def _digest(parent: bytes, tokens, partial: bool) -> bytes:
        h = hashlib.blake2b(parent, digest_size=16)
        h.update(b"P" if partial else b"F")
        h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
        return h.digest()

    # ------------------------------------------------------------ match
    def match(self, prompt) -> Tuple[List[int], Optional[int], int]:
        """Longest cached prefix of ``prompt``: returns ``(shared_pages,
        cow_src, n_tokens)`` — full pages to attach read-only, an
        optional page to copy-on-write (divergent or capped tail), and
        the KV entries covered.  ``n_tokens`` is capped at
        ``len(prompt) - 1``: the last prompt token is always
        re-prefilled so the final chunk yields the logits token 0
        samples from (a fully-covered prompt turns its last matched
        page into the CoW source)."""
        ps = self.pool.page_size
        prompt = np.asarray(prompt, np.int32)
        n = len(prompt)
        pages: List[int] = []
        parent = self._ROOT
        covered = 0
        while covered + ps <= n:
            piece = prompt[covered:covered + ps]
            e = self._full.get(self._digest(parent, piece, False))
            if e is None or not np.array_equal(e.tokens, piece):
                break
            e.last_use = next(self._clock)
            pages.append(e.page)
            parent = e.digest
            covered += ps
        if covered >= n:               # fully covered: cap at n-1
            return pages[:-1], pages[-1], n - 1
        # partial tail: longest common prefix among this chain point's
        # retired tails (eager CoW attach — the divergence point is
        # known here, before any write)
        best, best_m = None, 0
        for e in self._partials.get(parent, ()):  # noqa: B020
            tail = prompt[covered:covered + len(e.tokens)]
            m = _lcp(e.tokens, tail)
            m = min(m, n - 1 - covered)
            if m > best_m:
                best, best_m = e, m
        if best is not None:
            best.last_use = next(self._clock)
            return pages, best.page, covered + best_m
        return pages, None, covered

    # --------------------------------------------------------- register
    def register(self, kv_tokens, pages: Sequence[int],
                 include_partial: bool = False) -> None:
        """Index a slot's written pages: ``kv_tokens`` are the tokens
        whose KV the slot holds (prompt, then generated), ``pages`` its
        block-table row.  Full pages chain-register (immutable once
        written — decode never revisits them); ``include_partial``
        additionally registers the trailing partial page (retirement
        only — a live request still writes its tail).  Existing digests
        dedup to a recency bump; each NEW entry retains its page."""
        ps = self.pool.page_size
        kv_tokens = np.asarray(kv_tokens, np.int32)
        parent = self._ROOT
        n_full = len(kv_tokens) // ps
        for i in range(n_full):
            piece = kv_tokens[i * ps:(i + 1) * ps]
            d = self._digest(parent, piece, False)
            e = self._full.get(d)
            if e is None:
                e = _Entry(d, parent, int(pages[i]), piece.copy(), False)
                self.pool.retain(e.page)
                self._full[d] = e
                pe = self._full.get(parent)
                if pe is not None:
                    pe.children += 1
            elif not np.array_equal(e.tokens, piece):
                return                 # digest collision: stop the chain
            e.last_use = next(self._clock)
            parent = d
        if not include_partial:
            return
        tail = kv_tokens[n_full * ps:]
        if len(tail) == 0 or n_full >= len(pages):
            return
        d = self._digest(parent, tail, True)
        sibs = self._partials.setdefault(parent, [])
        if any(s.digest == d for s in sibs):
            for s in sibs:
                if s.digest == d:
                    s.last_use = next(self._clock)
            return
        e = _Entry(d, parent, int(pages[n_full]), tail.copy(), True)
        e.last_use = next(self._clock)
        self.pool.retain(e.page)
        sibs.append(e)
        pe = self._full.get(parent)
        if pe is not None:
            pe.children += 1

    # ---------------------------------------------------------- evict
    def evict_lru(self) -> bool:
        """Evict the least-recently-used *leaf* entry (releasing its
        page reference).  Returns False when nothing is evictable."""
        best: Optional[_Entry] = None
        for e in self._full.values():
            if e.children == 0 and (best is None
                                    or e.last_use < best.last_use):
                best = e
        for sibs in self._partials.values():
            for e in sibs:
                if best is None or e.last_use < best.last_use:
                    best = e
        if best is None:
            return False
        if best.partial:
            sibs = self._partials[best.parent]
            sibs.remove(best)
            if not sibs:
                del self._partials[best.parent]
        else:
            del self._full[best.digest]
        pe = self._full.get(best.parent)
        if pe is not None:
            pe.children -= 1
        self.pool.release([best.page])
        self.pool.m.prefix_evictions.inc()
        return True


def _lcp(a, b) -> int:
    n = min(len(a), len(b))
    if n == 0:
        return 0
    eq = np.asarray(a[:n]) == np.asarray(b[:n])
    if eq.all():
        return n
    return int(np.argmin(eq))


# ----------------------------------------------------------------------
# host-memory swap tier
# ----------------------------------------------------------------------
class HostArena:
    """Pinned host-memory page arena — the swap tier below the device
    pool.  One preallocated numpy buffer per attention leaf, shaped
    like the leaf with the page dim replaced by the arena capacity;
    arena slot ``i`` across all leaves holds one full logical page.

    ``gather`` pulls device pages down in one ``jax.device_get`` per
    leaf (a device-side gather first, so only the evicted pages cross
    the wire); ``scatter`` stages the host bytes back (placed by
    ``dist.sharding.host_arena_stage_spec`` on a mesh — replicated,
    matching the never-sharded page dim) and functionally scatters them
    into freshly allocated pages, inheriting the pool leaves' sharding
    through the ``.at[pages].set`` operand."""

    def __init__(self, pool: PagedKVPool, capacity: int):
        self.capacity = capacity
        self._pool = pool
        self._free = list(range(capacity - 1, -1, -1))
        self._bufs: Dict[Tuple[Tuple[str, ...], str], np.ndarray] = {}
        self._stacked: Dict[Tuple[Tuple[str, ...], str], bool] = {}
        for path, stacked in pool._attn_paths:
            block = _tree_get(pool.kv, path)
            for k, v in block.items():
                shape = list(v.shape)
                shape[1 if stacked else 0] = capacity
                self._bufs[(path, k)] = np.zeros(shape, v.dtype)
                self._stacked[(path, k)] = stacked

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def has_room(self, n: int) -> bool:
        return n <= len(self._free)

    def reset(self) -> None:
        self._free = list(range(self.capacity - 1, -1, -1))

    def free(self, slots: Sequence[int]) -> None:
        self._free.extend(slots)

    def gather(self, kv, pages: Sequence[int]) -> List[int]:
        """Copy device ``pages`` into fresh arena slots (one blocking
        ``device_get`` per leaf).  Caller must have checked
        :meth:`has_room`."""
        slots = [self._free.pop() for _ in pages]
        if not pages:
            return slots
        idx = jnp.asarray(pages, jnp.int32)
        for (path, k), buf in self._bufs.items():
            leaf = _tree_get(kv, path)[k]
            if self._stacked[(path, k)]:
                buf[:, slots] = np.asarray(
                    jax.device_get(jnp.take(leaf, idx, axis=1)))
            else:
                buf[slots] = np.asarray(
                    jax.device_get(jnp.take(leaf, idx, axis=0)))
        return slots

    def scatter(self, kv, slots: Sequence[int], pages: Sequence[int]):
        """Upload arena ``slots`` into device ``pages`` (functional —
        returns the updated cache tree).  The staged blob is committed
        replicated on a mesh (``host_arena_stage_spec``); the scatter
        output keeps each leaf's pool sharding."""
        stage_sharding = None
        if self._pool.mesh is not None:
            from jax.sharding import NamedSharding

            from repro.dist.sharding import host_arena_stage_spec

            stage_sharding = NamedSharding(self._pool.mesh,
                                           host_arena_stage_spec())
        idx = jnp.asarray(pages, jnp.int32)
        for (path, k), buf in self._bufs.items():
            block = _tree_get(kv, path)
            leaf = block[k]
            data = buf[:, slots] if self._stacked[(path, k)] else buf[slots]
            dev = jnp.asarray(data)
            if stage_sharding is not None:
                dev = jax.device_put(dev, stage_sharding)
            if self._stacked[(path, k)]:
                leaf = leaf.at[:, idx].set(dev)
            else:
                leaf = leaf.at[idx].set(dev)
            kv = _tree_set(kv, path, {**block, k: leaf})
        return kv


class StatePool:
    """Slot-recycled fixed-state pool for recurrent mixers.

    Mamba/xLSTM blocks carry O(1) per-request state instead of per-token
    KV, so their continuous-batching cache is simply the dense decode
    cache with batch = ``max_slots`` — slot index == batch row, and
    ``LM.decode_step(paged=...)`` advances every row exactly as dense
    decode does.  What pages get from masking-by-length, state rows need
    explicitly: a retired request's rows would leak into the next
    occupant of the slot, so :meth:`reset_slot` overwrites them with the
    block's init state at admission (join-at-prefill; recompute
    preemption re-admits through the same reset, which is what makes the
    replayed prefix bit-exact — and why archs with recurrent state take
    the recompute path rather than KV swap: their per-request state rows
    live outside the page pool the host arena tiers).

    The device arrays live in the engine's shared cache tree
    (``PagedKVPool.kv``) — this class only knows *where* the state
    leaves sit in that tree and what a fresh row looks like.  The
    recurrent-kind list is ``LM.STATE_KINDS`` (the one
    ``init_paged_cache`` validates against) — a kind missing here
    would silently skip the admission reset and leak state between
    requests, so there is deliberately no second copy.
    """

    def __init__(self, model, *, max_slots: int, dtype=None):
        from repro.models.transformer import block_cache_init

        cfg = model.cfg
        dt = dtype or model.dtype
        self.max_slots = max_slots
        state_kinds = model.STATE_KINDS
        # (path into the cache tree, single-slot init rows, stacked?)
        self.entries: List[Tuple[Tuple[str, ...], Dict[str, Any], bool]] = []
        for i, kind in enumerate(cfg.prefix):
            if kind in state_kinds:
                self.entries.append((
                    ("prefix", str(i)),
                    block_cache_init(cfg, kind, 1, 0, dt), False))
        for j, kind in enumerate(cfg.period):
            if kind in state_kinds:
                self.entries.append((
                    ("layers", f"s{j}"),
                    block_cache_init(cfg, kind, 1, 0, dt), True))

    @property
    def has_state(self) -> bool:
        return bool(self.entries)

    def reset_slot(self, cache, slot: int):
        """Overwrite slot ``slot``'s state rows with the init state
        (functional — returns the updated cache tree; attention page
        leaves pass through untouched)."""
        for path, rows, stacked in self.entries:
            block = _tree_get(cache, path)
            if stacked:     # (n_periods, max_slots, ...) — broadcast row
                new = {k: v.at[:, slot].set(rows[k][0].astype(v.dtype))
                       for k, v in block.items()}
            else:
                new = {k: v.at[slot].set(rows[k][0].astype(v.dtype))
                       for k, v in block.items()}
            cache = _tree_set(cache, path, new)
        return cache
