"""Deterministic fault injection for the serve stack (ISSUE-10).

The fault-tolerance layer (replica supervision, in-flight failover,
cancellation) is only trustworthy if every recovery path can be DRIVEN
— from tests, from CI's chaos smoke, from the chaos benchmark leg — not
just theorized.  A :class:`FaultPlan` is a list of :class:`FaultSpec`
triggers threaded through :class:`~repro.serve.config.ServeConfig`;
each spec names an injection *site* (a host-level seam the runtime
already passes through) and fires deterministically on the Nth pass,
so a chaos run is exactly reproducible.

Sites (``FaultSpec.site``):

  ``engine_step``     raise :class:`FaultError` at burst dispatch — the
                      session's ``step()`` blows up mid-interval,
                      killing the replica worker thread (the supervisor
                      recovery path).
  ``replica_worker``  raise inside the replica worker loop itself,
                      before any session work — a worker death with the
                      scheduler state still consistent.
  ``pool_alloc``      :meth:`PagedKVPool.alloc` reports exhaustion
                      (returns ``None``) — drives the preemption /
                      admission-blocked paths without actually filling
                      the pool.
  ``slow_burst``      sleep ``delay_s`` at burst dispatch — a stalled
                      device step, driving the stall-based health check
                      without waiting out the real threshold.
  ``swap_error``      host-arena swap failure: ``swap_out`` returns
                      ``None`` (preemption degrades to recompute) and
                      ``swap_in`` returns ``False`` (resume retries) —
                      the graceful-degrade paths.

Sites count every *pass*, fire while ``after < seen <= after + count``,
and go quiet again — recovery runs against a healthy system.  A spec
with ``replica`` set only counts passes from that replica's label, so
a multi-replica chaos run can kill exactly one worker.

Token-stream contract: every injected failure is recoverable without
changing any surviving request's tokens (the per-(uid, step) sampling
key contract); the chaos smoke asserts exactly that.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

SITES = ("engine_step", "replica_worker", "pool_alloc", "slow_burst",
         "swap_error")


class FaultError(RuntimeError):
    """An injected failure (never raised by real code paths) — what a
    crashed worker's ``Replica.crashed`` holds in chaos runs."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic trigger: fire at passes ``after+1 ..
    after+count`` through ``site`` (optionally only counting passes
    from one replica label)."""

    site: str
    after: int = 0            # passes to let through before firing
    count: int = 1            # consecutive firings once triggered
    delay_s: float = 0.5      # stall length (slow_burst only)
    replica: Optional[str] = None   # restrict to one replica label

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """Parse a CLI spec: ``site[:key=value,...]`` with keys
        ``after``, ``count``, ``delay_s``, ``replica`` — e.g.
        ``replica_worker:after=3,replica=r0``."""
        site, _, rest = text.partition(":")
        kw: Dict[str, object] = {}
        if rest:
            for item in rest.split(","):
                k, _, v = item.partition("=")
                k = k.strip()
                if k in ("after", "count"):
                    kw[k] = int(v)
                elif k == "delay_s":
                    kw[k] = float(v)
                elif k == "replica":
                    kw[k] = v.strip()
                else:
                    raise ValueError(f"unknown fault-spec key {k!r}")
        return cls(site=site.strip(), **kw)

    def validate(self) -> "FaultSpec":
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(expected one of {SITES})")
        if self.after < 0:
            raise ValueError("fault 'after' must be >= 0")
        if self.count < 1:
            raise ValueError("fault 'count' must be >= 1")
        if self.delay_s < 0:
            raise ValueError("fault 'delay_s' must be >= 0")
        return self


class FaultPlan:
    """A set of specs plus per-spec pass counters (thread-safe: the
    replica worker threads and the pool all hit sites concurrently).
    One plan is shared by every replica built from one ServeConfig, so
    ``replica``-scoped specs see a per-replica count and unscoped specs
    a global one."""

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs: List[FaultSpec] = [s.validate() for s in specs]
        self._seen: Dict[int, int] = {}
        self._lock = threading.Lock()
        # observability for tests/bench: site -> times it actually fired
        self.fired: Dict[str, int] = {}

    @classmethod
    def parse(cls, texts: Sequence[str]) -> "FaultPlan":
        return cls([FaultSpec.parse(t) for t in texts])

    def __bool__(self) -> bool:
        return bool(self.specs)

    def hit(self, site: str, replica: Optional[str] = None
            ) -> Optional[FaultSpec]:
        """Count one pass through ``site``; return the spec that should
        fail this pass (None = proceed normally).  O(1) when the plan
        is empty."""
        if not self.specs:
            return None
        with self._lock:
            fired = None
            for i, spec in enumerate(self.specs):
                if spec.site != site:
                    continue
                if spec.replica is not None and spec.replica != replica:
                    continue
                seen = self._seen.get(i, 0) + 1
                self._seen[i] = seen
                if fired is None and spec.after < seen <= (spec.after
                                                           + spec.count):
                    fired = spec
            if fired is not None:
                self.fired[site] = self.fired.get(site, 0) + 1
            return fired

    # ---------------------------------------------------- burst seam
    def burst_hook(self, replica: Optional[str] = None) -> None:
        """The host-side hook the fused burst wrappers call before each
        device dispatch: a fired ``slow_burst`` sleeps (stalled step),
        a fired ``engine_step`` raises (worker crash)."""
        spec = self.hit("slow_burst", replica)
        if spec is not None:
            time.sleep(spec.delay_s)
        if self.hit("engine_step", replica) is not None:
            raise FaultError(
                f"injected engine_step failure (replica={replica})")
