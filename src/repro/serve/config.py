"""ServeConfig: the one object carrying every serve-runtime knob.

PR-3..6 grew the serving stack a keyword argument at a time —
``--serve-mode/--page-size/--num-pages/--prefill-chunk/--steps-per-sync/
--sampling/--top-k/--top-p/--replicas/--queue-depth`` — each threaded
positionally through ``launch/serve.py`` → :class:`ServeEngine` →
``frontend.Replica``/``Router`` and duplicated in the benchmarks.  This
dataclass is the consolidation point (ISSUE-7): one object, one
``validate()``, constructed once (``ServeConfig.from_args`` in the
launcher, a literal in tests/benchmarks) and handed down whole.

``ServeEngine(model, params, **knobs)`` still works — the engine builds
a config from bare keywords — so call sites migrate at their own pace;
new knobs land HERE, not in another positional parameter.

Prefix caching + tiered KV (the ISSUE-7 tentpole) add:

  ``prefix_cache``      hash-based prefix reuse over refcounted pages
                        (kvpool.PrefixCache) — matching full pages of a
                        new prompt attach without prefill, divergence
                        triggers copy-on-write (docs/serving.md)
  ``host_swap_pages``   host-memory swap arena capacity in pages
                        (kvpool.HostArena): preemption evicts a
                        victim's exclusive pages to the host tier and
                        streams them back on resume instead of
                        recomputing.  ``None`` sizes the arena to the
                        pool (swap-preferred); ``0`` disables swap
                        (recompute-only, the pre-ISSUE-7 behavior).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serve.faults import FaultPlan

_MODES = ("continuous", "static")
_SAMPLING = ("greedy", "temperature", "top-k", "top-p")


@dataclasses.dataclass
class ServeConfig:
    """Every serve-runtime knob, validated in one place."""

    # engine
    mode: str = "continuous"
    max_batch: int = 8
    max_len: int = 256
    eos_id: Optional[int] = None
    # sampling (per-(uid, step)-keyed in continuous mode)
    temperature: float = 0.0
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    # paged runtime
    page_size: int = 16
    num_pages: Optional[int] = None     # None → dense-cache equivalent
    prefill_chunk: int = 32
    steps_per_sync: int = 8
    # prefix caching + tiered KV (ISSUE-7 tentpole)
    prefix_cache: bool = True
    host_swap_pages: Optional[int] = None   # None → pool-sized; 0 → off
    # KV page dtype (ISSUE-9): "int8" stores pages quantized with
    # per-row f32 scales (quantize at attn_apply's paged scatter,
    # dequantize at the paged_attn gather) — ~half the page bytes, so
    # the default pool sizing doubles the page count at the same HBM
    # budget (resolved_num_pages)
    kv_dtype: str = "fp32"
    # compressed-weight serving (ISSUE-9): "auto" detects 2:4 leaves at
    # engine load and keeps only (vals, idx) in HBM (serve.sparse
    # .compressed_param_tree — f32 token streams are bit-identical);
    # "off" serves whatever tree it was handed unmodified (the
    # benchmark's dense-on-pruned comparison leg)
    sparse_weights: str = "auto"
    # front end (launch/serve.py, frontend.Replica/Router)
    replicas: int = 1
    queue_depth: Optional[int] = None   # wait-queue cap → HTTP 429
    # observability (ISSUE-8, repro.obs): ``metrics`` feeds the
    # counter/gauge/histogram registry behind ``engine.stats`` and the
    # frontend /metrics endpoint (off → zero-cost no-ops); ``trace``
    # records Chrome-trace request-lifecycle spans (--trace-out).
    # Token streams are bit-identical under every combination.
    metrics: bool = True
    trace: bool = False
    # fault injection (ISSUE-10, serve.faults): deterministic failures
    # at named sites — engine-step raise, replica worker death, pool
    # alloc failure, stalled burst, host-arena swap error — so every
    # recovery path (supervision, failover, preemption degrade) is
    # drivable from tests/CI.  None = nothing ever fires.  ONE plan is
    # shared by all replicas built from this config (replica-scoped
    # specs count per replica label).
    faults: Optional[FaultPlan] = None

    def validate(self) -> "ServeConfig":
        """The single validation point.  Returns self (chainable)."""
        if self.mode not in _MODES:
            raise ValueError(f"unknown serve mode {self.mode!r} "
                             f"(expected one of {_MODES})")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_len < 1:
            raise ValueError("max_len must be >= 1")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.num_pages is not None and self.num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is scrap)")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.steps_per_sync < 1:
            raise ValueError("steps_per_sync must be >= 1")
        if self.temperature < 0.0:
            raise ValueError("temperature must be >= 0 (0 = greedy)")
        if self.top_k is not None and self.top_k < 1:
            raise ValueError("top_k must be >= 1")
        if self.top_p is not None and not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        if self.host_swap_pages is not None and self.host_swap_pages < 0:
            raise ValueError("host_swap_pages must be >= 0 (0 = off)")
        if self.kv_dtype not in ("fp32", "int8"):
            raise ValueError(f"unknown kv_dtype {self.kv_dtype!r} "
                             "(expected 'fp32' or 'int8')")
        if self.sparse_weights not in ("auto", "off"):
            raise ValueError(f"unknown sparse_weights "
                             f"{self.sparse_weights!r} "
                             "(expected 'auto' or 'off')")
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        if self.queue_depth is not None and self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        if self.faults is not None:
            for spec in self.faults.specs:
                spec.validate()
        return self

    def resolved_num_pages(self) -> int:
        """The pool size: explicit, or the dense static cache's token
        capacity + the scrap page.  int8 KV pages cost half the bytes
        of fp32 (int8 payload + a per-row f32 scale, amortized over
        head_dim), so the default sizing doubles the per-slot page
        count — the same HBM budget holds 2× the tokens."""
        if self.num_pages is not None:
            return self.num_pages
        per_slot = -(-self.max_len // self.page_size)
        if self.kv_dtype == "int8":
            per_slot *= 2
        return self.max_batch * per_slot + 1

    def resolved_swap_pages(self) -> int:
        """Host-arena capacity: explicit, or pool-sized (every live
        page can swap out)."""
        if self.host_swap_pages is not None:
            return self.host_swap_pages
        return self.resolved_num_pages()

    # ------------------------------------------------------------ intake
    @classmethod
    def from_args(cls, args) -> "ServeConfig":
        """Build from the ``launch/serve.py`` argparse namespace — the
        one place CLI flags map onto runtime knobs.  ``--sampling``
        resolves to (temperature, top_k, top_p) here: non-greedy modes
        need a live draw, so a zero temperature is bumped to 1.0."""
        temperature = args.temperature
        top_k = top_p = None
        if args.sampling == "top-k":
            top_k = args.top_k
        elif args.sampling == "top-p":
            top_p = args.top_p
        if args.sampling != "greedy" and temperature <= 0.0:
            temperature = 1.0
        return cls(
            mode=args.serve_mode,
            max_batch=args.max_batch,
            max_len=args.max_len,
            temperature=temperature,
            top_k=top_k,
            top_p=top_p,
            page_size=args.page_size,
            num_pages=args.num_pages,
            prefill_chunk=args.prefill_chunk,
            steps_per_sync=args.steps_per_sync,
            prefix_cache=args.prefix_cache,
            host_swap_pages=args.host_swap_pages,
            kv_dtype=getattr(args, "kv_dtype", "fp32"),
            replicas=args.replicas,
            queue_depth=args.queue_depth,
            metrics=getattr(args, "metrics", True),
            trace=getattr(args, "trace_out", None) is not None,
            faults=(FaultPlan.parse(args.inject_fault)
                    if getattr(args, "inject_fault", None) else None),
        ).validate()
