"""The assigned input shapes × applicability rules × ShapeDtypeStruct specs.

Shapes (per assignment; every LM arch pairs with all four):
  train_4k     seq 4,096   global_batch 256   → lowers train_step
  prefill_32k  seq 32,768  global_batch 32    → lowers prefill_step
  decode_32k   seq 32,768  global_batch 128   → lowers serve_step
                                                (1 new token, 32k KV cache)
  long_500k    seq 524,288 global_batch 1     → lowers serve_step
                                                (sub-quadratic archs only)

``input_specs(cfg, shape)`` returns {name: ShapeDtypeStruct} — weak-type
correct, shardable, ZERO device allocation (the dry-run contract).  For
decode shapes the cache specs come from ``LM.init_cache_shapes`` (also
allocation-free via eval_shape).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig
from repro.models.transformer import LM


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_is_applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """(ok, reason-if-skipped). Skip rules are declared per-config."""
    if shape not in SHAPES:
        raise KeyError(f"unknown shape {shape!r}")
    if shape in cfg.skip_shapes:
        return False, cfg.skip_shapes[shape]
    return True, ""


def applicable_shapes(cfg: ArchConfig):
    return [s for s in SHAPES if shape_is_applicable(cfg, s)[0]]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: str) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sp = SHAPES[shape]
    b, t = sp.global_batch, sp.seq_len
    specs: Dict[str, object] = {}
    model = LM(cfg)

    if sp.kind == "train":
        t_text = t
        if cfg.frontend is not None and not cfg.encdec:
            t_text = t - cfg.frontend_len       # frontend occupies positions
        specs["tokens"] = _sds((b, t_text), jnp.int32)
        specs["labels"] = _sds((b, t_text), jnp.int32)
        if cfg.frontend is not None:
            specs["frontend_feats"] = _sds(
                (b, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
        return specs

    if sp.kind == "prefill":
        t_text = t
        if cfg.frontend is not None and not cfg.encdec:
            t_text = t - cfg.frontend_len
        specs["tokens"] = _sds((b, t_text), jnp.int32)
        if cfg.frontend is not None:
            specs["frontend_feats"] = _sds(
                (b, cfg.frontend_len, cfg.frontend_dim), jnp.bfloat16)
        specs["cache"] = model.init_cache_shapes(b, t)
        return specs

    # decode: one new token against a seq_len-deep cache
    specs["token"] = _sds((b,), jnp.int32)
    specs["cache"] = model.init_cache_shapes(b, t)
    specs["pos"] = _sds((), jnp.int32)
    return specs
