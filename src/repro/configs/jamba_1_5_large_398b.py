"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attention 7:1 interleave,
MoE every other layer. [arXiv:2403.19887; hf]

Jamba period = 8 layers: slot 3 is attention, the rest Mamba; every block
carries an FFN (``ssm_mlp``), alternating dense MLP / 16-expert MoE.
Runs ``long_500k`` — attention KV exists only every 8th layer and Mamba
state is O(1) in sequence length.
"""

from repro.models.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    period=("mamba", "mamba", "mamba", "attn",
            "mamba", "mamba", "mamba", "mamba"),
    mlp_kind="swiglu",
    ssm_mlp=True,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576),
    moe_slots=(1, 3, 5, 7),
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    remat="full",
)

SMOKE = ArchConfig(
    name="jamba-1.5-smoke",
    family="hybrid",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    period=("mamba", "mamba", "mamba", "attn",
            "mamba", "mamba", "mamba", "mamba"),
    mlp_kind="swiglu",
    ssm_mlp=True,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    moe_slots=(1, 3, 5, 7),
    ssm_state=4,
    ssm_expand=2,
    ssm_conv=4,
    dtype="float32",
)
