"""seamless-m4t-large-v2 [audio] — enc-dec, 24L enc + 24L dec,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206. [arXiv:2308.11596; hf]

The speech frontend (w2v-BERT conformer feature extractor) is a STUB per
the assignment: ``input_specs`` provides precomputed frame embeddings
(B, 1024 frames, 1024) which the encoder stack consumes directly.
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,              # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    period=("dec_attn",),
    mlp_kind="gelu",
    encdec=True,
    enc_layers=24,
    frontend="audio",
    frontend_dim=1024,
    frontend_len=1024,          # speech frames after conformer downsampling
    skip_shapes={
        "long_500k": "full-attention decoder — quadratic at 524k",
    },
)

SMOKE = ArchConfig(
    name="seamless-m4t-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    period=("dec_attn",),
    mlp_kind="gelu",
    encdec=True,
    enc_layers=2,
    frontend="audio",
    frontend_dim=32,
    frontend_len=16,
    dtype="float32",
)
