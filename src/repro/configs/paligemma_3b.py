"""paligemma-3b [vlm] — gemma-2b backbone (18L d_model=2048 8H MQA kv=1
d_ff=16384) + SigLIP patch-embedding frontend STUB, vocab=257216.
[arXiv:2407.07726; hf]

Per the assignment, the modality frontend is a stub: ``input_specs``
provides precomputed SigLIP patch embeddings (B, 256, 1152) which the
model projects into d_model and prepends as a bidirectional prefix
(prefix-LM attention, as in the paper).
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    period=("attn",),
    mlp_kind="geglu",
    embed_scale=True,
    tie_embeddings=True,
    frontend="patch",
    frontend_dim=1152,          # SigLIP so400m features
    frontend_len=256,           # 224px / 14px patches = 16x16
    skip_shapes={
        "long_500k": "full attention — quadratic at 524k",
    },
)

SMOKE = ArchConfig(
    name="paligemma-3b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=256,
    vocab_size=256,
    period=("attn",),
    mlp_kind="geglu",
    embed_scale=True,
    tie_embeddings=True,
    frontend="patch",
    frontend_dim=32,
    frontend_len=8,
    dtype="float32",
)
