"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    # 5 sliding-window layers followed by 1 global layer, repeated
    period=("attn_local",) * 5 + ("attn",),
    window=1024,
    mlp_kind="geglu",
    qk_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    remat="full",
    skip_shapes={
        "long_500k": "global layers are full attention — quadratic at 524k",
    },
)

SMOKE = ArchConfig(
    name="gemma3-12b-smoke",
    family="dense",
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    period=("attn_local",) * 5 + ("attn",),
    window=8,
    mlp_kind="geglu",
    qk_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    dtype="float32",
)
