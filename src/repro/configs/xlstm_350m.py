"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 (no FFN) vocab=50304,
mLSTM + sLSTM blocks (7:1). [arXiv:2405.04517; unverified]

Runs ``long_500k``: pure recurrent state, O(1) decode memory.
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    period=("mlstm", "mlstm", "mlstm", "slstm",
            "mlstm", "mlstm", "mlstm", "mlstm"),
    mlp_kind="none",
    mlstm_proj=2,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="xlstm-350m-smoke",
    family="ssm",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=256,
    period=("mlstm", "mlstm", "mlstm", "slstm",
            "mlstm", "mlstm", "mlstm", "mlstm"),
    mlp_kind="none",
    mlstm_proj=2,
    tie_embeddings=True,
    dtype="float32",
)
