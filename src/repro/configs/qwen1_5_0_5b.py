"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    period=("attn",),
    mlp_kind="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    skip_shapes={
        "long_500k": "full attention — quadratic at 524k",
    },
)

SMOKE = ArchConfig(
    name="qwen1.5-0.5b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    period=("attn",),
    mlp_kind="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    dtype="float32",
)
