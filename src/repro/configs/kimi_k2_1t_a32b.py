"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared, DeepSeek-V3 style).
Trillion-parameter MoE (paper-table). [arXiv:2501.kimi2; unverified]"""

from repro.models.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,                   # per-expert FFN width (the assigned d_ff)
    vocab_size=163840,
    period=("attn",),
    mlp_kind="swiglu",
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048, num_shared=1),
    moe_slots=(0,),              # every layer is MoE
    remat="full",
    skip_shapes={
        "long_500k": "full attention — quadratic at 524k",
    },
)

SMOKE = ArchConfig(
    name="kimi-k2-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=256,
    period=("attn",),
    mlp_kind="swiglu",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32, num_shared=1),
    moe_slots=(0,),
    dtype="float32",
)
