"""paper-tiny-lm — CPU-scale analogue of the paper's evaluation family.

The paper prunes LLaMA2/OPT/BLOOM (transformers) and Mamba LMs. Offline,
we train this tiny dense LM (and a tiny Mamba twin, ``MAMBA``) on the
synthetic corpus, then reproduce the paper's tables: method ordering
(SS < SM/MM), unstructured vs 2:4, high-sparsity degradation, and the
γ / calibration-size ablations. See benchmarks/.
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="paper-tiny-lm",
    family="dense",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=384,
    vocab_size=512,
    period=("attn",),
    mlp_kind="swiglu",
    dtype="float32",
)

SMOKE = ArchConfig(
    name="paper-tiny-lm-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    period=("attn",),
    mlp_kind="swiglu",
    dtype="float32",
)

# Mamba twin for the paper's Table 3 (Mamba-based LLM) experiments.
MAMBA = ArchConfig(
    name="paper-tiny-mamba",
    family="ssm",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    period=("mamba",),
    mlp_kind="none",
    ssm_state=8,
    dtype="float32",
)
