"""Assigned-architecture registry: ``get_config(id)`` / ``get_smoke(id)``.

Every architecture is a module exporting CONFIG (the exact published
dims) and SMOKE (a reduced same-family variant for CPU tests).  Shapes
(the 4 assigned input-shape cells) and per-arch skip rules live in
``shapes.py``.
"""

import importlib
from typing import Dict

from repro.models.base import ArchConfig

ARCH_IDS = (
    "qwen3_14b",
    "gemma3_12b",
    "qwen1_5_0_5b",
    "gemma_2b",
    "paligemma_3b",
    "kimi_k2_1t_a32b",
    "phi3_5_moe_42b_a6_6b",
    "jamba_1_5_large_398b",
    "xlstm_350m",
    "seamless_m4t_large_v2",
    # the paper's own evaluation family (CPU-scale analogue)
    "paper_tiny_lm",
)

_ALIAS = {a.replace("_", "-"): a for a in ARCH_IDS}
# also accept the ids exactly as assigned (dots/dashes)
_ALIAS.update({
    "qwen3-14b": "qwen3_14b",
    "gemma3-12b": "gemma3_12b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "gemma-2b": "gemma_2b",
    "paligemma-3b": "paligemma_3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "xlstm-350m": "xlstm_350m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
})


def canonical(arch_id: str) -> str:
    key = arch_id.strip()
    if key in ARCH_IDS:
        return key
    if key in _ALIAS:
        return _ALIAS[key]
    raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ALIAS)}")


def _module(arch_id: str):
    return importlib.import_module(f"repro.configs.{canonical(arch_id)}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ArchConfig:
    return _module(arch_id).SMOKE


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


from repro.configs.shapes import (  # noqa: E402
    SHAPES,
    input_specs,
    shape_is_applicable,
    applicable_shapes,
)

__all__ = [
    "ARCH_IDS",
    "canonical",
    "get_config",
    "get_smoke",
    "all_configs",
    "SHAPES",
    "input_specs",
    "shape_is_applicable",
    "applicable_shapes",
]
