"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""

from repro.models.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    period=("attn",),
    mlp_kind="swiglu",
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400),
    moe_slots=(0,),
    remat="full",
    skip_shapes={
        "long_500k": "full attention — quadratic at 524k",
    },
)

SMOKE = ArchConfig(
    name="phi3.5-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    period=("attn",),
    mlp_kind="swiglu",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
    moe_slots=(0,),
    dtype="float32",
)
