"""Pipelined calibration/solve scheduler — the pruning engine's hot path.

Algorithm 1 is serial over segments, but within a segment there are three
stages whose only dependencies are array values:

  capture(i)    calibration hiddens through segment i (dense weights),
                accumulating the per-linear Hessians
  solve(i)      per-linear layer solves from those Hessians
  propagate(i)  segment i re-run with the *pruned* weights → the inputs
                of segment i+1

The serial engine (``PruningEngine`` with ``pipeline="off"``) runs these
as per-batch eager Python loops with host syncs between stages.  The
scheduler here instead

  - stacks the calibration batches into one batched hidden-state pytree
    per calibration shard and jits each segment's capture/propagate
    apply: one XLA dispatch per stage instead of ``n_batches`` eager
    walks, with one compilation shared by every segment that carries the
    same ``apply.trace_key`` (all period instances of a model compile
    once);
  - shards the calibration set over the mesh's data(+pod) axes: each
    shard accumulates its own :class:`CalibrationSet` and the per-linear
    Hessians merge through ``core.distributed.allreduce_calibration`` —
    one collective per linear, DCN-friendly on multi-pod meshes;
  - never blocks the host mid-segment: jax's async dispatch lets the
    host enqueue segment *i*'s solves, its pruned propagate and segment
    *i+1*'s capture while segment *i*'s solves are still executing.
    Report scalars (sparsity, reconstruction error) stay device arrays
    until the end of the run.  (Exception: on multi-device CPU the
    stages synchronize — see :func:`strict_collective_sync`);
  - donates the propagate inputs (``donate_argnums``, accelerator
    backends) so peak activation memory stays ~one segment.

Dispatch timeline (host runs ahead of the device queue; only
``progress_store`` checkpoints synchronize, on segment boundaries):

  host:   cap(i) solves(i) prop(i) cap(i+1) solves(i+1) ...
  device: ──cap(i)──►─solves(i)──►─prop(i)──►─cap(i+1)──► ...

``PruningEngine.run`` drives :func:`run_pipelined`; the serial loop
remains available as ``pipeline="off"`` and is the semantic reference —
the pipelined path must produce the same masks/weights (tested).
"""

from __future__ import annotations

import contextlib
import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.calibration import CalibrationSet
from repro.obs import Obs

log = logging.getLogger("repro.pipeline")


def strict_collective_sync(mesh) -> bool:
    """Serialize collective-bearing dispatches on multi-device CPU.

    XLA's CPU runtime runs concurrent programs on a thread pool with no
    per-device FIFO ordering, so two *independent* in-flight programs
    that both contain collectives can interleave their rendezvous and
    deadlock (observed with a capture's hessian_allreduce racing a layer
    solve's resharding).  Accelerator runtimes enqueue programs in
    dispatch order per device, and mesh-less runs dispatch single-device
    programs with no collectives at all — only the virtual-device CPU
    configuration *with* a multi-device mesh needs the stage-by-stage
    sync.
    """
    return (mesh is not None and mesh.size > 1
            and jax.default_backend() == "cpu" and jax.device_count() > 1)


@dataclasses.dataclass
class PipelineStats:
    """Per-run scheduler accounting (``engine.last_pipeline_stats``).

    In the default async mode the per-stage seconds measure host
    *dispatch* time (the device queue drains concurrently); with
    ``instrument=True`` every stage blocks until its results are ready,
    so the seconds are true stage costs and ``sum(stages) - wall`` of an
    uninstrumented run measures the overlap won by pipelining.
    """

    segments: int = 0
    calib_shards: int = 1
    batches: int = 0
    # distinct jitted stage callables built (trace-key × mode).  jax may
    # still retrace one callable per input shape — e.g. uneven shard
    # groups stack to two batch sizes — so this is a lower bound on XLA
    # compilations, not an exact count.
    compiles: int = 0
    capture_s: float = 0.0
    solve_s: float = 0.0
    propagate_s: float = 0.0
    wall_s: float = 0.0
    instrumented: bool = False

    def stage_total(self) -> float:
        return self.capture_s + self.solve_s + self.propagate_s


def _resolve_shards(calib_shard, mesh, dp_axes, n_batches: int) -> int:
    """How many calibration shards to accumulate separately.

    ``"auto"`` uses one shard per data(+pod) slice when the batch count
    allows it; ``"off"``/1 accumulates locally; an int forces a count.
    """
    if isinstance(calib_shard, bool):        # before int tests: True == 1
        calib_shard = "on" if calib_shard else "off"
    if calib_shard in ("off", None, 1):
        return 1
    dp = 1
    if mesh is not None:
        for a in dp_axes:
            if a in mesh.axis_names:
                dp *= mesh.shape[a]
    if isinstance(calib_shard, int):
        return max(1, min(calib_shard, n_batches))
    if calib_shard == "auto":
        return dp if (dp > 1 and n_batches >= dp) else 1
    if calib_shard == "on":
        if dp <= 1:
            return 1
        return min(dp, n_batches)
    raise ValueError(f"calib_shard={calib_shard!r} not in "
                     "('auto', 'on', 'off') or int")


class SegmentScheduler:
    """Batched, jitted, optionally sharded capture/propagate over segments.

    One instance lives for one ``run_pipelined`` call; jitted segment
    applies are cached by ``apply.trace_key`` (falling back to the apply
    object itself), so structurally identical segments share a compile.
    """

    def __init__(
        self,
        mesh=None,
        dp_axes: Sequence[str] = ("pod", "data"),
        calib_shard="auto",
        donate: Optional[bool] = None,
        instrument: bool = False,
        obs: Optional[Obs] = None,
    ):
        self.mesh = mesh
        self.dp_axes = tuple(a for a in dp_axes
                             if mesh is not None and a in mesh.axis_names)
        self.calib_shard = calib_shard
        if donate is None:
            # buffer donation is a no-op (warning spam) on CPU
            donate = jax.default_backend() != "cpu"
        self.donate = donate
        self.strict = strict_collective_sync(mesh)
        self.stats = PipelineStats(instrumented=instrument)
        self._instrument = instrument
        self._fns: Dict[Any, Callable] = {}
        # stage timing flows through the SAME obs registry/tracer the
        # serve stack uses (ISSUE-8): prune_stage_seconds_total{stage}
        # mirrors stats.<stage>_s, and every stage window becomes a
        # trace span when the caller's bundle has tracing on
        self.obs = obs if obs is not None else Obs.disabled()
        reg = self.obs.metrics
        self._stage_s = reg.counter(
            "prune_stage_seconds_total",
            "Pipelined prune wall seconds by stage "
            "(capture/solve/propagate)", ("stage",))
        self._m_segments = reg.counter(
            "prune_segments_total", "Segments pruned")
        self._m_compiles = reg.counter(
            "prune_compiles_total",
            "Distinct jitted stage callables built")

    # ---------------------------------------------------------- timing
    @contextlib.contextmanager
    def timed(self, stage: str, ready: Callable[[], Any] = lambda: ()):
        """Accrue host time into ``stats.<stage>_s``; with instrumentation
        on (or under the multi-device-CPU collective serialization), also
        block on ``ready()``'s arrays so the time is a true device cost
        instead of an async dispatch."""
        t0 = time.monotonic()
        try:
            yield
        finally:
            if self._instrument or self.strict:
                for leaf in jax.tree.leaves(ready()):
                    jax.block_until_ready(leaf)
            t1 = time.monotonic()
            setattr(self.stats, f"{stage}_s",
                    getattr(self.stats, f"{stage}_s") + t1 - t0)
            self._stage_s.labels(stage=stage).inc(t1 - t0)
            self.obs.tracer.complete(f"prune_{stage}", t0, t1,
                                     track="prune")

    # -------------------------------------------------------- stacking
    def shard_states(self, per_batch_states: Sequence[Any]) -> List[Any]:
        """Stack per-batch calibration states into per-shard batched
        states (tree-concatenate along the leading batch dim)."""
        states = list(per_batch_states)
        self.stats.batches = len(states)
        n = _resolve_shards(self.calib_shard, self.mesh, self.dp_axes,
                            len(states))
        self.stats.calib_shards = n
        groups = [states[i::n] for i in range(n)]
        return [
            jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *g)
            if len(g) > 1 else g[0]
            for g in groups
        ]

    # ------------------------------------------------------------- jit
    def _fn(self, seg, capture: bool) -> Callable:
        key = (getattr(seg.apply, "trace_key", seg.apply), capture)
        fn = self._fns.get(key)
        if fn is None:
            self.stats.compiles += 1
            self._m_compiles.inc()
            if capture:
                fn = jax.jit(
                    lambda p, s, a=seg.apply: a(p, s, capture=True))
            else:
                fn = jax.jit(
                    lambda p, s, a=seg.apply: a(p, s, capture=False)[0],
                    donate_argnums=(1,) if self.donate else ())
            self._fns[key] = fn
        return fn

    # ---------------------------------------------------------- stages
    def capture(self, seg, seg_params, shard_states: List[Any]
                ) -> CalibrationSet:
        """Run calibration through ``seg`` in capture mode, one batched
        apply per shard, and merge the per-shard Hessians (collective
        when the shard count matches the mesh's batch axes)."""
        fn = self._fn(seg, capture=True)
        sets: List[CalibrationSet] = []
        result: List[CalibrationSet] = []
        with self.timed(
                "capture",
                lambda: [a.h for s in result for a in s.accs.values()]):
            for st in shard_states:
                _, caps = fn(seg_params, st)
                if self.strict:
                    # per-shard programs are mutually independent — on
                    # multi-device CPU their collectives must not overlap
                    jax.block_until_ready(jax.tree.leaves(caps))
                sets.append(CalibrationSet.from_captures(caps))
            if len(sets) == 1:
                merged = sets[0]
            elif self.mesh is not None and self.dp_axes:
                from repro.core.distributed import allreduce_calibration

                merged = allreduce_calibration(sets, self.mesh,
                                               axis_name=self.dp_axes)
            else:
                merged = CalibrationSet.merge_all(sets)
            result.append(merged)
        return merged

    def propagate(self, seg, seg_params, shard_states: List[Any]
                  ) -> List[Any]:
        """Re-run ``seg`` (pruned weights) over every shard, donating the
        input hidden buffers; returns the next segment's inputs."""
        fn = self._fn(seg, capture=False)
        out: List[Any] = []
        with self.timed("propagate", lambda: out):
            for st in shard_states:
                out.append(fn(seg_params, st))
                if self.strict:
                    jax.block_until_ready(jax.tree.leaves(out[-1]))
        return out


def run_pipelined(
    engine, params: Any, calib_batches: Sequence[Any],
    instrument: bool = False,
) -> Tuple[Any, List]:
    """Drive Algorithm 1 with the pipelined scheduler.

    Semantics match ``PruningEngine`` serial mode exactly: same segment
    order, same skip/resume/checkpoint behavior (``progress_store`` saves
    land on segment boundaries), same reports — only the dispatch
    structure differs.
    """
    from repro.core.engine import LinearReport

    model = engine.model
    segments = model.prunable_segments()

    start_seg = 0
    if engine.progress_store is not None:
        loader = getattr(engine.progress_store, "load_into", None)
        resumed = loader(params) if loader else engine.progress_store.load()
        if resumed is not None:
            start_seg, params = resumed
            log.info("resuming pipelined pruning at segment %d", start_seg)

    sched = SegmentScheduler(
        mesh=engine.mesh,
        calib_shard=engine.calib_shard,
        instrument=instrument,
        # engines wired with an obs bundle (launch/prune.py) surface
        # stage seconds through the shared registry; bare engines no-op
        obs=getattr(engine, "obs", None),
    )
    t_wall = time.monotonic()

    init_fn = getattr(model, "calib_init", None) or model.first_hidden
    states = sched.shard_states([init_fn(params, b) for b in calib_batches])
    # fast-forward through already-pruned segments (resume): the same
    # jitted propagate path recomputes their (pruned) outputs bit-exactly
    for seg in segments[:start_seg]:
        states = sched.propagate(seg, seg.get_params(params), states)

    # reports carry device scalars until the end of the run — a float()
    # mid-pipeline would stall the dispatch queue
    pending: List[Tuple[str, jax.Array, Any, float, Tuple[int, ...]]] = []

    for si in range(start_seg, len(segments)):
        seg = segments[si]
        seg_params = seg.get_params(params)

        calib = sched.capture(seg, seg_params, states)

        linears = seg.linears
        if linears is None:
            linears = model.segment_linears(seg, seg_params)
        seg_params_ref = [seg_params]
        with sched.timed(
                "solve",
                lambda: ([r[1] for r in pending[-len(linears):]]
                         + jax.tree.leaves(seg_params_ref[0]))):
            for lin in linears:
                if engine._should_skip(f"{seg.name}.{lin.name}"):
                    continue
                if lin.name not in calib.accs:
                    raise KeyError(
                        f"segment {seg.name}: no capture for linear "
                        f"{lin.name!r} (captures: {sorted(calib.names())})")
                w = lin.get(seg_params)
                hmat = calib.hessian(lin.name)
                t0 = time.monotonic()
                # strict mode (multi-device CPU): the loss float() blocks
                # the per-linear chain so no two collective programs are
                # ever in flight together
                res = engine._prune_one(w, hmat, sync=sched.strict)
                seg_params = lin.set(seg_params, res.w)
                seg_params_ref[0] = seg_params
                pending.append((
                    f"{seg.name}.{lin.name}",
                    res.w,
                    (res.mask, res.loss),
                    time.monotonic() - t0,
                    tuple(w.shape),
                ))

        params = seg.set_params(params, seg_params)
        states = sched.propagate(seg, seg_params, states)
        sched.stats.segments += 1
        sched._m_segments.inc()

        if engine.progress_store is not None:
            # the only mid-run host sync: checkpoints materialize params,
            # always on a segment boundary
            engine.progress_store.save(si + 1, params)

    if engine.progress_store is not None:
        engine.progress_store.finalize()

    # materialize report scalars only now — the mask means / losses are
    # the run's only remaining device work, drained one float() at a time
    reports = [
        LinearReport(
            name=name,
            method=engine.method,
            sparsity=float(jnp.mean(mask.astype(jnp.float32))),
            recon_error=float(loss),
            seconds=secs,
            shape=shape,
        )
        for name, _, (mask, loss), secs, shape in pending
    ]
    sched.stats.wall_s = time.monotonic() - t_wall
    engine.last_pipeline_stats = sched.stats
    return params, reports
