"""SparseGPT (Frantar & Alistarh 2023) — the SRP-based 𝔖𝔖 baseline.

Faithful JAX port of the released sparsegpt.py algorithm, kept for two
roles: (a) the paper's main baseline, and (b) Solution 𝔖 *compensation*
inside our mixed combinations (𝔐𝔖).

Algorithm recap (sequential weight freezing — the thing MRP removes):
  Hinv  = chol_upper( (H + γI)⁻¹ )          # upper Cholesky factor U
  per column block [i1:i2):
    per column i (left→right):
      select pruned entries (by w²/U_ii² within block, or per N:M group)
      q     = w_i with pruned slots zeroed
      err_i = (w_i − q) / U_ii
      w[:, i:] −= err_i ⊗ U[i, i:]          # frozen left, updated right
    w[:, i2:] −= Err_block @ U[i1:i2, i2:]  # lazy trailing update

The per-column loop is inherently sequential (each step reads weights the
previous step wrote) — on TPU it is a `lax.fori_loop`. Our MRP path
replaces the whole loop with one batched solve; see core.mrp.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sparsity import SparsitySpec


def cholesky_inv_upper(h: jax.Array, gamma: float = 0.01) -> jax.Array:
    """U with (H + γ·mean(diag)·I)⁻¹ = Uᵀ U  (SparseGPT's `Hinv`)."""
    m = h.shape[0]
    damp = jnp.maximum(gamma * jnp.mean(jnp.diag(h)), 1e-8)
    hd = (h + damp * jnp.eye(m, dtype=h.dtype)).astype(jnp.float32)
    chol = jax.scipy.linalg.cho_factor(hd, lower=True)
    hinv = jax.scipy.linalg.cho_solve(chol, jnp.eye(m, dtype=jnp.float32))
    # upper Cholesky of hinv:  hinv = Uᵀ U ⇒ U = chol(hinv, lower=False)
    u = jnp.linalg.cholesky(hinv, upper=True)
    return u


def _column_step(w1, err1, mask1, u1, i, *, lazy_from: int):
    """One inner column update; mask1 column i decides pruning."""
    s = w1.shape[1]
    wcol = w1[:, i]
    d = u1[i, i]
    q = jnp.where(mask1[:, i], 0.0, wcol)
    err = (wcol - q) / d
    # update columns i..s (the frozen-left / updated-right rule)
    row = u1[i, :]                                  # (S,)
    upd = err[:, None] * row[None, :]               # (n, S)
    colmask = (jnp.arange(s) >= i + 1)
    w1 = w1 - upd * colmask[None, :]
    w1 = w1.at[:, i].set(q)
    err1 = err1.at[:, i].set(err)
    return w1, err1


@functools.partial(
    jax.jit, static_argnames=("blocksize", "prune_n", "prune_m", "num_prune_per_block")
)
def _sparsegpt_core(
    w: jax.Array,
    u: jax.Array,
    mask_override: Optional[jax.Array],
    blocksize: int,
    prune_n: int,
    prune_m: int,
    num_prune_per_block: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Blocked sequential SparseGPT. Returns (w_new, mask, per-col loss)."""
    n, m = w.shape
    w = w.astype(jnp.float32)
    u = u.astype(jnp.float32)
    nblocks = m // blocksize
    have_override = mask_override is not None
    if not have_override:
        mask_override = jnp.zeros((n, m), bool)

    def block_body(b, carry):
        w, mask_all, losses = carry
        i1 = b * blocksize
        w1 = jax.lax.dynamic_slice(w, (0, i1), (n, blocksize))
        u1 = jax.lax.dynamic_slice(u, (i1, i1), (blocksize, blocksize))
        udiag = jnp.diagonal(u1)

        if have_override:
            mask1 = jax.lax.dynamic_slice(mask_override, (0, i1), (n, blocksize))
        elif prune_n == 0:
            # unstructured: threshold w²/U_jj² within the block, exact count
            scores = (w1**2) / (udiag[None, :] ** 2)
            flat = scores.reshape(-1)
            order = jnp.argsort(flat)
            mask1 = (
                jnp.zeros((n * blocksize,), bool)
                .at[order[:num_prune_per_block]]
                .set(True)
                .reshape(n, blocksize)
            )
        else:
            mask1 = jnp.zeros((n, blocksize), bool)  # filled per group below

        def col_body(i, inner):
            w1, err1, mask1 = inner
            if (not have_override) and prune_n > 0:
                # refresh the group's mask when entering it (i % M == 0),
                # using *current* (already-compensated) weights.
                def refresh(args):
                    w1, mask1 = args
                    gstart = i
                    wg = jax.lax.dynamic_slice(w1, (0, gstart), (n, prune_m))
                    dg = jax.lax.dynamic_slice(udiag, (gstart,), (prune_m,))
                    sc = (wg**2) / (dg[None, :] ** 2)
                    _, idx = jax.lax.top_k(-sc, prune_n)
                    mg = jax.nn.one_hot(idx, prune_m, dtype=jnp.float32).sum(-2) > 0
                    return jax.lax.dynamic_update_slice(mask1, mg, (0, gstart))

                mask1 = jax.lax.cond(
                    i % prune_m == 0, refresh, lambda a: a[1], (w1, mask1)
                )
            w1, err1 = _column_step(w1, err1, mask1, u1, i, lazy_from=blocksize)
            return (w1, err1, mask1)

        err1 = jnp.zeros((n, blocksize), jnp.float32)
        w1, err1, mask1 = jax.lax.fori_loop(
            0, blocksize, col_body, (w1, err1, mask1)
        )

        # lazy trailing update: w[:, i2:] -= Err1 @ U[i1:i2, i2:]
        urows = jax.lax.dynamic_slice(u, (i1, 0), (blocksize, m))
        trailing = err1 @ urows                       # (n, m)
        colmask = jnp.arange(m) >= (i1 + blocksize)
        w = w - trailing * colmask[None, :]
        w = jax.lax.dynamic_update_slice(w, w1, (0, i1))
        mask_all = jax.lax.dynamic_update_slice(mask_all, mask1, (0, i1))
        # per-block loss bookkeeping: Σ err² /2 (OBS loss units)
        losses = losses.at[b].set(0.5 * jnp.sum(err1**2))
        return (w, mask_all, losses)

    mask_all = jnp.zeros((n, m), bool)
    losses = jnp.zeros((nblocks,), jnp.float32)
    w, mask_all, losses = jax.lax.fori_loop(
        0, nblocks, block_body, (w, mask_all, losses)
    )
    w = jnp.where(mask_all, 0.0, w)
    return w, mask_all, losses


def sparsegpt_prune(
    w: jax.Array,
    h: jax.Array,
    spec: SparsitySpec,
    blocksize: int = 128,
    gamma: float = 0.01,
    mask_override: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full SparseGPT (𝔖𝔖), or 𝔖-compensation under a given mask (𝔐𝔖).

    Returns (w_pruned, mask, per-block losses).
    """
    n, m = w.shape
    blocksize = min(blocksize, m)
    if m % blocksize:
        raise ValueError(f"cols {m} must divide by blocksize {blocksize}")
    spec.validate_block(blocksize)
    u = cholesky_inv_upper(h, gamma)
    if spec.is_semi_structured:
        pn, pm = spec.n, spec.m
        nppb = 0
    else:
        pn = pm = 0
        nppb = int(round(n * blocksize * spec.rate))
    dtype = w.dtype
    w_new, mask, losses = _sparsegpt_core(
        w, u, mask_override, blocksize, pn, pm, nppb
    )
    return w_new.astype(dtype), mask, losses
