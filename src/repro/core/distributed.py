"""Distributed pruning: data-parallel Hessians + row-parallel MRP solves.

Remark 4.2 (separate row computation) makes MRP pruning embarrassingly
parallel over weight rows: each row's compensation touches only that row's
pruned set and the (replicated) inverse Hessian.  We exploit it with
``shard_map`` over the ``model`` mesh axis:

  - calibration:  each data shard accumulates a local H = 2 x xᵀ over its
    calibration tokens; ``psum_hessian`` combines shards (token-weighted
    mean, matching HessianAccumulator.merge);
  - pruning:      weight rows are sharded over ``model``; H / Hinv are
    replicated; every shard runs the *same* per-layer pass on its rows.
    N:M masks are per-row ⇒ bitwise identical to the single-device result.
    Unstructured masks use the row-balanced variant (exact per-row counts)
    so selection never needs cross-shard coordination.

No collective happens inside a layer's solve — the only communication in
the whole pruning pass is the Hessian psum, once per linear.

Both entry points resolve the mesh from the active ``repro.dist`` context
when one is not passed explicitly — inside ``use_mesh(mesh)`` the call
sites never thread a mesh by hand.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.pruner import prune_matrix
from repro.core.sparsity import SparsitySpec
from repro.dist import current_ctx, shard_map
from repro.dist.sharding import replicated, row_sharding


def _resolve_mesh(mesh: Optional[Mesh]) -> Mesh:
    if mesh is not None:
        return mesh
    ctx = current_ctx()
    if ctx is None:
        raise ValueError(
            "no mesh given and no active device context — pass mesh= or "
            "call inside repro.dist.use_mesh(mesh)")
    return ctx.mesh


# ----------------------------------------------------------------------
# Hessian combination across data shards
# ----------------------------------------------------------------------
def psum_hessian(
    h_local: jax.Array, count_local: jax.Array, axis_name: str = "data"
) -> Tuple[jax.Array, jax.Array]:
    """Token-weighted mean of per-shard Hessians (call inside shard_map).

    Matches ``HessianAccumulator.merge``: H = Σ_s H_s·n_s / Σ_s n_s.
    """
    total = jax.lax.psum(count_local, axis_name)
    h = jax.lax.psum(h_local * count_local, axis_name) / jnp.maximum(total, 1.0)
    return h, total


def hessian_allreduce(
    mesh: Optional[Mesh], h_shards: jax.Array, counts: jax.Array,
    axis_name: str = "data"
) -> jax.Array:
    """Host-level convenience: merge per-shard Hessians stacked on axis 0.

    h_shards: (n_shards, m, m) placed along ``axis_name``; counts:
    (n_shards,).  ``mesh=None`` resolves the active context's mesh.
    """
    mesh = _resolve_mesh(mesh)
    ax = axis_name

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(ax), P(ax)),
        out_specs=P(),
    )
    def _merge(hs, cs):
        # each shard holds (1, m, m) / (1,)
        h, _ = psum_hessian(hs[0], cs[0], ax)
        return h

    return _merge(h_shards, counts)


# ----------------------------------------------------------------------
# Row-parallel layer pruning
# ----------------------------------------------------------------------
def prune_matrix_sharded(
    w: jax.Array,
    h: jax.Array,
    spec: SparsitySpec | str,
    mesh: Optional[Mesh] = None,
    method: str = "SM",
    blocksize: int = 128,
    gamma: float = 0.01,
    score: Optional[str] = None,
    row_chunk: Optional[int] = None,
    model_axis: str = "model",
) -> Tuple[jax.Array, jax.Array]:
    """Row-sharded prune: returns (w_pruned, mask) with w's sharding.

    Rows (output channels) are sharded over ``model_axis``; ``h`` is
    replicated.  Each shard runs the identical traceable pruning pass on
    its rows — zero collectives (Remark 4.2).  ``mesh=None`` resolves the
    active ``repro.dist`` context's mesh.
    """
    mesh = _resolve_mesh(mesh)
    if isinstance(spec, str):
        spec = SparsitySpec.parse(spec)
    n, m = w.shape
    n_shards = mesh.shape[model_axis]
    if n % n_shards:
        raise ValueError(f"rows {n} not divisible by {model_axis}={n_shards}")

    def _local(w_loc, h_rep):
        res = prune_matrix(
            w_loc,
            h_rep,
            spec,
            method=method,
            blocksize=blocksize,
            gamma=gamma,
            score=score,
            row_chunk=row_chunk,
            row_balanced=True,          # static shapes, per-row selection
        )
        return res.w, res.mask

    fn = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(model_axis, None), P(None, None)),
        out_specs=(P(model_axis, None), P(model_axis, None)),
        check_vma=False,
    )
    w_sh = jax.device_put(w, row_sharding(mesh, model_axis))
    h_rep = jax.device_put(h, replicated(mesh))
    return fn(w_sh, h_rep)
