"""Distributed pruning: data-parallel Hessians + row-parallel MRP solves.

Remark 4.2 (separate row computation) makes MRP pruning embarrassingly
parallel over weight rows: each row's compensation touches only that row's
pruned set and the (replicated) inverse Hessian.  We exploit it with
``shard_map`` over the ``model`` mesh axis:

  - calibration:  each data shard accumulates a local H = 2 x xᵀ over its
    calibration tokens; ``psum_hessian`` combines shards (token-weighted
    mean, matching HessianAccumulator.merge);
  - pruning:      weight rows are sharded over ``model``; H / Hinv are
    replicated; every shard runs the *same* per-layer pass on its rows.
    N:M masks are per-row ⇒ bitwise identical to the single-device result.
    Unstructured masks use the row-balanced variant (exact per-row counts)
    so selection never needs cross-shard coordination.

No collective happens inside a layer's solve — the only communication in
the whole pruning pass is the Hessian psum, once per linear.

Both entry points resolve the mesh from the active ``repro.dist`` context
when one is not passed explicitly — inside ``use_mesh(mesh)`` the call
sites never thread a mesh by hand.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.calibration import CalibrationSet
from repro.core.hessian import HessianAccumulator
from repro.core.pruner import prune_matrix
from repro.core.sparsity import SparsitySpec
from repro.dist import current_ctx, shard_map
from repro.dist.sharding import replicated, row_sharding

Axes = Union[str, Sequence[str]]


def _resolve_mesh(mesh: Optional[Mesh]) -> Mesh:
    if mesh is not None:
        return mesh
    ctx = current_ctx()
    if ctx is None:
        raise ValueError(
            "no mesh given and no active device context — pass mesh= or "
            "call inside repro.dist.use_mesh(mesh)")
    return ctx.mesh


def _as_axes(axis_name: Axes) -> Tuple[str, ...]:
    return (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)


# ----------------------------------------------------------------------
# Hessian combination across data shards
# ----------------------------------------------------------------------
def psum_hessian(
    h_local: jax.Array, count_local: jax.Array, axis_name: Axes = "data"
) -> Tuple[jax.Array, jax.Array]:
    """Token-weighted mean of per-shard Hessians (call inside shard_map).

    Matches ``HessianAccumulator.merge``: H = Σ_s H_s·n_s / Σ_s n_s.
    ``axis_name`` may be one axis or several (``("pod", "data")`` reduces
    over DCN and within-pod batch shards in one collective).
    """
    ax = axis_name if isinstance(axis_name, str) else tuple(axis_name)
    total = jax.lax.psum(count_local, ax)
    h = jax.lax.psum(h_local * count_local, ax) / jnp.maximum(total, 1.0)
    return h, total


def hessian_allreduce(
    mesh: Optional[Mesh], h_shards: jax.Array, counts: jax.Array,
    axis_name: Axes = "data"
) -> jax.Array:
    """Host-level convenience: merge per-shard Hessians stacked on axis 0.

    h_shards: (n_shards, m, m) placed along ``axis_name`` (one axis or a
    tuple like ``("pod", "data")`` — n_shards must equal the product of
    the axis sizes); counts: (n_shards,).  ``mesh=None`` resolves the
    active context's mesh.
    """
    mesh = _resolve_mesh(mesh)
    return _allreduce_fn(mesh, _as_axes(axis_name))(h_shards, counts)


@functools.lru_cache(maxsize=64)
def _allreduce_fn(mesh: Mesh, axes: Tuple[str, ...]):
    """Compiled Hessian-merge collective, cached per (mesh, axes) —
    shard_map re-traces on every fresh closure, and the engine calls
    this once per linear per segment."""
    ax_entry = axes if len(axes) > 1 else axes[0]

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(ax_entry), P(ax_entry)),
        out_specs=P(),
    )
    def _merge(hs, cs):
        # each shard holds (1, m, m) / (1,)
        h, _ = psum_hessian(hs[0], cs[0], ax_entry)
        return h

    return jax.jit(_merge)


def allreduce_calibration(
    sets: Sequence[CalibrationSet],
    mesh: Optional[Mesh] = None,
    axis_name: Axes = "data",
) -> CalibrationSet:
    """Merge per-shard :class:`CalibrationSet`s over the mesh's batch axes.

    Each entry of ``sets`` is one data(+pod) shard's accumulated
    calibration state for the same segment; the merged Hessian per linear
    comes from one :func:`hessian_allreduce` collective with the stacked
    per-shard Hessians placed along ``axis_name`` — no host round-trips.
    When the shard count does not match the axis sizes (e.g. calibration
    was split more coarsely than the mesh), falls back to the on-device
    tree merge ``CalibrationSet.merge_all``.
    """
    sets = list(sets)
    if len(sets) == 1:
        return sets[0]
    mesh = _resolve_mesh(mesh)
    axes = _as_axes(axis_name)
    n_axes = 1
    for a in axes:
        n_axes *= mesh.shape[a]
    if len(sets) != n_axes:
        return CalibrationSet.merge_all(sets)

    out = CalibrationSet()
    names = set().union(*(set(s.accs) for s in sets))
    stack_sh = row_sharding(mesh, axes, ndim=3)
    count_sh = row_sharding(mesh, axes, ndim=1)
    for name in sorted(names):
        if any(name not in s.accs for s in sets):
            # a linear some shard never saw (shouldn't happen for dense
            # segments) — degrade to the tree merge for this name only
            accs = [s.accs[name] for s in sets if name in s.accs]
            out.accs[name] = HessianAccumulator.merge_many(accs)
            continue
        accs = [s.accs[name] for s in sets]
        hs = jax.device_put(jnp.stack([a.h for a in accs]), stack_sh)
        cs = jnp.stack([a.count for a in accs])
        h = hessian_allreduce(mesh, hs, jax.device_put(cs, count_sh),
                              axis_name=axes)
        if _cpu_multidevice():
            # XLA's CPU runtime deadlocks on concurrent independent
            # collective programs (see core.pipeline.strict_collective_
            # sync) — drain each linear's allreduce before the next
            jax.block_until_ready(h)
        out.accs[name] = HessianAccumulator(
            accs[0].dim, h=h, count=jnp.sum(cs))
    return out


def _cpu_multidevice() -> bool:
    return jax.default_backend() == "cpu" and jax.device_count() > 1


# ----------------------------------------------------------------------
# Row-parallel layer pruning
# ----------------------------------------------------------------------
def prune_matrix_sharded(
    w: jax.Array,
    h: jax.Array,
    spec: SparsitySpec | str,
    mesh: Optional[Mesh] = None,
    method: str = "SM",
    blocksize: int = 128,
    gamma: float = 0.01,
    score: Optional[str] = None,
    row_chunk: Optional[int] = None,
    model_axis: str = "model",
) -> Tuple[jax.Array, jax.Array]:
    """Row-sharded prune: returns (w_pruned, mask) with w's sharding.

    Rows (output channels) are sharded over ``model_axis``; ``h`` is
    replicated.  Each shard runs the identical traceable pruning pass on
    its rows — zero collectives (Remark 4.2).  ``mesh=None`` resolves the
    active ``repro.dist`` context's mesh.
    """
    mesh = _resolve_mesh(mesh)
    if isinstance(spec, str):
        spec = SparsitySpec.parse(spec)
    n, m = w.shape
    n_shards = mesh.shape[model_axis]
    if n % n_shards:
        raise ValueError(f"rows {n} not divisible by {model_axis}={n_shards}")

    fn = _sharded_prune_fn(
        mesh, spec, method, blocksize, gamma, score, row_chunk, model_axis)
    w_sh = jax.device_put(w, row_sharding(mesh, model_axis))
    h_rep = jax.device_put(h, replicated(mesh))
    return fn(w_sh, h_rep)


@functools.lru_cache(maxsize=256)
def _sharded_prune_fn(
    mesh: Mesh,
    spec: SparsitySpec,
    method: str,
    blocksize: int,
    gamma: float,
    score: Optional[str],
    row_chunk: Optional[int],
    model_axis: str,
):
    """Compiled row-parallel layer solve, cached per (mesh, prune
    config); jit keys on the weight/Hessian shapes, so every linear of
    the same shape across all segments shares one compilation (a fresh
    shard_map closure per call re-traced the whole MRP block loop —
    28 compiles per tiny-LM prune, the wall-clock dominator)."""

    def _local(w_loc, h_rep):
        res = prune_matrix(
            w_loc,
            h_rep,
            spec,
            method=method,
            blocksize=blocksize,
            gamma=gamma,
            score=score,
            row_chunk=row_chunk,
            row_balanced=True,          # static shapes, per-row selection
        )
        return res.w, res.mask

    return jax.jit(shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(model_axis, None), P(None, None)),
        out_specs=(P(model_axis, None), P(model_axis, None)),
        check_vma=False,
    ))
