"""Pruning-mask construction and algebra.

Masks follow the paper's convention: boolean array, **True = pruned**.
Selection always takes the *lowest-score* weights (scores are estimated
pruning losses — see core.scores).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------
# Unstructured: exact-count selection within a (n, S) column block
# ----------------------------------------------------------------------
def unstructured_mask_from_scores(scores: jax.Array, num_prune: int) -> jax.Array:
    """Prune exactly ``num_prune`` weights with the smallest scores.

    Selection is global across the whole (n, S) block — rows may lose
    different numbers of weights (k_i varies per row), matching SparseGPT's
    per-block thresholding and the paper's MRP formulation.
    """
    n, s = scores.shape
    if num_prune <= 0:
        return jnp.zeros((n, s), bool)
    if num_prune >= n * s:
        return jnp.ones((n, s), bool)
    flat = scores.reshape(-1)
    # kth-smallest threshold with exact tie-breaking via argsort ranks.
    order = jnp.argsort(flat)  # ascending
    mask_flat = jnp.zeros((n * s,), bool).at[order[:num_prune]].set(True)
    return mask_flat.reshape(n, s)


def unstructured_mask_rowwise(scores: jax.Array, per_row: int) -> jax.Array:
    """Prune exactly ``per_row`` lowest-score weights in every row.

    Row-balanced variant of :func:`unstructured_mask_from_scores`: the
    pruned-per-row count k_i is a static constant, which (a) makes the MRP
    padded solve's k_max exact with zero padding waste, (b) keeps the whole
    pruning pass traceable (no host sync), so it can run inside shard_map /
    jit on TPU, and (c) load-balances row-sharded pruning.  Slightly less
    optimal than global block selection when per-row saliency mass is very
    uneven; measured in benchmarks/ablation.
    """
    n, s = scores.shape
    if per_row <= 0:
        return jnp.zeros((n, s), bool)
    if per_row >= s:
        return jnp.ones((n, s), bool)
    _, idx = jax.lax.top_k(-scores, per_row)                 # (n, per_row)
    return jnp.zeros((n, s), bool).at[
        jnp.arange(n)[:, None], idx
    ].set(True)


# ----------------------------------------------------------------------
# Semi-structured N:M from per-weight scores (Solution 𝔖 mask)
# ----------------------------------------------------------------------
def nm_mask_from_scores(scores: jax.Array, n_prune: int, m_group: int) -> jax.Array:
    """Prune the ``n_prune`` lowest-score weights in every group of
    ``m_group`` consecutive weights along the last axis."""
    r, c = scores.shape
    if c % m_group:
        raise ValueError(f"cols {c} not divisible by M={m_group}")
    g = scores.reshape(r, c // m_group, m_group)
    # top_k on negated scores ⇒ the n smallest per group.
    _, idx = jax.lax.top_k(-g, n_prune)  # (r, G, n)
    onehot = jax.nn.one_hot(idx, m_group, dtype=jnp.float32).sum(-2) > 0  # (r,G,M)
    return onehot.reshape(r, c)


# ----------------------------------------------------------------------
# Padded per-row index extraction (for the batched MRP solve)
# ----------------------------------------------------------------------
def padded_row_indices(mask: jax.Array, k_max: int):
    """Per-row pruned column indexes, padded to ``k_max``.

    Returns (idx, valid):
      idx   (n, k_max) int32  — pruned column positions (arbitrary pad value
                                 for the padding tail)
      valid (n, k_max) bool   — True where the slot holds a real index.

    Rows are sorted so real indices come first. ``k_max`` must be ≥ the max
    per-row pruned count (checked by callers; excess is silently truncated,
    which callers must avoid by sizing k_max correctly).
    """
    n, m = mask.shape
    k_max = int(k_max)
    # Sort key: pruned entries get their column index, others get m + col
    # (stable ascending puts pruned columns, in order, first).
    cols = jnp.arange(m, dtype=jnp.int32)[None, :]
    key = jnp.where(mask, cols, cols + m)
    order = jnp.argsort(key, axis=1)[:, :k_max].astype(jnp.int32)
    counts = mask.sum(axis=1, dtype=jnp.int32)
    valid = jnp.arange(k_max, dtype=jnp.int32)[None, :] < counts[:, None]
    return order, valid


def max_row_count(mask: jax.Array) -> int:
    """Host-side max pruned-per-row (concretizes — call outside jit)."""
    return int(jax.device_get(mask.sum(axis=1).max()))


def bucket_k(k: int, step: int = 32) -> int:
    """Round k up to a bucket to bound jit recompilations across blocks."""
    if k <= 0:
        return step
    return int(np.ceil(k / step) * step)


def validate_nm(mask: np.ndarray, n_prune: int, m_group: int) -> bool:
    """Check that every group of M has exactly N pruned (host-side)."""
    r, c = mask.shape
    g = np.asarray(mask).reshape(r, c // m_group, m_group)
    return bool((g.sum(-1) == n_prune).all())


def sparsity_of(mask: jax.Array) -> float:
    return float(jax.device_get(jnp.mean(mask.astype(jnp.float32))))
