"""Per-weight saliency scores for pruning-mask selection (Solution 𝔖 family).

All scores are "loss if this weight were pruned alone" proxies; lower
score ⇒ pruned first.

  - magnitude:  |w|                        (Zhu & Gupta 2017)
  - wanda:      |w| · ‖x_j‖₂               (Sun et al. 2023)
  - obs:        w² / (2 [H⁻¹]_jj)          (paper Eq. 14 — Solution 𝔖)
  - sparsegpt:  w² / [H⁻¹]_jj²             (SparseGPT public code variant)

`obs` is the exact single-removal loss (Eq. 14), derived from the MRP loss
Eq. 12 under a diagonal-interaction assumption. SparseGPT's released code
uses the square of the inverse diagonal instead; we keep both so the 𝔖𝔖
baseline can match either convention (`sparsegpt` is the default for the
baseline, `obs` for our methods, per DESIGN.md §8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def magnitude_score(w: jax.Array) -> jax.Array:
    return jnp.abs(w)


def wanda_score(w: jax.Array, h: jax.Array) -> jax.Array:
    """|w| * ||x_j||_2 per input column.

    H = mean_t 2 x xᵀ ⇒ diag(H)_j = 2·mean_t x_j² ⇒ ‖x_j‖ ∝ sqrt(diag(H)_j).
    The constant factor is rank-irrelevant.
    """
    norms = jnp.sqrt(jnp.clip(jnp.diag(h), 0.0, None))
    return jnp.abs(w) * norms[None, :]


def obs_score(w: jax.Array, hinv: jax.Array) -> jax.Array:
    """Paper Eq. (14): L̂ = w_ij² / (2 [H⁻¹]_jj)."""
    d = jnp.clip(jnp.diag(hinv), 1e-30, None)
    return (w.astype(jnp.float32) ** 2) / (2.0 * d[None, :])


def sparsegpt_score(w: jax.Array, hinv: jax.Array) -> jax.Array:
    """SparseGPT code's criterion: w² / diag(H⁻¹)² (uses the Cholesky diag)."""
    d = jnp.clip(jnp.diag(hinv), 1e-30, None)
    return (w.astype(jnp.float32) ** 2) / (d[None, :] ** 2)


SCORE_FNS = {
    "magnitude": lambda w, h, hinv: magnitude_score(w),
    "wanda": lambda w, h, hinv: wanda_score(w, h),
    "obs": lambda w, h, hinv: obs_score(w, hinv),
    "sparsegpt": lambda w, h, hinv: sparsegpt_score(w, hinv),
}


def compute_score(name: str, w: jax.Array, h: jax.Array, hinv: jax.Array) -> jax.Array:
    try:
        fn = SCORE_FNS[name]
    except KeyError:
        raise ValueError(f"unknown score {name!r}; one of {sorted(SCORE_FNS)}")
    return fn(w, h, hinv)
