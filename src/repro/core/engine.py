"""Whole-model layer-wise pruning engine (paper Sec. 5, SparseGPT-style).

The engine walks a model segment by segment (transformer block by block —
"sequentially load and prune one single block instead of the whole model"),
so peak memory is one segment's weights + Hessians:

  for each segment:
    1. run calibration hiddens through the segment in capture mode,
       accumulating H = mean_t 2 x xᵀ per prunable linear;
    2. prune every linear with core.pruner.prune_matrix (SS/SM/MS/MM/...);
    3. re-run the segment with the *pruned* weights to produce the next
       segment's calibration inputs (error does not compound silently —
       downstream layers calibrate on what they will actually see).

Model contract (duck-typed; implemented by models/):

  model.prunable_segments() -> list[SegmentSpec]
  model.first_hidden(params, batch) -> h        # embedding/frontend output

Fault tolerance: pass ``progress_store`` (ckpt.PruneProgressStore) and the
engine checkpoints (segment index, params) after every segment; ``run``
resumes from the last completed segment automatically.

Distribution: pass ``mesh=`` or construct the engine inside
``repro.dist.use_mesh(mesh)`` and every divisible layer solve runs
row-parallel over the mesh's ``model`` axis (core.distributed,
Remark 4.2); without a mesh the engine is the paper's host-driven loop.

Pipelining: by default (``pipeline="auto"``) the engine drives the
batched/jitted/async scheduler in :mod:`repro.core.pipeline` — stacked
calibration batches, per-data-shard Hessian accumulation merged with
``hessian_allreduce`` (``calib_shard``), and capture/solve/propagate
overlap via async dispatch.  ``pipeline="off"`` keeps the paper's serial
per-batch loop (the semantic reference; identical results, tested).
"""

from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.calibration import CalibrationSet, Capture
from repro.core.pruner import PruneResult, prune_matrix
from repro.core.sparsity import SparsitySpec

log = logging.getLogger("repro.engine")


@functools.lru_cache(maxsize=256)
def _local_solve_fn(spec, method, blocksize, gamma, score, row_chunk,
                    row_balanced):
    def f(w, h):
        res = prune_matrix(
            w, h, spec, method=method, blocksize=blocksize, gamma=gamma,
            score=score, row_chunk=row_chunk, row_balanced=row_balanced)
        return res.w, res.mask, res.loss
    return jax.jit(f)


@dataclasses.dataclass
class LinearSpec:
    """Handle to one prunable weight inside a segment's params.

    ``get`` must return the weight in the paper's (n_out, m_in) orientation
    (``y = w x``); ``set`` writes it back (transposing as needed for the
    model's storage layout).
    """

    name: str
    get: Callable[[Any], jax.Array]
    set: Callable[[Any, jax.Array], Any]


@dataclasses.dataclass
class SegmentSpec:
    """One sequentially-prunable model segment (usually one block)."""

    name: str
    apply: Callable[..., Tuple[jax.Array, Dict[str, Capture]]]
    #      (seg_params, h, capture: bool) -> (h_out, captures)
    linears: List[LinearSpec]
    get_params: Callable[[Any], Any]
    set_params: Callable[[Any, Any], Any]


@dataclasses.dataclass
class LinearReport:
    name: str
    method: str
    sparsity: float
    recon_error: float
    # serial mode: the solve's blocking wall-clock.  Pipelined mode: the
    # host *dispatch* time only (solves execute async; per-linear device
    # time is unobservable without stalling the queue — use
    # engine.last_pipeline_stats for stage-level costs).
    seconds: float
    shape: Tuple[int, int]


class PruningEngine:
    """Drives Algorithm 1 across a whole model."""

    def __init__(
        self,
        model,
        spec: SparsitySpec | str,
        method: str = "SM",
        blocksize: int = 128,
        gamma: float = 0.01,
        score: Optional[str] = None,
        row_chunk: Optional[int] = None,
        row_balanced: bool = False,
        skip: Sequence[str] = (),
        progress_store=None,
        mesh=None,
        pipeline: str = "auto",
        calib_shard="auto",
    ):
        self.model = model
        self.spec = SparsitySpec.parse(spec) if isinstance(spec, str) else spec
        self.method = method
        self.blocksize = blocksize
        self.gamma = gamma
        self.score = score
        self.row_chunk = row_chunk
        self.row_balanced = row_balanced
        self.skip = tuple(skip)
        self.progress_store = progress_store
        if pipeline not in ("auto", "on", "off", True, False, None):
            raise ValueError(
                f"pipeline={pipeline!r} not in ('auto', 'on', 'off')")
        self.pipeline = pipeline
        self.calib_shard = calib_shard
        self.last_pipeline_stats = None
        self._solve_fn = None
        if mesh is None:
            from repro.dist import current_ctx

            ctx = current_ctx()
            mesh = ctx.mesh if ctx is not None else None
        self.mesh = mesh

    # ------------------------------------------------------------------
    def _should_skip(self, name: str) -> bool:
        return any(pat in name for pat in self.skip)

    def _model_parallel(self) -> int:
        """Shards available for the row-parallel layer solve."""
        if self.mesh is None or "model" not in self.mesh.axis_names:
            return 1
        return self.mesh.shape["model"]

    def _local_solve(self) -> Callable:
        """Jitted local layer solve (traceable specs only): returns
        (w_pruned, mask, loss) with the loss left on device — the
        pipelined path must not sync the host per linear.  Cached per
        prune config (module level), so every engine in a process shares
        one compilation per layer shape."""
        if self._solve_fn is None:
            self._solve_fn = _local_solve_fn(
                self.spec, self.method, self.blocksize, self.gamma,
                self.score, self.row_chunk, self.row_balanced)
        return self._solve_fn

    def _prune_one(self, w: jax.Array, hmat: jax.Array,
                   sync: bool = True) -> PruneResult:
        """One layer solve — row-parallel over the mesh's ``model`` axis
        when active and the rows divide (Remark 4.2), else local.

        The sharded path selects masks per-row (its static-shape
        requirement), so unstructured specs only take it when the engine
        was configured ``row_balanced`` — a global-top-k request must not
        silently change selection semantics under a mesh.

        ``sync=False`` (the pipelined scheduler) keeps the result's loss
        a device array and routes traceable local solves through one
        cached jit, so nothing here blocks the async dispatch queue.
        """
        tp = self._model_parallel()
        traceable = self.spec.is_semi_structured or self.row_balanced
        if (tp > 1 and w.ndim == 2 and w.shape[0] % tp == 0 and traceable):
            from repro.core.distributed import prune_matrix_sharded
            from repro.core.pruner import reconstruction_error_traced

            w_new, mask = prune_matrix_sharded(
                w, hmat, self.spec, self.mesh, method=self.method,
                blocksize=self.blocksize, gamma=self.gamma,
                score=self.score, row_chunk=self.row_chunk)
            loss = reconstruction_error_traced(w, w_new, hmat)
            return PruneResult(
                w_new, mask, float(loss) if sync else loss,
                self.method, self.spec)
        if not sync and traceable:
            w_new, mask, loss = self._local_solve()(w, hmat)
            return PruneResult(w_new, mask, loss, self.method, self.spec)
        return prune_matrix(
            w, hmat, self.spec, method=self.method,
            blocksize=self.blocksize, gamma=self.gamma, score=self.score,
            row_chunk=self.row_chunk, row_balanced=self.row_balanced)

    def _pipeline_enabled(self) -> bool:
        return self.pipeline not in ("off", False, None)

    def run(
        self, params: Any, calib_batches: Sequence[Any]
    ) -> Tuple[Any, List[LinearReport]]:
        """Prune the whole model. ``calib_batches``: token batches.

        Dispatches to the pipelined scheduler (core.pipeline) unless
        ``pipeline="off"`` selected the serial reference loop.
        """
        if self._pipeline_enabled():
            from repro.core.pipeline import run_pipelined

            return run_pipelined(self, params, calib_batches)
        return self._run_serial(params, calib_batches)

    def _run_serial(
        self, params: Any, calib_batches: Sequence[Any]
    ) -> Tuple[Any, List[LinearReport]]:
        """The paper's host-driven per-batch loop (``pipeline="off"``)."""
        self.last_pipeline_stats = None
        segments = self.model.prunable_segments()
        reports: List[LinearReport] = []

        start_seg = 0
        hiddens = None
        if self.progress_store is not None:
            loader = getattr(self.progress_store, "load_into", None)
            resumed = loader(params) if loader else self.progress_store.load()
            if resumed is not None:
                start_seg, params = resumed
                log.info("resuming pruning at segment %d", start_seg)

        # calibration hiddens entering the first (or resumed-at) segment
        # (models may provide calib_init when their calibration state is
        # richer than a single hidden array — e.g. enc-dec models flow
        # {"h": decoder, "enc": encoder} through the segments)
        init_fn = getattr(self.model, "calib_init", None) or self.model.first_hidden
        hiddens = [init_fn(params, b) for b in calib_batches]
        for seg in segments[:start_seg]:
            seg_params = seg.get_params(params)
            hiddens = [seg.apply(seg_params, h, capture=False)[0] for h in hiddens]

        for si in range(start_seg, len(segments)):
            seg = segments[si]
            seg_params = seg.get_params(params)

            # 1. capture + accumulate Hessians
            calib = CalibrationSet()
            for h in hiddens:
                _, caps = seg.apply(seg_params, h, capture=True)
                calib.update(caps)

            # 2. prune each linear (specs may resolve lazily from params)
            linears = seg.linears
            if linears is None:
                linears = self.model.segment_linears(seg, seg_params)
            for lin in linears:
                if self._should_skip(f"{seg.name}.{lin.name}"):
                    continue
                if lin.name not in calib.accs:
                    raise KeyError(
                        f"segment {seg.name}: no capture for linear "
                        f"{lin.name!r} (captures: {sorted(calib.names())})")
                w = lin.get(seg_params)
                hmat = calib.hessian(lin.name)
                t0 = time.monotonic()
                res: PruneResult = self._prune_one(w, hmat)
                seg_params = lin.set(seg_params, res.w)
                reports.append(
                    LinearReport(
                        name=f"{seg.name}.{lin.name}",
                        method=self.method,
                        sparsity=res.sparsity,
                        recon_error=res.loss,
                        seconds=time.monotonic() - t0,
                        shape=tuple(w.shape),
                    )
                )

            # 3. write back + propagate with pruned weights
            params = seg.set_params(params, seg_params)
            hiddens = [seg.apply(seg_params, h, capture=False)[0] for h in hiddens]

            if self.progress_store is not None:
                self.progress_store.save(si + 1, params)

        if self.progress_store is not None:
            self.progress_store.finalize()
        return params, reports


def summarize(reports: Sequence[LinearReport]) -> Dict[str, float]:
    if not reports:
        return {"linears": 0}
    return {
        "linears": len(reports),
        "mean_sparsity": float(
            sum(r.sparsity for r in reports) / len(reports)),
        "total_recon_error": float(sum(r.recon_error for r in reports)),
        "total_seconds": float(sum(r.seconds for r in reports)),
    }
