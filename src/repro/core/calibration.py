"""Layer-wise calibration capture (paper Sec. 3.3 / SparseGPT Sec. 3).

The pruning engine processes one model segment (transformer block) at a
time: it runs the calibration set through the segment in *capture* mode,
which returns — alongside the hidden states — the inputs ``x`` of every
linear layer inside the segment.  Those feed the streaming Hessian
accumulators (H = mean_t 2 x_t x_tᵀ), one per prunable linear.

Capture format (the contract between models/ and core/engine):

  captures: dict[str, Capture]
  Capture  = x                      # (..., T, d_in) dense-token linear
           | (x, weights)           # weights (..., T) — MoE routed tokens /
                                    # padding validity; 0-weight tokens are
                                    # excluded from the Hessian.

Leading dims are arbitrary (batch, experts, ...) and get flattened here.
"""

from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple, Union

import jax

from repro.core.hessian import HessianAccumulator

Capture = Union[jax.Array, Tuple[jax.Array, jax.Array]]


def _flatten_capture(cap: Capture) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Normalize a capture to (x2d (T, d), weights (T,) | None)."""
    if isinstance(cap, tuple):
        x, w = cap
        d = x.shape[-1]
        x2 = x.reshape(-1, d)
        w2 = w.reshape(-1)
        if w2.shape[0] != x2.shape[0]:
            raise ValueError(
                f"capture weights {w.shape} incompatible with x {x.shape}")
        return x2, w2
    d = cap.shape[-1]
    return cap.reshape(-1, d), None


class CalibrationSet:
    """Holds one Hessian accumulator per (named) linear in a segment."""

    def __init__(self):
        self.accs: Dict[str, HessianAccumulator] = {}

    def update(self, captures: Mapping[str, Capture]) -> None:
        for name, cap in captures.items():
            x2, w2 = _flatten_capture(cap)
            acc = self.accs.get(name)
            if acc is None:
                acc = HessianAccumulator(x2.shape[1])
                self.accs[name] = acc
            if w2 is None:
                acc.update_tokens(x2)
            else:
                acc.update_weighted(x2.T, w2)

    @classmethod
    def from_captures(cls, captures: Mapping[str, Capture]) -> "CalibrationSet":
        """One-shot construction from a single (batched) capture dict."""
        out = cls()
        out.update(captures)
        return out

    def merge(self, other: "CalibrationSet") -> "CalibrationSet":
        out = CalibrationSet()
        names = set(self.accs) | set(other.accs)
        for name in names:
            a, b = self.accs.get(name), other.accs.get(name)
            if a is None:
                out.accs[name] = b
            elif b is None:
                out.accs[name] = a
            else:
                out.accs[name] = a.merge(b)
        return out

    @classmethod
    def merge_all(cls, sets: "Sequence[CalibrationSet]") -> "CalibrationSet":
        """Merge N per-shard sets on device (one fused op per linear).

        Unlike folding :meth:`merge` pairwise this dispatches a single
        stacked weighted mean per linear — the host never materializes
        an intermediate Hessian (calibration sharding, core.pipeline).
        """
        sets = list(sets)
        if len(sets) == 1:
            return sets[0]
        out = cls()
        names = set().union(*(set(s.accs) for s in sets))
        for name in sorted(names):
            accs = [s.accs[name] for s in sets if name in s.accs]
            out.accs[name] = HessianAccumulator.merge_many(accs)
        return out

    def hessian(self, name: str) -> jax.Array:
        return self.accs[name].finalize()

    def names(self) -> Iterable[str]:
        return self.accs.keys()
