"""Sparsity specifications: unstructured rate or semi-structured N:M.

A spec is parsed from strings like "0.5" (50% unstructured) or "2:4"
(N:M semi-structured — N pruned out of every M consecutive weights in a
row, matching the paper's Sec. 4.3.2 / NVIDIA 2:4 convention).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SparsitySpec:
    """Either unstructured (rate in (0,1)) or semi-structured N:M."""

    rate: Optional[float] = None  # unstructured sparsity fraction
    n: Optional[int] = None       # pruned per group (semi-structured)
    m: Optional[int] = None       # group size (semi-structured)

    # ------------------------------------------------------------------
    @staticmethod
    def parse(text: str) -> "SparsitySpec":
        text = str(text).strip()
        if ":" in text:
            n_s, m_s = text.split(":")
            n, m = int(n_s), int(m_s)
            if not (0 < n < m):
                raise ValueError(f"invalid N:M sparsity {text!r}: need 0<N<M")
            return SparsitySpec(n=n, m=m)
        rate = float(text)
        if not (0.0 < rate < 1.0):
            raise ValueError(f"invalid unstructured sparsity {rate}: need (0,1)")
        return SparsitySpec(rate=rate)

    @staticmethod
    def unstructured(rate: float) -> "SparsitySpec":
        return SparsitySpec.parse(str(rate))

    @staticmethod
    def semi_structured(n: int, m: int) -> "SparsitySpec":
        return SparsitySpec.parse(f"{n}:{m}")

    # ------------------------------------------------------------------
    @property
    def is_semi_structured(self) -> bool:
        return self.n is not None

    @property
    def fraction(self) -> float:
        """Overall fraction of weights pruned."""
        if self.is_semi_structured:
            return self.n / self.m
        return float(self.rate)

    def pruned_per_row_block(self, block_cols: int) -> int:
        """Number of weights pruned in each row within a column block."""
        if self.is_semi_structured:
            if block_cols % self.m:
                raise ValueError(
                    f"block of {block_cols} cols not divisible by M={self.m}")
            return (block_cols // self.m) * self.n
        return int(math.floor(block_cols * self.rate + 1e-9))

    def validate_block(self, block_cols: int) -> None:
        if self.is_semi_structured and block_cols % self.m:
            raise ValueError(
                f"blocksize {block_cols} incompatible with {self.n}:{self.m}")

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        if self.is_semi_structured:
            return f"{self.n}:{self.m}"
        return f"{self.rate:g}"
