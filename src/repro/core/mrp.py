"""The Multiple Removal Problem (MRP) — the paper's core contribution.

Closed-form optimal solution (Sec. 4.1). For each row q with pruned column
set P (selector E ∈ R^{m×k}), with H = 2xxᵀ + γI and Hinv = H⁻¹:

  Eq. (13):  δw*[q,:] = − w[q,P] · (Eᵀ Hinv E)⁻¹ · Eᵀ Hinv
  Eq. (12):  L*_q     = ½ · w[q,P] · (Eᵀ Hinv E)⁻¹ · w[q,P]ᵀ

TPU-native batching (DESIGN.md §4.1): instead of the paper's per-row GPU
loop we pad every row's pruned set to a common k_max and run ONE batched
symmetric solve over all rows:

  A_q = Hinv[P_q, P_q]   (k_max×k_max, identity-padded)
  z_q = A_q⁻¹ w[q, P_q]  (zero-padded rhs ⇒ padding rows solve to zero)
  δw[q, :] = − scatter(z_q) @ Hinv      (one dense (n,m)@(m,m) matmul)
  L_q      = ½ ⟨z_q, w[q, P_q]⟩

Identity padding makes the padded solve *exactly* equal to the unpadded
one, so this is the paper's optimal solution, not an approximation.
Rows are independent (Remark 4.2) ⇒ the row dimension shards freely over
the `model` mesh axis (core.distributed).
"""

from __future__ import annotations

import functools
import itertools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import masks as masks_lib


# ----------------------------------------------------------------------
# Batched padded-row compensation (Solutions 𝔐 for compensation)
# ----------------------------------------------------------------------
def _gather_submatrix(hinv: jax.Array, idx: jax.Array, valid: jax.Array) -> jax.Array:
    """A = Hinv[idx, idx] with identity padding on invalid slots.

    hinv: (m, m); idx: (n, k); valid: (n, k) → (n, k, k).
    """
    rows = hinv[idx]                                     # (n, k, m)
    sub = jnp.take_along_axis(
        rows, idx[:, None, :].repeat(idx.shape[1], 1), axis=2
    )                                                    # (n, k, k)
    k = idx.shape[1]
    eye = jnp.eye(k, dtype=hinv.dtype)
    vv = valid[:, :, None] & valid[:, None, :]
    return jnp.where(vv, sub, eye[None])


@functools.partial(jax.jit, static_argnames=("row_chunk",))
def mrp_compensate(
    w: jax.Array,
    hinv: jax.Array,
    idx: jax.Array,
    valid: jax.Array,
    row_chunk: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Apply Eq. (13) compensation for the pruned sets given per row.

    Args:
      w:     (n, m) weights (pruned slots may hold any value; they are
             zeroed exactly by the optimal δw).
      hinv:  (m, m) dampened inverse Hessian.
      idx:   (n, k_max) per-row pruned columns (padded).
      valid: (n, k_max) validity of idx slots.
      row_chunk: process rows in chunks of this size (memory control for
             the (chunk, k, k) gather); None = all rows at once.

    Returns:
      (w_new, loss_per_row) — w_new has *exact* zeros at pruned slots;
      loss_per_row is Eq. (12)'s per-row L* (float32, shape (n,)).
    """
    n, m = w.shape
    w32 = w.astype(jnp.float32)
    hinv = hinv.astype(jnp.float32)

    def solve_rows(w_rows, idx_rows, valid_rows):
        a = _gather_submatrix(hinv, idx_rows, valid_rows)          # (c,k,k)
        wp = jnp.take_along_axis(w_rows, idx_rows, axis=1)
        wp = jnp.where(valid_rows, wp, 0.0)                        # (c,k)
        # A is a principal submatrix of a PD matrix ⇒ PD ⇒ Cholesky solve.
        chol = jax.scipy.linalg.cho_factor(a, lower=True)
        z = jax.scipy.linalg.cho_solve(chol, wp[..., None])[..., 0]  # (c,k)
        z = jnp.where(valid_rows, z, 0.0)
        loss = 0.5 * jnp.sum(z * wp, axis=1)                       # (c,)
        # Scatter z back to full width and do ONE dense matmul with Hinv.
        zfull = jnp.zeros_like(w_rows).at[
            jnp.arange(w_rows.shape[0])[:, None], idx_rows
        ].add(jnp.where(valid_rows, z, 0.0))
        delta = -(zfull @ hinv)                                    # (c,m)
        return w_rows + delta, loss

    if row_chunk is None or row_chunk >= n:
        w_new, loss = solve_rows(w32, idx, valid)
    else:
        pad = (-n) % row_chunk
        wp_ = jnp.pad(w32, ((0, pad), (0, 0)))
        ip_ = jnp.pad(idx, ((0, pad), (0, 0)))
        vp_ = jnp.pad(valid, ((0, pad), (0, 0)))
        nb = (n + pad) // row_chunk
        w_new, loss = jax.lax.map(
            lambda args: solve_rows(*args),
            (
                wp_.reshape(nb, row_chunk, m),
                ip_.reshape(nb, row_chunk, -1),
                vp_.reshape(nb, row_chunk, -1),
            ),
        )
        w_new = w_new.reshape(-1, m)[:n]
        loss = loss.reshape(-1)[:n]

    # Enforce exact zeros at pruned slots (δw analytically cancels w there;
    # this removes residual float error).
    mask = jnp.zeros((n, m), bool).at[
        jnp.arange(n)[:, None], idx
    ].max(valid)
    w_new = jnp.where(mask, 0.0, w_new)
    return w_new.astype(w.dtype), loss


def mrp_compensate_mask(
    w: jax.Array,
    hinv: jax.Array,
    mask: jax.Array,
    k_max: Optional[int] = None,
    row_chunk: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Convenience wrapper: boolean mask (True = pruned) → Eq. (13).

    ``k_max`` defaults to the concrete per-row max (host sync + bucketing).
    """
    if k_max is None:
        k_max = masks_lib.bucket_k(masks_lib.max_row_count(mask))
    k_max = min(int(k_max), mask.shape[1])
    idx, valid = masks_lib.padded_row_indices(mask, k_max)
    return mrp_compensate(w, hinv, idx, valid, row_chunk=row_chunk)


# ----------------------------------------------------------------------
# Eq. (12) losses for N:M combination enumeration (Solution 𝔐 for masks)
# ----------------------------------------------------------------------
def nm_combinations(n_prune: int, m_group: int) -> jnp.ndarray:
    """All C(M,N) index combinations, shape (n_combos, N), int32."""
    combos = list(itertools.combinations(range(m_group), n_prune))
    return jnp.asarray(combos, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_prune", "m_group"))
def nm_group_losses(
    w: jax.Array, hinv: jax.Array, n_prune: int, m_group: int
) -> jax.Array:
    """Eq. (12) loss of every pruning combination in every M-group.

    Interactions *within* a group are exact (the (EᵀHinvE)⁻¹ term);
    groups are treated independently (paper Sec. 4.2.1: 6^G joint search
    is unaffordable, so the paper also scopes 𝔐 to within-group).

    Returns losses of shape (n, G, n_combos).
    """
    n, m = w.shape
    if m % m_group:
        raise ValueError(f"cols {m} not divisible by M={m_group}")
    g = m // m_group
    combos = nm_combinations(n_prune, m_group)             # (C, N)
    ncombo = combos.shape[0]

    w32 = w.astype(jnp.float32).reshape(n, g, m_group)
    # Per-group Hinv sub-blocks: columns of group j are j*M + [0..M).
    base = (jnp.arange(g, dtype=jnp.int32) * m_group)[:, None]          # (G,1)
    gcols = base + jnp.arange(m_group, dtype=jnp.int32)[None, :]        # (G,M)
    hg = hinv[gcols[:, :, None], gcols[:, None, :]].astype(jnp.float32)  # (G,M,M)

    # A_c = hg[combo, combo] for each combo: (G, C, N, N)
    a = hg[:, combos[:, :, None], combos[:, None, :]]                  # (G,C,N,N)
    # w_c: (n, G, C, N)
    wc = w32[:, :, combos]                                             # (n,G,C,N)
    # Solve A_c z = w_c batched; N is tiny (e.g. 2) so this is cheap.
    a_b = jnp.broadcast_to(a[None], (n, g, ncombo, n_prune, n_prune))
    z = jnp.linalg.solve(a_b, wc[..., None])[..., 0]
    loss = 0.5 * jnp.sum(z * wc, axis=-1)                              # (n,G,C)
    return loss


@functools.partial(jax.jit, static_argnames=("n_prune", "m_group"))
def select_nm_mask_mrp(
    w: jax.Array, hinv: jax.Array, n_prune: int, m_group: int
) -> jax.Array:
    """Solution 𝔐 mask: per group, pick the combination minimizing Eq. (12)."""
    n, m = w.shape
    losses = nm_group_losses(w, hinv, n_prune, m_group)   # (n,G,C)
    best = jnp.argmin(losses, axis=-1)                    # (n,G)
    combos = nm_combinations(n_prune, m_group)            # (C,N)
    chosen = combos[best]                                 # (n,G,N)
    onehot = jax.nn.one_hot(chosen, m_group, dtype=jnp.float32).sum(-2) > 0
    return onehot.reshape(n, m)


# ----------------------------------------------------------------------
# Reference-style direct per-row solution (oracle for tests; no padding)
# ----------------------------------------------------------------------
def mrp_row_reference(w_row, hinv, pruned_cols):
    """Literal Eq. (13)/(12) for ONE row — used as a test oracle.

    NumPy-style (no jit); pruned_cols: 1D int array.
    """
    import numpy as np

    w_row = np.asarray(w_row, np.float64)
    hinv = np.asarray(hinv, np.float64)
    p = np.asarray(pruned_cols, np.int64)
    if p.size == 0:
        return w_row.copy(), 0.0
    wp = w_row[p]                                   # (k,)
    a = hinv[np.ix_(p, p)]                          # (k,k)
    z = np.linalg.solve(a, wp)
    delta = -(z @ hinv[p, :])                       # (m,)
    loss = 0.5 * float(wp @ z)
    out = w_row + delta
    out[p] = 0.0
    return out, loss
