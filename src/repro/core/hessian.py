"""Calibration Hessian accumulation: H = 2 x xᵀ (+ γ I).

For the layer-wise quadratic loss L'(w) = ‖w x‖² the Hessian w.r.t. any
weight row is H = 2 x xᵀ (paper Sec. 2.3.1). We accumulate it streaming
over calibration batches so the full activation matrix never has to be
materialized (SparseGPT does the same).

Numerical conventions (shared by SparseGPT's public code and this paper):
  - accumulate in float32 regardless of activation dtype;
  - normalize by the running number of columns (tokens) so magnitudes stay
    bounded — scaling H by a constant does not change the solutions of
    Eq. (11)–(14) beyond the dampening trade-off, but keeps γ comparable
    across layers;
  - dampening (Remark 4.1): γ · mean(diag H) added to the diagonal.

Distributed: each data-parallel shard accumulates its local H and the
results are summed with `jax.lax.psum` (see core.distributed) — the sums
commute with the normalization here because we track token counts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def token_outer_product(x: jax.Array) -> jax.Array:
    """2 · x xᵀ for x of shape (m, B) — float32, the paper's Hessian term."""
    x32 = x.astype(jnp.float32)
    return 2.0 * (x32 @ x32.T)


@jax.jit
def _accum_update(h: jax.Array, count: jax.Array, x: jax.Array):
    """Numerically stable streaming mean of 2xxᵀ over tokens.

    Keeps H as the *mean* over tokens seen so far: H_n = H_{n-1} * (n_prev/n)
    + 2 x xᵀ / n. Equivalent to dividing the total sum by total tokens.
    """
    x32 = x.astype(jnp.float32)
    b = x32.shape[1]
    new_count = count + b
    scale_old = count / new_count
    h = h * scale_old + (2.0 / new_count) * (x32 @ x32.T)
    return h, new_count


@jax.jit
def _accum_update_weighted(h: jax.Array, count: jax.Array, x: jax.Array,
                           wts: jax.Array):
    """Weighted streaming mean: H = Σ_t w_t · 2 x_t x_tᵀ / Σ_t w_t.

    Used for MoE expert linears where each expert only sees its routed
    tokens (weights are routing validity 0/1 or gate probabilities).
    """
    x32 = x.astype(jnp.float32)
    w32 = wts.astype(jnp.float32)
    b = jnp.sum(w32)
    new_count = count + b
    denom = jnp.maximum(new_count, 1e-12)
    scale_old = count / denom
    xw = x32 * w32[None, :]
    h = h * scale_old + (2.0 / denom) * (xw @ x32.T)
    return h, new_count


@jax.jit
def _merge_many(hs: jax.Array, cs: jax.Array):
    """Weighted mean of stacked (S, m, m) Hessians by (S,) token counts."""
    total = jnp.sum(cs)
    h = jnp.einsum("s,sij->ij", cs, hs) / jnp.maximum(total, 1.0)
    return jnp.where(total > 0, h, hs[0]), total


@dataclasses.dataclass
class HessianAccumulator:
    """Streaming accumulator for the layer Hessian H = mean_t 2 x_t x_tᵀ.

    Usage:
        acc = HessianAccumulator(m)
        for batch in calib_batches:       # batch: (m, B) layer inputs
            acc.update(batch)
        h = acc.finalize()                # (m, m) float32
    """

    dim: int
    h: Optional[jax.Array] = None
    count: Optional[jax.Array] = None

    def __post_init__(self):
        if self.h is None:
            self.h = jnp.zeros((self.dim, self.dim), jnp.float32)
        if self.count is None:
            self.count = jnp.zeros((), jnp.float32)

    def update(self, x: jax.Array) -> None:
        """x: (m, B) — columns are calibration tokens for this layer."""
        if x.ndim != 2 or x.shape[0] != self.dim:
            raise ValueError(f"expected ({self.dim}, B) activations, got {x.shape}")
        self.h, self.count = _accum_update(self.h, self.count, x)

    def update_tokens(self, tokens_first: jax.Array) -> None:
        """Convenience for (num_tokens, m) layouts (batch*seq flattened)."""
        self.update(tokens_first.T)

    def update_weighted(self, x: jax.Array, weights: jax.Array) -> None:
        """Weighted update. x: (m, B); weights: (B,) non-negative.

        Equivalent to ``update`` restricted to the tokens with weight 1 —
        used for MoE expert layers (routing validity masks / gate probs).
        """
        if x.ndim != 2 or x.shape[0] != self.dim:
            raise ValueError(f"expected ({self.dim}, B) activations, got {x.shape}")
        if weights.shape != (x.shape[1],):
            raise ValueError(
                f"weights {weights.shape} incompatible with x {x.shape}")
        self.h, self.count = _accum_update_weighted(
            self.h, self.count, x, weights)

    def merge(self, other: "HessianAccumulator") -> "HessianAccumulator":
        """Merge two accumulators (e.g. from different data shards)."""
        total = self.count + other.count
        h = jnp.where(
            total > 0,
            (self.h * self.count + other.h * other.count) / jnp.maximum(total, 1.0),
            self.h,
        )
        return HessianAccumulator(self.dim, h=h, count=total)

    @staticmethod
    def merge_many(accs: "list[HessianAccumulator]") -> "HessianAccumulator":
        """Token-weighted mean of N accumulators in one fused device op.

        Equivalent to folding :meth:`merge` pairwise, but a single
        einsum over the stacked Hessians — no host round-trips, one
        dispatch regardless of shard count (the calibration-sharding
        merge path, core.pipeline).
        """
        if len(accs) == 1:
            return accs[0]
        dim = accs[0].dim
        if any(a.dim != dim for a in accs):
            raise ValueError(
                f"cannot merge accumulators of dims {[a.dim for a in accs]}")
        hs, cs = _merge_many(jnp.stack([a.h for a in accs]),
                             jnp.stack([a.count for a in accs]))
        return HessianAccumulator(dim, h=hs, count=cs)

    def finalize(self) -> jax.Array:
        return self.h


def dampened_inverse(h: jax.Array, gamma: float = 0.01) -> jax.Array:
    """(H + γ·mean(diag H)·I)⁻¹ via Cholesky (Remark 4.1).

    γ is relative to the mean diagonal (SparseGPT's `percdamp` convention)
    so the same γ works across layers of very different activation scale.
    Falls back to increasing dampening if the factorization produces
    non-finite values (rank-deficient calibration sets).
    """
    m = h.shape[0]
    damp = gamma * jnp.mean(jnp.diag(h))
    # Dead input channels (all-zero activations) make H singular even after
    # relative dampening if mean diag is 0; add tiny absolute floor.
    damp = jnp.maximum(damp, 1e-8)
    hd = h + damp * jnp.eye(m, dtype=h.dtype)
    # chol-solve against I == inverse; cho_factor keeps it O(m^3/3).
    chol = jax.scipy.linalg.cho_factor(hd, lower=True)
    inv = jax.scipy.linalg.cho_solve(chol, jnp.eye(m, dtype=h.dtype))
    return inv


def dampened_inverse_np(h: np.ndarray, gamma: float = 0.01) -> np.ndarray:
    """NumPy twin of :func:`dampened_inverse` for host-side tooling."""
    m = h.shape[0]
    damp = max(gamma * float(np.mean(np.diag(h))), 1e-8)
    hd = h + damp * np.eye(m, dtype=h.dtype)
    return np.linalg.inv(hd)
