"""Core library: the paper's contribution — MRP post-training pruning.

Public API:
  - SparsitySpec.parse("0.5") / .parse("2:4")
  - prune_matrix(w, hessian, spec, method="SM", blocksize=128)
  - PruningEngine: whole-model layer-wise pruning (see core.engine)
"""

from repro.core.sparsity import SparsitySpec
from repro.core.hessian import HessianAccumulator, dampened_inverse
from repro.core.pruner import prune_matrix, PruneResult, METHODS
from repro.core.engine import PruningEngine, LinearSpec
from repro.core.pipeline import PipelineStats, SegmentScheduler, run_pipelined

__all__ = [
    "SparsitySpec",
    "HessianAccumulator",
    "dampened_inverse",
    "prune_matrix",
    "PruneResult",
    "METHODS",
    "PruningEngine",
    "LinearSpec",
    "PipelineStats",
    "SegmentScheduler",
    "run_pipelined",
]
