"""Algorithm 1 — accurate post-training pruning (paper Sec. 4.2/4.3).

Method names follow the paper: first letter = mask solution, second =
compensation solution.

  SS  SparseGPT (baseline; sequential freezing)
  SM  𝔖 mask (Eq. 14 scores) + 𝔐 compensation (Eq. 13)   ← paper's pick
  MS  𝔐 mask (Eq. 12 combos) + 𝔖 compensation             [N:M only]
  MM  𝔐 mask + 𝔐 compensation                             [N:M only]
  magnitude / wanda  score-only baselines (no compensation)

Block loop (unstructured & N:M): the accumulated mask grows block by
block, and 𝔐 compensation re-solves Eq. (13) against the FULL accumulated
mask each block — previously pruned weights stay exactly zero while every
unpruned weight (in ALL blocks, left included) keeps being refined. That
is precisely the paper's fix for SparseGPT's frozen-left-columns drawback.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import masks as masks_lib
from repro.core import mrp, scores, sparsegpt
from repro.core.hessian import dampened_inverse
from repro.core.sparsity import SparsitySpec

METHODS = ("magnitude", "wanda", "SS", "SM", "MS", "MM")


@dataclasses.dataclass
class PruneResult:
    w: jax.Array          # pruned + compensated weights
    mask: jax.Array       # True = pruned
    loss: float           # Σ Eq.(12) losses (or method analogue)
    method: str
    spec: SparsitySpec
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def sparsity(self) -> float:
        return masks_lib.sparsity_of(self.mask)


def reconstruction_error(w0: jax.Array, w1: jax.Array, h: jax.Array) -> float:
    """‖(w1−w0) x‖² evaluated through H: tr(δw H δwᵀ)/2-free form.

    Since H = mean_t 2 x xᵀ,  ‖δw x‖²/T = ½ tr(δw H δwᵀ).
    This is the paper's objective — used everywhere as the quality metric.
    """
    err = reconstruction_error_traced(w0, w1, h)
    return float(err)


def reconstruction_error_traced(
    w0: jax.Array, w1: jax.Array, h: jax.Array
) -> jax.Array:
    """Traceable twin of :func:`reconstruction_error` (no host sync)."""
    dw = (w1 - w0).astype(jnp.float32)
    return 0.5 * jnp.einsum("ij,jk,ik->", dw, h.astype(jnp.float32), dw)


def _maybe_float(x):
    """float() outside jit; pass tracers through (keeps prune_matrix
    usable both as a host API and inside jit/shard_map)."""
    return x if isinstance(x, jax.core.Tracer) else float(x)


# ----------------------------------------------------------------------
def _score_mask_block(
    wblk: jax.Array,
    h: jax.Array,
    hinv: jax.Array,
    spec: SparsitySpec,
    score_name: str,
    col0: int,
    row_balanced: bool = False,
) -> jax.Array:
    """Solution 𝔖 mask for one column block (Eq. 14 / baselines)."""
    s = wblk.shape[1]
    hs = jax.lax.dynamic_slice(h, (col0, col0), (s, s))
    hinvs = jax.lax.dynamic_slice(hinv, (col0, col0), (s, s))
    sc = scores.compute_score(score_name, wblk, hs, hinvs)
    if spec.is_semi_structured:
        return masks_lib.nm_mask_from_scores(sc, spec.n, spec.m)
    if row_balanced:
        return masks_lib.unstructured_mask_rowwise(
            sc, spec.pruned_per_row_block(s))
    nppb = int(round(wblk.shape[0] * s * spec.rate))
    return masks_lib.unstructured_mask_from_scores(sc, nppb)


def prune_matrix(
    w: jax.Array,
    h: jax.Array,
    spec: SparsitySpec,
    method: str = "SM",
    blocksize: int = 128,
    gamma: float = 0.01,
    score: Optional[str] = None,
    row_chunk: Optional[int] = None,
    row_balanced: bool = False,
) -> PruneResult:
    """Prune one linear layer's weight matrix. w: (n, m); h: (m, m).

    This is the host-driven per-layer pass (the paper runs it layer by
    layer on one GPU; we run it row-sharded on TPU — see core.distributed).

    ``row_balanced=True`` selects an exact per-row pruned count instead of
    the per-block global count.  With it (or with N:M specs) the whole pass
    is traceable — static shapes, no host sync — so it can be jitted and
    shard_map'd (see core.distributed.prune_matrix_sharded).
    """
    if isinstance(spec, str):
        spec = SparsitySpec.parse(spec)
    if method not in METHODS:
        raise ValueError(f"method {method!r} not in {METHODS}")
    if method in ("MS", "MM") and not spec.is_semi_structured:
        raise ValueError(
            f"Solution 𝔐 mask is combinatorial — N:M only (paper Sec. 4.2.1); "
            f"got method={method} with unstructured {spec}"
        )
    n, m = w.shape
    blocksize = min(blocksize, m)
    if m % blocksize:
        raise ValueError(f"m={m} must be divisible by blocksize={blocksize}")
    spec.validate_block(blocksize)
    w0 = w

    # --- score-only baselines -----------------------------------------
    if method in ("magnitude", "wanda"):
        hinv = dampened_inverse(h, gamma)  # unused by magnitude; cheap enough
        sc = scores.compute_score(method, w, h, hinv)
        if spec.is_semi_structured:
            mask = masks_lib.nm_mask_from_scores(sc, spec.n, spec.m)
        elif row_balanced:
            mask = masks_lib.unstructured_mask_rowwise(
                sc, int(round(m * spec.rate)))
        else:
            mask = masks_lib.unstructured_mask_from_scores(
                sc, int(round(n * m * spec.rate))
            )
        w_new = jnp.where(mask, 0.0, w)
        return PruneResult(
            w_new, mask, _maybe_float(reconstruction_error_traced(w0, w_new, h)), method, spec
        )

    # --- SparseGPT (𝔖𝔖) ------------------------------------------------
    if method == "SS":
        w_new, mask, _ = sparsegpt.sparsegpt_prune(w, h, spec, blocksize, gamma)
        return PruneResult(
            w_new, mask, _maybe_float(reconstruction_error_traced(w0, w_new, h)), method, spec
        )

    hinv = dampened_inverse(h, gamma)

    # --- 𝔐𝔖: combo mask + SparseGPT compensation (N:M only) ------------
    if method == "MS":
        mask = mrp.select_nm_mask_mrp(w, hinv, spec.n, spec.m)
        w_new, _, _ = sparsegpt.sparsegpt_prune(
            w, h, spec, blocksize, gamma, mask_override=mask
        )
        return PruneResult(
            w_new, mask, _maybe_float(reconstruction_error_traced(w0, w_new, h)), method, spec
        )

    # --- 𝔖𝔐 / 𝔐𝔐: Algorithm 1 block loop with MRP compensation ---------
    score_name = score or "obs"
    nblocks = m // blocksize
    # static per-row bound when selection is row-balanced (incl. all N:M)
    static_rows = spec.is_semi_structured or row_balanced
    per_blk = spec.pruned_per_row_block(blocksize) if static_rows else None
    mask_acc = jnp.zeros((n, m), bool)
    w_cur = w
    # Per-block Eq. (12) losses.  Each block's solve is against the FULL
    # accumulated mask, so entry b supersedes entry b-1 (it re-solves the
    # earlier blocks' weights too) — the honest scalar summary is the
    # FINAL solve's loss, not a sum or a silently-overwritten "total".
    block_losses = []
    for b in range(nblocks):
        c0 = b * blocksize
        wblk = jax.lax.dynamic_slice(w_cur, (0, c0), (n, blocksize))
        if method == "SM":
            mblk = _score_mask_block(
                wblk, h, hinv, spec, score_name, c0, row_balanced)
        else:  # MM
            hinv_blk = jax.lax.dynamic_slice(
                hinv, (c0, c0), (blocksize, blocksize)
            )
            mblk = mrp.select_nm_mask_mrp(wblk, hinv_blk, spec.n, spec.m)
        mask_acc = jax.lax.dynamic_update_slice(mask_acc, mblk, (0, c0))
        # MRP compensation against the FULL accumulated mask (Algorithm 1).
        k_max = (b + 1) * per_blk if static_rows else None
        w_cur, loss_rows = mrp.mrp_compensate_mask(
            w_cur, hinv, mask_acc, k_max=k_max, row_chunk=row_chunk
        )
        block_losses.append(jnp.sum(loss_rows))
    return PruneResult(
        w_cur,
        mask_acc,
        _maybe_float(reconstruction_error_traced(w0, w_cur, h)),
        method,
        spec,
        stats={
            "final_mrp_loss": _maybe_float(block_losses[-1]),
            "block_mrp_losses": tuple(
                _maybe_float(bl) for bl in block_losses),
        },
    )
