"""The model zoo's LM assembly: any ArchConfig → trainable/servable model.

One class (:class:`LM`) covers all 10 assigned architectures:
decoder-only transformers (dense / MoE / local-global interleave),
Mamba & xLSTM SSM stacks, the Jamba hybrid, the PaliGemma prefix-LM VLM,
and the SeamlessM4T encoder-decoder — by composing block *kinds* from
layers.py / ssm.py / moe.py per the config's prefix/period layout.

HLO discipline: the repeated period is a ``lax.scan`` over stacked layer
params, so the lowered module is O(one period) regardless of depth (61-
layer Kimi lowers as fast as 18-layer Gemma).  Capture mode (the pruning
engine) runs the same blocks *unrolled* — tiny CPU models only.

Entry points:
  init(key) / init_shapes()             params (concrete / ShapeDtypeStruct)
  forward(params, batch)                logits, aux-loss
  loss_fn(params, batch)                scalar loss + metrics (train_step)
  init_cache(batch, max_len)            decode cache pytree
  prefill(params, batch, cache)         prompt → logits, filled cache
  decode_step(params, token, cache, pos)   one-token serve_step
  init_paged_cache / prefill_paged /    paged twin of the decode path
    prefill_chunk / decode_step(...,      (continuous batching, serve/ —
    paged=...)                            KV pages + slot-pooled state)
  prunable_segments() / first_hidden()  core.engine contract
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import LinearSpec, SegmentSpec
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.base import ArchConfig
from repro.models.layers import (
    Params,
    attn_apply,
    attn_cache_init,
    attn_init,
    attn_paged_cache_init,
    embed_apply,
    embed_init,
    frontend_apply,
    linear,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed_apply,
    unembed_init,
)
from repro.utils.trees import tree_slice_layer

MIXER_KINDS = ("attn", "attn_local", "enc_attn", "dec_attn",
               "mamba", "mlstm", "slstm")


# ======================================================================
# Block init / apply dispatch
# ======================================================================
def _block_init(key, cfg: ArchConfig, kind: str, is_moe: bool, dtype) -> Params:
    k_mix, k_ffn, k_x = jax.random.split(key, 3)
    p: Params = {}
    if kind in ("attn", "attn_local", "enc_attn"):
        p["attn"] = attn_init(k_mix, cfg, dtype)
    elif kind == "dec_attn":
        p["attn"] = attn_init(k_mix, cfg, dtype)
        p["xattn"] = attn_init(k_x, cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = ssm_lib.mamba_init(k_mix, cfg, dtype)
    elif kind == "mlstm":
        p["mlstm"] = ssm_lib.mlstm_init(k_mix, cfg, dtype)
    elif kind == "slstm":
        p["slstm"] = ssm_lib.slstm_init(k_mix, cfg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cfg.block_has_mlp(kind):
        if is_moe:
            p["moe"] = moe_lib.moe_init(k_ffn, cfg, dtype)
        else:
            p["mlp"] = mlp_init(k_ffn, cfg, dtype)
    return p


def block_apply(
    cfg: ArchConfig,
    kind: str,
    p: Params,
    h: jax.Array,
    *,
    is_moe: bool = False,
    caps=None,
    cache: Optional[Params] = None,
    pos=None,
    enc_out: Optional[jax.Array] = None,
    prefix_len: Optional[int] = None,
    name_prefix: str = "",
    paged: Optional[Params] = None,
    page_size: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Apply one block (mixer + optional FFN). Returns (h, cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    np_ = name_prefix
    if paged is not None and kind in ("enc_attn", "dec_attn"):
        raise ValueError(f"paged decode supports decoder-only mixers, "
                         f"got {kind!r}")
    if kind in ("attn", "attn_local", "enc_attn"):
        h, cache = attn_apply(
            p["attn"], h, cfg, kind=kind, caps=caps, cache=cache, pos=pos,
            prefix=f"{np_}attn.", causal=(kind != "enc_attn"),
            prefix_len=prefix_len, paged=paged, page_size=page_size)
    elif kind == "dec_attn":
        h, cache = attn_apply(
            p["attn"], h, cfg, caps=caps, cache=cache, pos=pos,
            prefix=f"{np_}attn.")
        # cross attention over the encoder output
        if cache is not None and enc_out is None:
            xk, xv = cache["xk"], cache["xv"]
        else:
            b, s, _ = enc_out.shape
            kvh, hd = cfg.num_kv_heads, cfg.hd
            xk = linear(enc_out, p["xattn"]["wk"], caps=caps,
                        name=f"{np_}xattn.wk").reshape(b, s, kvh, hd)
            xv = linear(enc_out, p["xattn"]["wv"], caps=caps,
                        name=f"{np_}xattn.wv").reshape(b, s, kvh, hd)
            if cache is not None:          # prefill: store cross K/V
                cache = dict(cache)
                cache["xk"], cache["xv"] = (
                    xk.astype(cache["xk"].dtype), xv.astype(cache["xv"].dtype))
        h, cache = attn_apply(
            p["xattn"], h, cfg, caps=caps, cache=cache,
            cross_kv=(xk, xv), prefix=f"{np_}xattn.")
    elif kind == "mamba":
        h, cache = ssm_lib.mamba_apply(
            p["mamba"], h, cfg, caps=caps, cache=cache, pos=pos,
            prefix=f"{np_}mamba.", paged=paged)
    elif kind == "mlstm":
        h, cache = ssm_lib.mlstm_apply(
            p["mlstm"], h, cfg, caps=caps, cache=cache, pos=pos,
            prefix=f"{np_}mlstm.", paged=paged)
    elif kind == "slstm":
        h, cache = ssm_lib.slstm_apply(
            p["slstm"], h, cfg, caps=caps, cache=cache, pos=pos,
            prefix=f"{np_}slstm.", paged=paged)
    else:
        raise ValueError(f"unknown block kind {kind!r}")

    if "moe" in p:
        h, aux = moe_lib.moe_apply(p["moe"], h, cfg, caps=caps,
                                   prefix=f"{np_}moe.")
    elif "mlp" in p:
        h = mlp_apply(p["mlp"], h, cfg, caps=caps, prefix=f"{np_}mlp.")
    return h, cache, aux


def block_cache_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                     dtype, enc_len: int = 0) -> Params:
    if kind in ("attn", "attn_local"):
        return attn_cache_init(cfg, batch, max_len, dtype)
    if kind == "dec_attn":
        c = attn_cache_init(cfg, batch, max_len, dtype)
        c["xk"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.hd), dtype)
        c["xv"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.hd), dtype)
        return c
    if kind == "mamba":
        return ssm_lib.mamba_cache_init(cfg, batch, dtype)
    if kind == "mlstm":
        return ssm_lib.mlstm_cache_init(cfg, batch, dtype)
    if kind == "slstm":
        return ssm_lib.slstm_cache_init(cfg, batch, dtype)
    raise ValueError(f"no cache for kind {kind!r}")


_BLOCK_LINEARS: Dict[str, List[Tuple[str, str]]] = {
    # kind -> [(subtree, weight_key)] in capture-name order
    "attn": [("attn", "wq"), ("attn", "wk"), ("attn", "wv"), ("attn", "wo")],
    "mamba": [("mamba", "in_proj"), ("mamba", "x_proj"),
              ("mamba", "dt_proj"), ("mamba", "out_proj")],
    "mlstm": [("mlstm", "wq"), ("mlstm", "wk"), ("mlstm", "wv"),
              ("mlstm", "wo")],
    "slstm": [("slstm", "wz"), ("slstm", "wi"), ("slstm", "wf"),
              ("slstm", "wo_gate"), ("slstm", "wo")],
}
_BLOCK_LINEARS["attn_local"] = _BLOCK_LINEARS["attn"]
_BLOCK_LINEARS["enc_attn"] = _BLOCK_LINEARS["attn"]
_BLOCK_LINEARS["dec_attn"] = _BLOCK_LINEARS["attn"] + [
    ("xattn", "wq"), ("xattn", "wk"), ("xattn", "wv"), ("xattn", "wo")]
_MLP_LINEARS = {"swiglu": ["wi", "wg", "wo"], "geglu": ["wi", "wg", "wo"],
                "gelu": ["wi", "wo"], "none": []}


# ======================================================================
# The model
# ======================================================================
class LM:
    """Any assigned architecture, from one ArchConfig."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------- init
    def init(self, key) -> Params:
        cfg = self.cfg
        dt = self.dtype
        keys = jax.random.split(key, 8)
        params: Params = {
            "embed": embed_init(keys[0], cfg, dt),
            "unembed": unembed_init(keys[1], cfg, dt),
        }
        if cfg.prefix:
            params["prefix"] = {
                str(i): _block_init(
                    jax.random.fold_in(keys[2], i), cfg, kind,
                    cfg.slot_is_moe(i, True), dt)
                for i, kind in enumerate(cfg.prefix)
            }
        if cfg.n_periods:
            layers = {}
            for j, kind in enumerate(cfg.period):
                is_moe = cfg.slot_is_moe(j, False)
                kj = jax.random.fold_in(keys[3], j)
                stacked = jax.vmap(
                    lambda k: _block_init(k, cfg, kind, is_moe, dt)
                )(jax.random.split(kj, cfg.n_periods))
                layers[f"s{j}"] = stacked
            params["layers"] = layers
        if cfg.encdec:
            enc = {
                "layers": jax.vmap(
                    lambda k: _block_init(k, cfg, "enc_attn", False, dt)
                )(jax.random.split(keys[4], cfg.enc_layers)),
                "ln": rmsnorm_init(cfg.d_model, dt),
            }
            params["enc"] = enc
        return params

    def init_shapes(self) -> Params:
        """ShapeDtypeStruct param pytree — no allocation (dry-run)."""
        return jax.eval_shape(self.init, jax.random.key(0))

    # ------------------------------------------------------ embeddings
    def first_hidden(self, params: Params, batch: Dict[str, jax.Array]):
        """Embedding (+ modality frontend) output entering block 0."""
        cfg = self.cfg
        h = embed_apply(params["embed"], batch["tokens"], cfg)
        if cfg.frontend is not None and not cfg.encdec:
            feats = batch["frontend_feats"]               # (B, F, fd)
            fh = frontend_apply(params["embed"], feats, cfg)
            if cfg.embed_scale:
                fh = fh * jnp.asarray(math.sqrt(cfg.d_model), fh.dtype)
            h = jnp.concatenate([fh.astype(h.dtype), h], axis=1)
        return h

    def encode(self, params: Params, batch, caps=None) -> jax.Array:
        """Encoder stack over frontend features (enc-dec archs)."""
        cfg = self.cfg
        feats = batch["frontend_feats"]
        h = frontend_apply(params["embed"], feats, cfg).astype(self.dtype)
        if caps is None and cfg.scan_layers:
            def body(h, pl):
                h, _, _ = block_apply(cfg, "enc_attn", pl, h)
                return h, None
            body = self._maybe_remat(body)
            h, _ = jax.lax.scan(body, h, params["enc"]["layers"])
        elif caps is None:
            for li in range(cfg.enc_layers):
                pl_ = tree_slice_layer(params["enc"]["layers"], li)
                h, _, _ = block_apply(cfg, "enc_attn", pl_, h)
        else:
            for li in range(cfg.enc_layers):
                pl = tree_slice_layer(params["enc"]["layers"], li)
                h, _, _ = block_apply(cfg, "enc_attn", pl, h, caps=caps,
                                      name_prefix=f"enc{li}.")
        return rmsnorm(params["enc"]["ln"], h, cfg.norm_eps)

    # ---------------------------------------------------------- forward
    def _maybe_remat(self, fn):
        if self.cfg.remat == "full":
            return jax.checkpoint(fn, prevent_cse=False)
        return fn

    def _prefix_len(self, batch) -> Optional[int]:
        cfg = self.cfg
        if cfg.frontend is not None and not cfg.encdec:
            return cfg.frontend_len
        return None

    def forward(self, params: Params, batch: Dict[str, jax.Array],
                caps=None) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward. Returns (logits f32, aux loss)."""
        cfg = self.cfg
        enc_out = self.encode(params, batch, caps=caps) if cfg.encdec else None
        h = self.first_hidden(params, batch)
        pl = self._prefix_len(batch)
        aux = jnp.zeros((), jnp.float32)

        for i, kind in enumerate(cfg.prefix):
            h, _, a = block_apply(
                cfg, kind, params["prefix"][str(i)], h,
                is_moe=cfg.slot_is_moe(i, True), caps=caps, enc_out=enc_out,
                prefix_len=pl, name_prefix=f"p{i}." if caps is not None else "")
            aux += a

        if cfg.n_periods:
            if caps is None:
                def body(carry, xs):
                    h, aux = carry
                    for j, kind in enumerate(cfg.period):
                        h, _, a = block_apply(
                            cfg, kind, xs[f"s{j}"], h,
                            is_moe=cfg.slot_is_moe(j, False),
                            enc_out=enc_out, prefix_len=pl)
                        aux += a
                    return (h, aux), None
                body = self._maybe_remat(body)
                if cfg.scan_layers:
                    (h, aux), _ = jax.lax.scan(
                        body, (h, aux), params["layers"])
                else:          # unrolled (cost-analysis lowerings)
                    for pi in range(cfg.n_periods):
                        xs = {k: tree_slice_layer(v, pi)
                              for k, v in params["layers"].items()}
                        (h, aux), _ = body((h, aux), xs)
            else:
                for pi in range(cfg.n_periods):
                    for j, kind in enumerate(cfg.period):
                        pj = tree_slice_layer(params["layers"][f"s{j}"], pi)
                        h, _, a = block_apply(
                            cfg, kind, pj, h,
                            is_moe=cfg.slot_is_moe(j, False), caps=caps,
                            enc_out=enc_out, prefix_len=pl,
                            name_prefix=f"b{pi}.s{j}.")
                        aux += a

        logits = unembed_apply(params["unembed"], params["embed"], h, cfg)
        return logits.astype(jnp.float32), aux

    # ------------------------------------------------------------- loss
    def loss_fn(self, params: Params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Next-token CE (+ z-loss + MoE aux). Returns (loss, metrics)."""
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        targets = batch["labels"]                         # (B, T_text)
        # frontends prepend non-text positions: predict text only
        off = logits.shape[1] - targets.shape[1]
        lg = logits[:, off:, :]
        # shift: position t predicts target t+1
        lg = lg[:, :-1]
        tg = targets[:, 1:]
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
        nll = lse - gold
        weights = (tg >= 0).astype(jnp.float32)
        tg = jnp.maximum(tg, 0)
        denom = jnp.maximum(weights.sum(), 1.0)
        ce = (nll * weights).sum() / denom
        zloss = 1e-4 * ((lse**2) * weights).sum() / denom
        moe_coef = cfg.moe.router_aux_coef if cfg.moe else 0.0
        loss = ce + zloss + moe_coef * aux
        return loss, {"ce": ce, "zloss": zloss, "aux": aux,
                      "tokens": denom}

    # ------------------------------------------------------------ decode
    def init_cache(self, batch: int, max_len: int,
                   dtype=None) -> Params:
        cfg = self.cfg
        dt = dtype or self.dtype
        enc_len = cfg.frontend_len
        cache: Params = {}
        if cfg.prefix:
            cache["prefix"] = {
                str(i): block_cache_init(cfg, kind, batch, max_len, dt, enc_len)
                for i, kind in enumerate(cfg.prefix)
            }
        if cfg.n_periods:
            cache["layers"] = {
                f"s{j}": jax.vmap(
                    lambda _: block_cache_init(
                        cfg, kind, batch, max_len, dt, enc_len)
                )(jnp.arange(cfg.n_periods))
                for j, kind in enumerate(cfg.period)
            }
        return cache

    def init_cache_shapes(self, batch: int, max_len: int, dtype=None):
        return jax.eval_shape(
            functools.partial(self.init_cache, batch, max_len, dtype))

    # block kinds whose paged serve cache is slot-pooled recurrent state
    STATE_KINDS = ("mamba", "mlstm", "slstm")

    def init_paged_cache(self, num_pages: int, page_size: int,
                         dtype=None, max_slots: Optional[int] = None
                         ) -> Params:
        """Paged serve cache for the continuous-batching runtime: the
        same tree layout as :meth:`init_cache` but each attention leaf
        is a global (num_pages, page_size, KV, hd) page pool shared by
        all requests via per-request block tables (serve.kvpool owns
        the allocator; page 0 is the scrap page), and each recurrent
        mixer leaf is a slot-recycled fixed-state pool — the dense
        cache with batch = ``max_slots``, one row per serve slot
        (serve.kvpool.StatePool resets rows at admission)."""
        cfg = self.cfg
        dt = dtype or self.dtype
        # int8 quantization applies to attention pages only (they carry
        # per-row scale leaves, see layers.attn_paged_cache_init);
        # recurrent-state slot pools stay at the model dtype
        state_dt = self.dtype if jnp.dtype(dt) == jnp.int8 else dt
        kinds = (*cfg.prefix, *cfg.period)
        bad = [k for k in kinds
               if k not in ("attn", "attn_local", *self.STATE_KINDS)]
        if bad or cfg.encdec or cfg.frontend is not None:
            # frontends excluded too: the paged decode branch carries no
            # prefix_len, so a bidirectional image prefix would be
            # silently masked out of windowed layers
            raise ValueError(
                f"{cfg.name}: paged decode supports plain decoder archs "
                f"only (got {bad or ['encdec/frontend']})")
        if max_slots is None and any(k in self.STATE_KINDS for k in kinds):
            raise ValueError(
                f"{cfg.name}: recurrent-state mixers need max_slots for "
                f"the slot-pooled state (serve.kvpool.StatePool)")

        def block_paged_init(kind):
            if kind in ("attn", "attn_local"):
                return attn_paged_cache_init(cfg, num_pages, page_size, dt)
            return block_cache_init(cfg, kind, max_slots, 0, state_dt)

        cache: Params = {}
        if cfg.prefix:
            cache["prefix"] = {
                str(i): block_paged_init(kind)
                for i, kind in enumerate(cfg.prefix)
            }
        if cfg.n_periods:
            cache["layers"] = {
                f"s{j}": jax.vmap(
                    lambda _, kind=kind: block_paged_init(kind)
                )(jnp.arange(cfg.n_periods))
                for j, kind in enumerate(cfg.period)
            }
        return cache

    def _cache_dims(self) -> Dict[str, int]:
        """The divisibility-relevant dims the dist cache rules consume."""
        cfg = self.cfg
        di = cfg.mlstm_proj * cfg.d_model
        return {"num_kv_heads": cfg.num_kv_heads, "hd": cfg.hd,
                "d_inner": cfg.d_inner, "d_model": cfg.d_model,
                "num_heads": cfg.num_heads,
                "mlstm_hd": di // cfg.num_heads}

    def _assemble_cache_specs(self, block_specs) -> Dict[str, Any]:
        """Lay per-block spec dicts out in the model's prefix/period tree
        (stacked period layers get one leading unsharded scan dim)."""
        cfg = self.cfg
        specs: Dict[str, Any] = {}
        if cfg.prefix:
            specs["prefix"] = {
                str(i): block_specs(kind, 0)
                for i, kind in enumerate(cfg.prefix)}
        if cfg.n_periods:
            specs["layers"] = {
                f"s{j}": block_specs(kind, 1)
                for j, kind in enumerate(cfg.period)}
        return specs

    def cache_specs(self, mesh, dp_axes=("data",), tp_axis: str = "model",
                    seq_shard: bool = False, prefer_seq: bool = False):
        """PartitionSpec pytree for the decode cache.

        The per-kind layout rules (batch over the data (+pod) axes, the
        'width' dim — KV heads / head_dim / d_inner — over the model
        axis when divisible, ``seq_shard``/``prefer_seq`` sequence
        sharding) live in :func:`repro.dist.sharding
        .decode_cache_block_specs`; this method only assembles them per
        the model's block layout."""
        from repro.dist.sharding import decode_cache_block_specs

        dims = self._cache_dims()
        return self._assemble_cache_specs(
            lambda kind, lead: decode_cache_block_specs(
                kind, dims, mesh, extra_lead=lead, dp_axes=dp_axes,
                tp_axis=tp_axis, seq_shard=seq_shard,
                prefer_seq=prefer_seq))

    def paged_cache_specs(self, mesh, tp_axis: str = "model",
                          quantized: bool = False):
        """PartitionSpec pytree for the paged serve cache
        (:meth:`init_paged_cache`): attention pages replicated over the
        data axes, KV heads over the model axis when they divide it —
        deliberately NO head_dim fallback (it would break paged/dense
        decode bit-parity); recurrent-state slot pools replicate the
        slot dim and shard the width dim over ``model`` only when the
        split is head-aligned.  The rules live in
        :func:`repro.dist.sharding.paged_kv_block_specs` /
        :func:`repro.dist.sharding.paged_state_block_specs`."""
        from repro.dist.sharding import (paged_kv_block_specs,
                                         paged_state_block_specs)

        dims = self._cache_dims()

        def block_specs(kind, lead):
            if kind in self.STATE_KINDS:
                return paged_state_block_specs(
                    kind, dims, mesh, extra_lead=lead, tp_axis=tp_axis)
            return paged_kv_block_specs(
                dims, mesh, extra_lead=lead, tp_axis=tp_axis,
                quantized=quantized)

        return self._assemble_cache_specs(block_specs)

    def prefill(self, params: Params, batch, cache: Params
                ) -> Tuple[jax.Array, Params]:
        """Run the prompt through the model, filling ``cache``.

        Returns (last-position logits (B, V) f32, filled cache).
        """
        cfg = self.cfg
        enc_out = self.encode(params, batch) if cfg.encdec else None
        h = self.first_hidden(params, batch)
        pl = self._prefix_len(batch)
        cache = dict(cache)

        if cfg.prefix:
            newp = {}
            for i, kind in enumerate(cfg.prefix):
                h, c, _ = block_apply(
                    cfg, kind, params["prefix"][str(i)], h,
                    cache=cache["prefix"][str(i)], enc_out=enc_out,
                    prefix_len=pl)
                newp[str(i)] = c
            cache["prefix"] = newp

        if cfg.n_periods:
            def body(h, xs):
                pj, cj = xs
                new_c = {}
                for j, kind in enumerate(cfg.period):
                    h, c, _ = block_apply(
                        cfg, kind, pj[f"s{j}"], h, cache=cj[f"s{j}"],
                        enc_out=enc_out, prefix_len=pl)
                    new_c[f"s{j}"] = c
                return h, new_c
            h, new_layers = self._scan_or_unroll(
                body, h, params["layers"], cache["layers"])
            cache["layers"] = new_layers

        logits = unembed_apply(params["unembed"], params["embed"],
                               h[:, -1:, :], cfg)
        return logits[:, 0, :].astype(jnp.float32), cache

    def _scan_or_unroll(self, body, h, layers, caches):
        """scan over periods, or an unrolled Python loop when
        cfg.scan_layers=False (cost-analysis lowerings)."""
        from repro.utils.trees import tree_stack
        if self.cfg.scan_layers:
            return jax.lax.scan(body, h, (layers, caches))
        outs = []
        for pi in range(self.cfg.n_periods):
            xs = (jax.tree.map(lambda x: x[pi], layers),
                  jax.tree.map(lambda x: x[pi], caches))
            h, new_c = body(h, xs)
            outs.append(new_c)
        return h, tree_stack(outs)

    def prefill_paged(self, params: Params, batch, cache: Params, *,
                      lengths, block_tables, page_size: int
                      ) -> Tuple[jax.Array, Params]:
        """Prompt prefill into paged KV pages (continuous batching).

        batch["tokens"]: (B, T_pad) prompts right-padded to a page
        multiple; lengths: (B,) actual prompt lengths (padded positions
        write to the scrap page, so pages only back real tokens);
        block_tables: (B, P_max) physical page ids.  Returns (per-request
        logits at position lengths-1, (B, V) f32, updated pool)."""
        cfg = self.cfg
        assert not cfg.encdec and cfg.frontend is None, \
            "paged prefill: plain decoder-only archs"
        h = self.first_hidden(params, batch)
        paged = {"block_tables": block_tables, "lengths": lengths}
        cache = dict(cache)

        if cfg.prefix:
            newp = {}
            for i, kind in enumerate(cfg.prefix):
                h, c, _ = block_apply(
                    cfg, kind, params["prefix"][str(i)], h,
                    cache=cache["prefix"][str(i)], paged=paged,
                    page_size=page_size)
                newp[str(i)] = c
            cache["prefix"] = newp

        if cfg.n_periods:
            def body(h, xs):
                pj, cj = xs
                new_c = {}
                for j, kind in enumerate(cfg.period):
                    h, c, _ = block_apply(
                        cfg, kind, pj[f"s{j}"], h, cache=cj[f"s{j}"],
                        paged=paged, page_size=page_size)
                    new_c[f"s{j}"] = c
                return h, new_c
            h, new_layers = self._scan_or_unroll(
                body, h, params["layers"], cache["layers"])
            cache["layers"] = new_layers

        idx = jnp.maximum(lengths - 1, 0)
        h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)
        logits = unembed_apply(params["unembed"], params["embed"],
                               h_last, cfg)
        return logits[:, 0, :].astype(jnp.float32), cache

    def prefill_chunk(self, params: Params, batch, cache: Params,
                      start, length, slot, block_tables, *,
                      page_size: int) -> Tuple[jax.Array, Params]:
        """One fixed-size chunk of ONE request's prompt (continuous
        batching — the chunked paged prefill, docs/serving.md).

        batch["tokens"]: (1, C) — tokens ``start .. start+C`` of the
        request's prompt, zero-padded past ``length``; ``start`` /
        ``length`` / ``slot``: scalar int32 (chunk offset, full prompt
        length, the request's serve slot); block_tables: (1, P_max).
        Attention layers scatter the chunk's K/V into the pages and
        attend over the gathered slot context; recurrent mixers carry
        slot ``slot``'s pooled state forward.  Every chunk of every
        prompt shares this one jitted shape.  Returns (logits at
        position ``min(length, start+C) - 1`` — the sampling logits
        when this is the final chunk, garbage otherwise — (1, V) f32,
        updated cache)."""
        cfg = self.cfg
        assert not cfg.encdec and cfg.frontend is None, \
            "chunked prefill: plain decoder archs"
        h = self.first_hidden(params, batch)
        t = h.shape[1]
        paged = {"block_tables": block_tables,
                 "lengths": jnp.reshape(length, (1,)),
                 "start": start, "slot": slot}
        cache = dict(cache)

        if cfg.prefix:
            newp = {}
            for i, kind in enumerate(cfg.prefix):
                h, c, _ = block_apply(
                    cfg, kind, params["prefix"][str(i)], h,
                    cache=cache["prefix"][str(i)], paged=paged,
                    page_size=page_size)
                newp[str(i)] = c
            cache["prefix"] = newp

        if cfg.n_periods:
            def body(h, xs):
                pj, cj = xs
                new_c = {}
                for j, kind in enumerate(cfg.period):
                    h, c, _ = block_apply(
                        cfg, kind, pj[f"s{j}"], h, cache=cj[f"s{j}"],
                        paged=paged, page_size=page_size)
                    new_c[f"s{j}"] = c
                return h, new_c
            h, new_layers = self._scan_or_unroll(
                body, h, params["layers"], cache["layers"])
            cache["layers"] = new_layers

        idx = jnp.clip(length - 1 - start, 0, t - 1)
        h_last = jax.lax.dynamic_slice_in_dim(h, idx, 1, axis=1)
        logits = unembed_apply(params["unembed"], params["embed"],
                               h_last, cfg)
        return logits[:, 0, :].astype(jnp.float32), cache

    def decode_step(self, params: Params, token: jax.Array, cache: Params,
                    pos, paged: Optional[Params] = None,
                    page_size: Optional[int] = None
                    ) -> Tuple[jax.Array, Params]:
        """One-token decode. token: (B,) int32; pos: scalar int32 (the
        absolute position being written). Returns (logits (B,V), cache).

        Paged mode (``paged={"block_tables": (B, P_max)}`` + static
        ``page_size``): ``cache`` is the paged serve cache from
        :meth:`init_paged_cache` and ``pos`` is a per-request (B,) vector
        of write positions, -1 marking idle slots.  Attention layers go
        through the block tables; recurrent mixers advance their slot
        row exactly as in dense decode (slot index == batch row — the
        pooled state IS the dense cache with batch = max_slots).

        Loop-carry contract (serve.fused relies on it): the returned
        cache has the SAME pytree structure, shapes and dtypes as the
        input — decode_step composes under ``lax.while_loop``/
        ``fori_loop`` as a carried step, which is how the serve engine
        runs K fused decode steps per host sync; ``pos`` is a traced
        value in both modes (never concretized)."""
        cfg = self.cfg
        h = embed_apply(params["embed"], token[:, None], cfg)
        pl = self._prefix_len(None)
        cache = dict(cache)

        if cfg.prefix:
            newp = {}
            for i, kind in enumerate(cfg.prefix):
                h, c, _ = block_apply(
                    cfg, kind, params["prefix"][str(i)], h,
                    cache=cache["prefix"][str(i)], pos=pos, prefix_len=pl,
                    paged=paged, page_size=page_size)
                newp[str(i)] = c
            cache["prefix"] = newp

        if cfg.n_periods:
            def body(h, xs):
                pj, cj = xs
                new_c = {}
                for j, kind in enumerate(cfg.period):
                    h, c, _ = block_apply(
                        cfg, kind, pj[f"s{j}"], h, cache=cj[f"s{j}"],
                        pos=pos, prefix_len=pl,
                        paged=paged, page_size=page_size)
                    new_c[f"s{j}"] = c
                return h, new_c
            h, new_layers = self._scan_or_unroll(
                body, h, params["layers"], cache["layers"])
            cache["layers"] = new_layers

        logits = unembed_apply(params["unembed"], params["embed"], h, cfg)
        return logits[:, 0, :].astype(jnp.float32), cache

    # ------------------------------------------------- pruning contract
    def _segment_linears(self, kinds) -> List[LinearSpec]:
        """LinearSpec list for one segment. Weights are stored (in, out);
        the paper works in (out, in) — get/set transpose."""
        cfg = self.cfg
        specs: List[LinearSpec] = []

        def mk(path: Tuple[str, ...], name: str):
            def get(sp, path=path):
                w = sp
                for k in path:
                    w = w[k]
                return w.T

            def set_(sp, w, path=path):
                sp = dict(sp)
                node = sp
                for k in path[:-1]:
                    node[k] = dict(node[k])
                    node = node[k]
                node[path[-1]] = w.T.astype(self.dtype)
                return sp
            return LinearSpec(name=name, get=get, set=set_)

        for slot_key, kind, is_moe in kinds:
            base = (slot_key,) if slot_key else ()
            npfx = f"{slot_key}." if slot_key else ""
            for sub, wkey in _BLOCK_LINEARS[kind]:
                specs.append(mk(base + (sub, wkey), f"{npfx}{sub}.{wkey}"))
            if cfg.block_has_mlp(kind):
                if is_moe:
                    for wkey in ("wi", "wg", "wo"):
                        for e in range(cfg.moe.num_experts):
                            specs.append(LinearSpec(
                                name=f"{npfx}moe.{wkey}.{e}",
                                get=self._moe_get(base, wkey, e),
                                set=self._moe_set(base, wkey, e),
                            ))
                    if cfg.moe.num_shared:
                        for wkey in _MLP_LINEARS[cfg.mlp_kind]:
                            specs.append(mk(base + ("moe", "shared", wkey),
                                            f"{npfx}moe.shared.{wkey}"))
                else:
                    for wkey in _MLP_LINEARS[cfg.mlp_kind]:
                        specs.append(mk(base + ("mlp", wkey),
                                        f"{npfx}mlp.{wkey}"))
        return specs

    def _moe_get(self, base, wkey, e):
        def get(sp):
            node = sp
            for k in base + ("moe",):
                node = node[k]
            return node[wkey][e].T
        return get

    def _moe_set(self, base, wkey, e):
        def set_(sp, w):
            sp = dict(sp)
            node = sp
            for k in base:
                node[k] = dict(node[k])
                node = node[k]
            moe = dict(node["moe"]) if base else dict(sp["moe"])
            if base:
                node["moe"] = moe
            else:
                sp["moe"] = moe
            moe[wkey] = moe[wkey].at[e].set(w.T.astype(self.dtype))
            return sp
        return set_

    def calib_init(self, params: Params, batch) -> Any:
        """Initial calibration state flowing through prunable segments.

        Plain LMs: the embedding output array.  Enc-dec: a dict
        {"h": decoder embedding, "enc": projected frontend features} — enc
        segments advance "enc", decoder segments advance "h" reading the
        (normed) final "enc"."""
        if not self.cfg.encdec:
            return self.first_hidden(params, batch)
        return {
            "h": self.first_hidden(params, batch),
            "enc": frontend_apply(
                params["embed"], batch["frontend_feats"], self.cfg
            ).astype(self.dtype),
        }

    def _seg_apply_factory(self, kinds, seg_type: str):
        """seg_type: 'plain' | 'enc' | 'dec' (enc-dec calibration flow)."""
        cfg = self.cfg

        def run_blocks(seg_params, h, caps, enc_out=None):
            for slot_key, kind, is_moe in kinds:
                p = seg_params[slot_key] if slot_key else seg_params
                h, _, _ = block_apply(
                    cfg, kind, p, h, is_moe=is_moe, caps=caps,
                    enc_out=enc_out, prefix_len=self._prefix_len(None),
                    name_prefix=f"{slot_key}." if slot_key else "")
            return h

        def seg_apply(seg_params, state, capture=False):
            caps = {} if capture else None
            if seg_type == "plain":
                return run_blocks(seg_params, state, caps), (caps or {})
            state = dict(state)
            if seg_type == "enc":
                state["enc"] = run_blocks(seg_params, state["enc"], caps)
            else:
                enc_out = rmsnorm(seg_params["_encln"], state["enc"],
                                  cfg.norm_eps)
                state["h"] = run_blocks(seg_params, state["h"], caps, enc_out)
            return state, (caps or {})

        # segments with equal trace keys run the identical computation on
        # identically-structured params — core.pipeline compiles each key
        # once and reuses it across e.g. all period instances
        seg_apply.trace_key = (seg_type, tuple(kinds))
        return seg_apply

    def prunable_segments(self) -> List[SegmentSpec]:
        """One segment per prefix block / per period instance (+ encoder
        layers for enc-dec).  CPU-scale path (unrolled, capture mode)."""
        cfg = self.cfg
        segs: List[SegmentSpec] = []
        dec_type = "dec" if cfg.encdec else "plain"

        if cfg.encdec:
            for li in range(cfg.enc_layers):
                kinds = [("", "enc_attn", False)]
                segs.append(SegmentSpec(
                    name=f"enc{li}",
                    apply=self._seg_apply_factory(kinds, "enc"),
                    linears=self._segment_linears(kinds),
                    get_params=functools.partial(self._get_enc_layer, li),
                    set_params=functools.partial(self._set_enc_layer, li),
                ))

        for i, kind in enumerate(cfg.prefix):
            kinds = [("", kind, cfg.slot_is_moe(i, True))]
            segs.append(SegmentSpec(
                name=f"prefix{i}",
                apply=self._seg_apply_factory(kinds, dec_type),
                linears=self._segment_linears(kinds),
                get_params=functools.partial(self._get_prefix, i),
                set_params=functools.partial(self._set_prefix, i),
            ))

        kinds = [(f"s{j}", kind, cfg.slot_is_moe(j, False))
                 for j, kind in enumerate(cfg.period)]
        for pi in range(cfg.n_periods):
            segs.append(SegmentSpec(
                name=f"period{pi}",
                apply=self._seg_apply_factory(kinds, dec_type),
                linears=self._segment_linears(kinds),
                get_params=functools.partial(self._get_period, pi),
                set_params=functools.partial(self._set_period, pi),
            ))
        return segs

    def _get_enc_layer(self, li, params):
        return tree_slice_layer(params["enc"]["layers"], li)

    def _set_enc_layer(self, li, params, seg_params):
        new = jax.tree.map(
            lambda full, s: jnp.asarray(full).at[li].set(
                s.astype(full.dtype)),
            params["enc"]["layers"], seg_params)
        return {**params, "enc": {**params["enc"], "layers": new}}

    def _get_prefix(self, i, params):
        sp = dict(params["prefix"][str(i)])
        if self.cfg.encdec:
            sp["_encln"] = params["enc"]["ln"]
        return sp

    def _set_prefix(self, i, params, seg_params):
        sp = {k: v for k, v in seg_params.items() if k != "_encln"}
        return {**params, "prefix": {**params["prefix"], str(i): sp}}

    def _get_period(self, pi, params):
        sp = {k: tree_slice_layer(v, pi) for k, v in params["layers"].items()}
        if self.cfg.encdec:
            sp["_encln"] = params["enc"]["ln"]
        return sp

    def _set_period(self, pi, params, seg_params):
        new = {
            k: jax.tree.map(
                lambda full, s: jnp.asarray(full).at[pi].set(
                    s.astype(full.dtype)),
                params["layers"][k], seg_params[k])
            for k in params["layers"]
        }
        return {**params, "layers": new}

    # -------------------------------------------------------- accounting
    def param_counts(self) -> Dict[str, int]:
        """total / active / embedding param counts (for 6·N·D roofline).

        ``active`` scales MoE expert weights by top_k/num_experts (+shared
        experts in full); embedding = token table (excluded from N by the
        6ND convention; the LM head matmul is real compute and stays in).
        """
        shapes = self.init_shapes()
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        total = active = embed = 0
        mc = self.cfg.moe
        for keypath, leaf in flat:
            path = "/".join(str(getattr(k, "key", k)) for k in keypath)
            n = int(np.prod(leaf.shape))
            total += n
            if path.endswith("embed/tok"):
                embed += n
                continue
            if mc is not None and "moe/w" in path and "shared" not in path:
                active += int(n * mc.top_k / mc.num_experts)
            else:
                active += n
        return {"total": total, "active": active, "embed": embed,
                "nonembed_total": total - embed}
