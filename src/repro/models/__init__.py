"""Model zoo: ArchConfig-driven LM assembly over a shared layer library."""

from repro.models.base import ArchConfig, MoEConfig
from repro.models.transformer import LM, block_apply, block_cache_init

__all__ = ["ArchConfig", "MoEConfig", "LM", "block_apply", "block_cache_init"]
