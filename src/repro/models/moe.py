"""Mixture-of-Experts MLP with expert parallelism over the ``model`` axis.

Routing is computed on model-replicated activations (they are replicated
across the tensor-parallel axis at block boundaries), so dispatch needs NO
all-to-all: each model shard gathers — locally — the tokens routed to ITS
experts, runs the batched expert matmuls, scatters back, and one psum over
``model`` combines expert contributions.  Communication per MoE layer is
exactly one all-reduce of the (N_local, D) output — the same volume as the
dense TP all-reduce it replaces.

Single-device path (CPU tests, pruning engine) is the identical math with
E_local = E and no collectives; capture mode additionally records
per-expert routed activations (x, validity) for the per-expert Hessians
(DESIGN.md §3: experts calibrate on their routed tokens only).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.api import current_ctx
from repro.dist.compat import shard_map
from repro.dist.sharding import moe_dispatch_specs
from repro.models.base import ArchConfig
from repro.models.layers import (Params, _dense_init, mlp_apply,
                                 mlp_init, rmsnorm, rmsnorm_init)


def moe_init(key, cfg: ArchConfig, dtype) -> Params:
    mc = cfg.moe
    d, e, f = cfg.d_model, mc.num_experts, mc.d_ff_expert
    ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f * 2 * cfg.num_layers)
    p = {
        "ln": rmsnorm_init(d, dtype),
        "router": _dense_init(ks[0], d, e, jnp.float32),  # router stays f32
        "wi": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale_in).astype(dtype),
        "wg": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale_in).astype(dtype),
        "wo": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * scale_out).astype(dtype),
    }
    if mc.num_shared:
        p["shared"] = mlp_init(ks[4], cfg, dtype, d_ff=mc.num_shared * f)
    return p


def _route(x2, router_w, top_k: int):
    """x2: (N, D) → dense renormalized gate matrix (N, E) f32 + aux loss."""
    logits = x2.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(x2.shape[0])[:, None], topi
    ].set(topv)
    # GShard load-balance loss: E * Σ_e mean(probs_e) * frac_tokens_e
    e = probs.shape[-1]
    frac = jnp.mean((gates > 0).astype(jnp.float32), axis=0)
    aux = e * jnp.sum(jnp.mean(probs, axis=0) * frac)
    return gates, aux


def _expert_ffn(xg, wi, wg, wo):
    """xg: (E, C, D) routed tokens → (E, C, D) expert outputs (swiglu)."""
    up = jnp.einsum("ecd,edf->ecf", xg, wi.astype(xg.dtype))
    gate = jnp.einsum("ecd,edf->ecf", xg, wg.astype(xg.dtype))
    hid = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", hid, wo.astype(xg.dtype)), hid


def _gather_compute_scatter(x2, gates_loc, wi, wg, wo, capacity, caps, prefix,
                            expert_offset=0):
    """Local dispatch: top-C tokens per (local) expert, FFN, scatter-add."""
    n, d = x2.shape
    c = min(capacity, n)
    gv, gi = jax.lax.top_k(gates_loc.T, c)          # (E_loc, C) gates/indices
    valid = gv > 0.0
    xg = x2[gi]                                      # (E_loc, C, D)
    yo, hid = _expert_ffn(xg, wi, wg, wo)
    if caps is not None:
        e_loc = xg.shape[0]
        for e in range(e_loc):
            caps[f"{prefix}wi.{expert_offset + e}"] = (xg[e], valid[e])
            caps[f"{prefix}wg.{expert_offset + e}"] = (xg[e], valid[e])
            caps[f"{prefix}wo.{expert_offset + e}"] = (hid[e], valid[e])
    yo = yo * jnp.where(valid, gv, 0.0)[..., None].astype(yo.dtype)
    out = jnp.zeros((n, d), yo.dtype).at[gi.reshape(-1)].add(
        yo.reshape(-1, d))
    return out


# §Perf (serving): bypass the shard_map expert-parallel dispatch and let
# GSPMD partition the expert einsums directly — required when expert
# weights are 2-D sharded (experts × model, d_ff × data) so trillion-
# param MoEs fit resident at serve time (kimi: 131GB/chip at EP=16 →
# 8.2GB/chip at 16×16). shard_map's in_specs pin a 1-D expert layout and
# would re-gather 2-D-sharded weights every step.
FORCE_PLAIN_GSPMD = False


def moe_apply(p: Params, h: jax.Array, cfg: ArchConfig, *,
              caps=None, prefix: str = "moe.") -> Tuple[jax.Array, jax.Array]:
    """Returns (h + moe_out, aux_loss)."""
    mc = cfg.moe
    b, t, d = h.shape
    h_in = rmsnorm(p["ln"], h, cfg.norm_eps)
    if caps is not None:
        caps[f"{prefix}router"] = h_in
    x2 = h_in.reshape(-1, d)
    n = x2.shape[0]
    gates, aux = _route(x2, p["router"], mc.top_k)

    ctx = current_ctx()
    use_shard_map = (ctx is not None and ctx.tp > 1
                     and not FORCE_PLAIN_GSPMD
                     and n % ctx.dp == 0          # tokens split over data
                     and mc.num_experts % ctx.tp == 0)
    if use_shard_map:
        tp, tpax = ctx.tp, ctx.tp_axis
        n_loc = n // ctx.dp
        cap = max(1, int(math.ceil(n_loc * mc.top_k / mc.num_experts
                                   * mc.capacity_factor)))
        e_loc = mc.num_experts // tp

        def body(x2s, gs, wi, wg, wo):
            eidx = jax.lax.axis_index(tpax)
            g_loc = jax.lax.dynamic_slice(
                gs, (0, eidx * e_loc), (x2s.shape[0], e_loc))
            out = _gather_compute_scatter(
                x2s, g_loc, wi, wg, wo, cap, None, prefix)
            return jax.lax.psum(out, tpax)

        # specs come from the dist rules layer, built off the context —
        # no ad-hoc PartitionSpec construction here (docs/dist_api.md)
        in_specs, out_specs = moe_dispatch_specs(ctx)
        out2 = shard_map(
            body,
            mesh=ctx.mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )(x2, gates, p["wi"], p["wg"], p["wo"])
    else:
        cap = max(1, int(math.ceil(n * mc.top_k / mc.num_experts
                                   * mc.capacity_factor)))
        out2 = _gather_compute_scatter(
            x2, gates, p["wi"], p["wg"], p["wo"], cap, caps, prefix)

    y = out2.reshape(b, t, d).astype(h.dtype)
    if mc.num_shared:
        # shared expert: plain dense MLP on the same normed input; reuse
        # mlp_apply's residual by passing h and letting it add.
        y = y + (mlp_apply(p["shared"], h, cfg, caps=caps,
                           prefix=f"{prefix}shared.") - h)
    return h + y, aux
