"""State-space / recurrent blocks: Mamba-1, xLSTM mLSTM and sLSTM.

All blocks follow the layers.py conventions: pre-RMSNorm + residual,
params as flat dicts with (in, out) linear kernels, optional ``caps``
capture of every linear input (for the pruning engine), and two code
paths — full-sequence (training / prefill) and single-token decode with
an explicit recurrent-state cache (the reason SSM archs run long_500k:
state is O(1) in sequence length).

Mamba-1 (Gu & Dao 2023):  selective SSM, associative-scan parallel form.
mLSTM  (Beck et al. 2024): matrix-memory LSTM, attention-like parallel
                           form over T, stabilized exponential gating.
sLSTM  (Beck et al. 2024): scalar-memory recurrent LSTM with block-diag
                           per-head recurrence — inherently sequential,
                           lax.scan over T.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig
from repro.models.layers import Params, _dense_init, linear, rmsnorm, rmsnorm_init


# ======================================================================
# Mamba-1
# ======================================================================
def mamba_init(key, cfg: ArchConfig, dtype) -> Params:
    d, di, n, r, ck = (
        cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias init so softplus(dt) spans
    # [1e-3, 1e-1] as in the reference implementation.
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init = jnp.exp(
        jax.random.uniform(ks[4], (di,), jnp.float32)
        * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3)
    )
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "ln": rmsnorm_init(d, dtype),
        "in_proj": _dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (di, ck), jnp.float32)
                   / math.sqrt(ck)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense_init(ks[2], di, r + 2 * n, dtype),
        "dt_proj": _dense_init(ks[3], r, di, dtype, scale=r**-0.5),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": jnp.log(a),
        "d": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(
            ks[5], di, d, dtype, scale=1.0 / math.sqrt(di * 2 * cfg.num_layers)),
    }


def _mamba_ssm_scan(dt, x, b, c, a, init=None):
    """Selective-scan core, parallel over T via associative_scan.

    dt, x: (B,T,Di) f32;  b, c: (B,T,N) f32;  a: (Di,N) f32 (negative).
    ``init`` (B,Di,N): carry-in state (chunked prefill continuation) —
    the scan's cumulative-decay component replays it as ``A_{1..t}·s0``.
    Returns (y: (B,T,Di), last state (B,Di,N)).
    """
    abar = jnp.exp(dt[..., None] * a[None, None])          # (B,T,Di,N)
    bx = (dt * x)[..., None] * b[:, :, None, :]            # (B,T,Di,N)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    aprod, states = jax.lax.associative_scan(combine, (abar, bx), axis=1)
    if init is not None:
        states = states + aprod * init[:, None]
    return jnp.einsum("btdn,btn->btd", states, c), states[:, -1]


def mamba_apply(
    p: Params,
    h: jax.Array,
    cfg: ArchConfig,
    *,
    caps=None,
    cache: Optional[Params] = None,
    pos=None,
    prefix: str = "mamba.",
    paged: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Returns (h + mamba(h), new_cache).

    cache = {"conv": (B, ck-1, Di), "ssm": (B, Di, N)} for decode (T==1).

    Chunked prefill (``paged`` with "slot"/"start"/"lengths", T>1, the
    continuous-batching runtime): cache leaves are the slot-pooled
    state (max_slots leading dim, serve.kvpool.StatePool); the chunk
    continues slot ``slot``'s state — conv window carried in, scan
    seeded with the carried SSM state — and positions past the prompt
    length leave the state untouched (dt masked to 0 ⇒ identity step).
    """
    di, n, r, ck = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    bsz, t, _ = h.shape
    h_in = rmsnorm(p["ln"], h, cfg.norm_eps)
    xz = linear(h_in, p["in_proj"], caps=caps, name=f"{prefix}in_proj")
    x, z = jnp.split(xz, 2, axis=-1)                       # (B,T,Di) each

    conv_w = p["conv_w"].astype(jnp.float32)               # (Di, ck)
    x32 = x.astype(jnp.float32)
    chunk = cache is not None and t > 1 and paged is not None
    prefill = cache is not None and t > 1 and not chunk

    if chunk:
        slot = paged["slot"]
        conv0 = jax.lax.dynamic_slice_in_dim(cache["conv"], slot, 1, axis=0)
        ssm0 = jax.lax.dynamic_slice_in_dim(cache["ssm"], slot, 1, axis=0)
        tpos = paged["start"] + jnp.arange(t, dtype=jnp.int32)
        valid = (tpos < paged["lengths"][0])[None, :]      # (1, T)
        # conv over [carried window ; chunk]
        xp = jnp.concatenate([conv0.astype(jnp.float32), x32], axis=1)
        stacked = jnp.stack(
            [xp[:, i:i + t, :] for i in range(ck)], axis=-1)
        xc = jnp.einsum("btdk,dk->btd", stacked, conv_w)
        # carry-out: the window ending at the last VALID input
        vc = jnp.clip(paged["lengths"][0] - paged["start"], 0, t)
        new_conv = jax.lax.dynamic_slice_in_dim(xp, vc, ck - 1, axis=1)
    elif cache is None or prefill:
        # causal depthwise conv over T: pad left ck-1
        xp = jnp.pad(x32, ((0, 0), (ck - 1, 0), (0, 0)))
        stacked = jnp.stack(
            [xp[:, i:i + t, :] for i in range(ck)], axis=-1)  # (B,T,Di,ck)
        xc = jnp.einsum("btdk,dk->btd", stacked, conv_w)
        new_conv = xp[:, t:, :]                            # last ck-1 inputs
    else:
        # decode: conv over [cache ; x_t]  (window of the last ck inputs)
        win = jnp.concatenate([cache["conv"].astype(jnp.float32), x32], axis=1)
        xc = jnp.einsum("btd,dt->bd", win, conv_w)[:, None, :]
        new_conv = win[:, 1:, :].astype(cache["conv"].dtype)
    xc = xc + p["conv_b"].astype(jnp.float32)[None, None]
    xc = jax.nn.silu(xc)

    dbc = linear(xc.astype(h.dtype), p["x_proj"], caps=caps,
                 name=f"{prefix}x_proj").astype(jnp.float32)
    dt_r, b, c = jnp.split(dbc, [r, r + n], axis=-1)
    dt = linear(dt_r.astype(h.dtype), p["dt_proj"], caps=caps,
                name=f"{prefix}dt_proj").astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"][None, None])
    a = -jnp.exp(p["a_log"])                               # (Di,N)

    if chunk:
        # padded tail positions: dt=0 ⇒ abar=1, bx=0 — identity steps,
        # so the carry-out equals the state at the last valid token
        dt = jnp.where(valid[..., None], dt, 0.0)
        y, last_state = _mamba_ssm_scan(
            dt, xc, b, c, a, init=ssm0.astype(jnp.float32))
        new_cache = dict(cache)
        new_cache["conv"] = jax.lax.dynamic_update_slice_in_dim(
            cache["conv"], new_conv.astype(cache["conv"].dtype),
            slot, axis=0)
        new_cache["ssm"] = jax.lax.dynamic_update_slice_in_dim(
            cache["ssm"], last_state.astype(cache["ssm"].dtype),
            slot, axis=0)
    elif cache is None or prefill:
        y, last_state = _mamba_ssm_scan(dt, xc, b, c, a)
        new_cache = None
        if prefill:
            new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                         "ssm": last_state.astype(cache["ssm"].dtype)}
    else:
        abar = jnp.exp(dt[:, 0, :, None] * a[None])        # (B,Di,N)
        bx = (dt[:, 0] * xc[:, 0])[..., None] * b[:, 0, None, :]
        ssm = abar * cache["ssm"].astype(jnp.float32) + bx  # (B,Di,N)
        y = jnp.einsum("bdn,bn->bd", ssm, c[:, 0])[:, None, :]
        new_conv = new_conv.astype(cache["conv"].dtype)
        ssm = ssm.astype(cache["ssm"].dtype)
        if paged is not None:
            # continuous batching: pos (B,) marks live decode slots;
            # idle/prefilling slots keep their state untouched (pages
            # get this for free via the scrap page — state rows can't)
            act = pos >= 0
            new_conv = jnp.where(act[:, None, None], new_conv,
                                 cache["conv"])
            ssm = jnp.where(act[:, None, None], ssm, cache["ssm"])
        new_cache = {"conv": new_conv, "ssm": ssm}

    y = y + p["d"].astype(jnp.float32)[None, None] * xc
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = linear(y.astype(h.dtype), p["out_proj"], caps=caps,
                 name=f"{prefix}out_proj")
    return h + out, new_cache


def mamba_cache_init(cfg: ArchConfig, batch, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


# ======================================================================
# xLSTM mLSTM (matrix memory, parallel form)
# ======================================================================
# the quadratic parallel form materializes (B,T,S,NH) decay/score
# matrices — 537GB at 32k — so long sequences switch to the CHUNKWISE
# form (intra-chunk parallel + inter-chunk recurrent state), the same
# strategy as the xLSTM paper's kernels. Threshold shared with attention.
MLSTM_CHUNK_THRESHOLD = 8192
MLSTM_CHUNK = 1024


def _mlstm_chunkwise(q, k, v, logi, logf, chunk, init=None):
    """Chunkwise-parallel stabilized mLSTM.

    q (pre-scaled), k, v: (B, T, NH, hd) f32; logi, logf: (B, T, NH).
    ``init``: carry-in (c0, n0, m0) — fresh zero state when None.
    Returns h: (B, T, NH, hd) f32.  Matches the quadratic parallel form
    (tested) at O(T·chunk) memory.
    """
    b, t, nh, hd = q.shape
    assert t % chunk == 0
    nck = t // chunk

    def to_chunks(x):
        return jnp.moveaxis(
            x.reshape(b, nck, chunk, *x.shape[2:]), 1, 0)

    qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    lis, lfs = to_chunks(logi), to_chunks(logf)

    def body(carry, xs):
        c0, n0, m0 = carry            # (b,nh,hd,hd), (b,nh,hd), (b,nh)
        qc, kc, vc, lic, lfc = xs     # (b,C,nh,hd) / (b,C,nh)
        fcum = jnp.cumsum(lfc, axis=1)                  # (b,C,nh)
        # intra-chunk decay D_ts = F_t − F_s + i_s  (s ≤ t)
        dmat = (fcum[:, :, None, :] - fcum[:, None, :, :]
                + lic[:, None, :, :])                   # (b,t,s,nh)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        a_max = jnp.max(dmat, axis=2)                   # (b,C,nh)
        m_inter = fcum + m0[:, None, :]                 # (b,C,nh)
        m_t = jnp.maximum(a_max, m_inter)
        msafe = jnp.where(jnp.isfinite(m_t), m_t, 0.0)
        intra = jnp.exp(dmat - msafe[:, :, None, :])
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * intra
        w_inter = jnp.exp(m_inter - msafe)              # (b,C,nh)
        num = (jnp.einsum("btsh,bshd->bthd", scores, vc)
               + w_inter[..., None]
               * jnp.einsum("bhde,bthd->bthe", c0, qc))
        lsum = (jnp.sum(scores, axis=2)
                + w_inter * jnp.einsum("bhd,bthd->bth", n0, qc))
        h = num / jnp.maximum(
            jnp.abs(lsum), jnp.exp(-msafe))[..., None]
        # inter-chunk state update (decay the carry by the whole chunk,
        # absorb this chunk's keys at their remaining decay)
        f_all = fcum[:, -1, :]                          # (b,nh)
        s_max = jnp.max(f_all[:, None, :] - fcum + lic, axis=1)
        m1 = jnp.maximum(f_all + m0, s_max)
        wts = jnp.exp(f_all[:, None, :] - fcum + lic - m1[:, None, :])
        decay = jnp.exp(f_all + m0 - m1)                # (b,nh)
        c1 = (decay[..., None, None] * c0
              + jnp.einsum("bch,bchd,bche->bhde", wts, kc, vc))
        n1 = decay[..., None] * n0 + jnp.einsum("bch,bchd->bhd", wts, kc)
        return (c1, n1, m1), h

    if init is None:
        init = (jnp.zeros((b, nh, hd, hd), jnp.float32),
                jnp.zeros((b, nh, hd), jnp.float32),
                jnp.full((b, nh), -1e30, jnp.float32))
    final, hs = jax.lax.scan(body, init, (qs, ks, vs, lis, lfs))
    return jnp.moveaxis(hs, 0, 1).reshape(b, t, nh, hd), final


def mlstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    di = cfg.mlstm_proj * d
    nh = cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "ln": rmsnorm_init(d, dtype),
        "wq": _dense_init(ks[0], d, di, dtype),
        "wk": _dense_init(ks[1], d, di, dtype),
        "wv": _dense_init(ks[2], d, di, dtype),
        "wi": _dense_init(ks[3], d, nh, jnp.float32, scale=0.1 / math.sqrt(d)),
        "wf": _dense_init(ks[4], d, nh, jnp.float32, scale=0.1 / math.sqrt(d)),
        "bi": jnp.zeros((nh,), jnp.float32),
        "bf": jnp.full((nh,), 3.0, jnp.float32),   # forget-open init
        "wo": _dense_init(
            ks[5], di, d, dtype, scale=1.0 / math.sqrt(di * 2 * cfg.num_layers)),
    }


def mlstm_apply(
    p: Params,
    h: jax.Array,
    cfg: ArchConfig,
    *,
    caps=None,
    cache: Optional[Params] = None,
    pos=None,
    prefix: str = "mlstm.",
    paged: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Stabilized mLSTM. cache = {"c": (B,NH,hd,hd), "n": (B,NH,hd),
    "m": (B,NH)} for decode.

    Chunked prefill (``paged`` given, T>1): one chunkwise-parallel step
    over the chunk seeded with slot ``paged["slot"]``'s pooled carry;
    padded tail positions contribute nothing (input gate masked to
    exp(-inf)=0, forget gate to log 1 = 0)."""
    d = cfg.d_model
    di = cfg.mlstm_proj * d
    nh = cfg.num_heads
    hd = di // nh
    bsz, t, _ = h.shape
    h_in = rmsnorm(p["ln"], h, cfg.norm_eps)
    q = linear(h_in, p["wq"], caps=caps, name=f"{prefix}wq")
    k = linear(h_in, p["wk"], caps=caps, name=f"{prefix}wk")
    v = linear(h_in, p["wv"], caps=caps, name=f"{prefix}wv")
    q = q.reshape(bsz, t, nh, hd).astype(jnp.float32) / math.sqrt(hd)
    k = k.reshape(bsz, t, nh, hd).astype(jnp.float32)
    v = v.reshape(bsz, t, nh, hd).astype(jnp.float32)
    h32 = h_in.astype(jnp.float32)
    logi = h32 @ p["wi"] + p["bi"]                          # (B,T,NH)
    logf = jax.nn.log_sigmoid(h32 @ p["wf"] + p["bf"])      # (B,T,NH)

    if cache is not None and t > 1 and paged is not None:
        slot = paged["slot"]
        c0 = jax.lax.dynamic_slice_in_dim(cache["c"], slot, 1, axis=0)
        n0 = jax.lax.dynamic_slice_in_dim(cache["n"], slot, 1, axis=0)
        m0 = jax.lax.dynamic_slice_in_dim(cache["m"], slot, 1, axis=0)
        tpos = paged["start"] + jnp.arange(t, dtype=jnp.int32)
        valid = (tpos < paged["lengths"][0])[None, :, None]  # (1,T,1)
        logi = jnp.where(valid, logi, -jnp.inf)
        logf = jnp.where(valid, logf, 0.0)
        y, (c1, n1, m1) = _mlstm_chunkwise(
            q, k, v, logi, logf, t,
            init=(c0.astype(jnp.float32), n0.astype(jnp.float32), m0))
        new_cache = dict(cache)
        new_cache["c"] = jax.lax.dynamic_update_slice_in_dim(
            cache["c"], c1.astype(cache["c"].dtype), slot, axis=0)
        new_cache["n"] = jax.lax.dynamic_update_slice_in_dim(
            cache["n"], n1.astype(cache["n"].dtype), slot, axis=0)
        new_cache["m"] = jax.lax.dynamic_update_slice_in_dim(
            cache["m"], m1, slot, axis=0)
    elif cache is None or t > 1:
        chunked = t > MLSTM_CHUNK_THRESHOLD and t % MLSTM_CHUNK == 0
        if chunked:
            from repro.models.layers import SEQ_PAR_ATTN, _dp_only_constrain
            if SEQ_PAR_ATTN:
                # nh=4 < TP ⇒ GSPMD shards head_dim and the chunk scan
                # all-reduces score partials per step (the GQA
                # pathology); the mixer is tiny — replicate it over
                # `model` within each data shard (one gather per layer)
                q = _dp_only_constrain(q)
                k = _dp_only_constrain(k)
                v = _dp_only_constrain(v)
                logi = _dp_only_constrain(logi)
                logf = _dp_only_constrain(logf)
            y, (cT_, nT_, mT_) = _mlstm_chunkwise(
                q, k, v, logi, logf, MLSTM_CHUNK)
        else:
            # parallel form: D_ts = exp(F_t − F_s + logi_s), F = cumsum
            fcum = jnp.cumsum(logf, axis=1)                 # (B,T,NH)
            dmat = (fcum[:, :, None, :] - fcum[:, None, :, :]
                    + logi[:, None, :, :])                  # (B,T,S,NH)
            tri = jnp.tril(jnp.ones((t, t), bool))
            dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
            m = jnp.max(dmat, axis=2, keepdims=True)        # (B,T,1,NH)
            dstab = jnp.exp(dmat - m)                       # (B,T,S,NH)
            scores = jnp.einsum("bthd,bshd->btsh", q, k) * dstab
            norm = jnp.maximum(
                jnp.abs(jnp.sum(scores, axis=2)), jnp.exp(-m[:, :, 0, :]))
            y = jnp.einsum("btsh,bshd->bthd", scores, v) / norm[..., None]
        new_cache = None
        if cache is not None and chunked:
            new_cache = {"c": cT_.astype(cache["c"].dtype),
                         "n": nT_.astype(cache["n"].dtype), "m": mT_}
        elif cache is not None:
            # prefill: summarize the prompt into the recurrent state
            dlast = fcum[:, -1:, :] - fcum + logi           # (B,T,NH)
            mT = jnp.max(dlast, axis=1)                     # (B,NH)
            wgt = jnp.exp(dlast - mT[:, None, :])           # (B,T,NH)
            cT = jnp.einsum("bth,bthd,bthe->bhde", wgt, k, v)
            nT = jnp.einsum("bth,bthd->bhd", wgt, k)
            new_cache = {"c": cT.astype(cache["c"].dtype),
                         "n": nT.astype(cache["n"].dtype), "m": mT}
    else:
        c0 = cache["c"].astype(jnp.float32)                 # (B,NH,hd,hd)
        n0 = cache["n"].astype(jnp.float32)                 # (B,NH,hd)
        m0 = cache["m"]                                     # (B,NH)
        lf, li = logf[:, 0], logi[:, 0]                     # (B,NH)
        m1 = jnp.maximum(lf + m0, li)
        fw = jnp.exp(lf + m0 - m1)[..., None]
        iw = jnp.exp(li - m1)[..., None]
        k1, v1, q1 = k[:, 0], v[:, 0], q[:, 0]              # (B,NH,hd)
        c1 = fw[..., None] * c0 + iw[..., None] * (
            k1[..., :, None] * v1[..., None, :])            # (B,NH,hd,hd)
        n1 = fw * n0 + iw * k1
        num = jnp.einsum("bhde,bhd->bhe", c1, q1)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", n1, q1)), jnp.exp(-m1))
        y = (num / den[..., None])[:, None]                 # (B,1,NH,hd)
        c1 = c1.astype(cache["c"].dtype)
        n1 = n1.astype(cache["n"].dtype)
        if paged is not None:
            # continuous batching: freeze idle slot rows (pos < 0)
            act = pos >= 0
            c1 = jnp.where(act[:, None, None, None], c1, cache["c"])
            n1 = jnp.where(act[:, None, None], n1, cache["n"])
            m1 = jnp.where(act[:, None], m1, cache["m"])
        new_cache = {"c": c1, "n": n1, "m": m1}

    y = y.reshape(bsz, t, di).astype(h.dtype)
    out = linear(y, p["wo"], caps=caps, name=f"{prefix}wo")
    return h + out, new_cache


def mlstm_cache_init(cfg: ArchConfig, batch, dtype):
    di = cfg.mlstm_proj * cfg.d_model
    nh = cfg.num_heads
    hd = di // nh
    return {
        "c": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


# ======================================================================
# xLSTM sLSTM (scalar memory, sequential recurrence)
# ======================================================================
def slstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    ks = jax.random.split(key, 9)
    rscale = 1.0 / math.sqrt(hd)

    def rmat(k):
        return (jax.random.normal(k, (nh, hd, hd), jnp.float32)
                * rscale).astype(dtype)

    return {
        "ln": rmsnorm_init(d, dtype),
        "wz": _dense_init(ks[0], d, d, dtype),
        "wi": _dense_init(ks[1], d, d, dtype),
        "wf": _dense_init(ks[2], d, d, dtype),
        "wo_gate": _dense_init(ks[3], d, d, dtype),
        "r_z": rmat(ks[4]),
        "r_i": rmat(ks[5]),
        "r_f": rmat(ks[6]),
        "r_o": rmat(ks[7]),
        "bf": jnp.full((d,), 3.0, jnp.float32),
        "wo": _dense_init(
            ks[8], d, d, dtype, scale=1.0 / math.sqrt(d * 2 * cfg.num_layers)),
    }


def _slstm_cell(p, zx, ix, fx, ox, state, nh, hd):
    """One sLSTM step. zx/ix/fx/ox: (B, D) pre-activations from inputs;
    state = (c, n, hprev, m), each (B, D) f32."""
    c0, n0, h0, m0 = state
    hh = h0.reshape(h0.shape[0], nh, hd)

    def rec(r):
        return jnp.einsum("bhd,hde->bhe", hh, r.astype(jnp.float32)).reshape(
            h0.shape[0], nh * hd)

    z = jnp.tanh(zx + rec(p["r_z"]))
    logi = ix + rec(p["r_i"])
    logf = jax.nn.log_sigmoid(fx + rec(p["r_f"]) + p["bf"][None])
    o = jax.nn.sigmoid(ox + rec(p["r_o"]))
    m1 = jnp.maximum(logf + m0, logi)
    iw = jnp.exp(logi - m1)
    fw = jnp.exp(logf + m0 - m1)
    c1 = fw * c0 + iw * z
    n1 = jnp.maximum(fw * n0 + iw, 1.0)
    h1 = o * c1 / n1
    return (c1, n1, h1, m1)


def slstm_apply(
    p: Params,
    h: jax.Array,
    cfg: ArchConfig,
    *,
    caps=None,
    cache: Optional[Params] = None,
    pos=None,
    prefix: str = "slstm.",
    paged: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Sequential sLSTM over T (lax.scan); decode consumes/updates cache
    {"c","n","h","m"} each (B, D) f32.

    Chunked prefill (``paged`` given, T>1): the scan carries on from
    slot ``paged["slot"]``'s pooled state; padded tail steps keep the
    state unchanged (per-step where-select), so the carry-out is the
    state at the last valid token."""
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    bsz, t, _ = h.shape
    h_in = rmsnorm(p["ln"], h, cfg.norm_eps)
    zx = linear(h_in, p["wz"], caps=caps, name=f"{prefix}wz").astype(jnp.float32)
    ix = linear(h_in, p["wi"], caps=caps, name=f"{prefix}wi").astype(jnp.float32)
    fx = linear(h_in, p["wf"], caps=caps, name=f"{prefix}wf").astype(jnp.float32)
    ox = linear(h_in, p["wo_gate"], caps=caps,
                name=f"{prefix}wo_gate").astype(jnp.float32)

    if cache is not None and t > 1 and paged is not None:
        slot = paged["slot"]
        state = tuple(
            jax.lax.dynamic_slice_in_dim(cache[k_], slot, 1, axis=0)
            for k_ in "cnhm")
        tpos = paged["start"] + jnp.arange(t, dtype=jnp.int32)
        valid_t = tpos < paged["lengths"][0]                # (T,)

        def step(state, xs):
            *gates, ok = xs
            st = _slstm_cell(p, *gates, state, nh, hd)
            st = tuple(jnp.where(ok, n_, o_) for n_, o_ in zip(st, state))
            return st, st[2]

        final, ys = jax.lax.scan(
            step, state,
            (zx.swapaxes(0, 1), ix.swapaxes(0, 1),
             fx.swapaxes(0, 1), ox.swapaxes(0, 1), valid_t))
        y = ys.swapaxes(0, 1)                               # (B,T,D)
        new_cache = dict(cache)
        for i, k_ in enumerate("cnhm"):
            new_cache[k_] = jax.lax.dynamic_update_slice_in_dim(
                cache[k_], final[i].astype(cache[k_].dtype), slot, axis=0)
    elif cache is None or t > 1:
        if cache is None:
            state = tuple(
                jnp.zeros((bsz, d), jnp.float32) if i != 3
                else jnp.full((bsz, d), -1e30, jnp.float32) for i in range(4))
        else:
            state = (cache["c"], cache["n"], cache["h"], cache["m"])

        def step(state, xs):
            st = _slstm_cell(p, *xs, state, nh, hd)
            return st, st[2]

        final, ys = jax.lax.scan(
            step, state,
            (zx.swapaxes(0, 1), ix.swapaxes(0, 1),
             fx.swapaxes(0, 1), ox.swapaxes(0, 1)))
        y = ys.swapaxes(0, 1)                               # (B,T,D)
        new_cache = None
        if cache is not None:
            new_cache = {"c": final[0], "n": final[1],
                         "h": final[2], "m": final[3]}
    else:
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
        st = _slstm_cell(p, zx[:, 0], ix[:, 0], fx[:, 0], ox[:, 0],
                         state, nh, hd)
        y = st[2][:, None]
        if paged is not None:
            # continuous batching: freeze idle slot rows (pos < 0)
            act = (pos >= 0)[:, None]
            st = tuple(jnp.where(act, n_, o_) for n_, o_ in zip(st, state))
        new_cache = {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}

    out = linear(y.astype(h.dtype), p["wo"], caps=caps, name=f"{prefix}wo")
    return h + out, new_cache


def slstm_cache_init(cfg: ArchConfig, batch, dtype):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
    }
