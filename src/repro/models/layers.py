"""Layer library: norms, rope, GQA attention, gated MLPs, embeddings.

Conventions:
  - params are plain nested dicts; linear kernels are stored (in, out);
  - every apply takes ``caps``: ``None`` for the fast path, or a dict that
    collects each linear's INPUT under the linear's name (the pruning
    engine's calibration capture — see core.calibration);
  - hidden states are (B, T, D); attention caches are (B, S_max, KV, hd).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig

Params = Dict[str, Any]


# ----------------------------------------------------------------------
# Param init helpers
# ----------------------------------------------------------------------
def _dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def linear(x, w, b=None, *, caps=None, name="", activation=None):
    """y = act(x @ w + b), recording the input under ``name`` when
    capturing.

    ``w`` may be a 2:4-packed dict {"vals", "idx"} (serve.sparse) — then
    the matmul dispatches through kernels.ops.nm_matmul: the jnp
    decompress-oracle on CPU, the nm_spmm Pallas kernel on TPU (which
    decompresses in VMEM and runs a dense MXU matmul off half the weight
    HBM traffic); ``b``/``activation`` ride along as the kernel's fused
    decode epilogue instead of separate HBM-round-trip ops.
    """
    if caps is not None and name:
        caps[name] = x
    if isinstance(w, dict):
        from repro.kernels import ops as _kops
        return _kops.nm_matmul(x, w["vals"], w["idx"], b,
                               activation=activation, out_dtype=x.dtype)
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    if activation is not None:
        from repro.kernels.ref import activate
        y = activate(y, activation)
    return y


# ----------------------------------------------------------------------
# Norms / rope
# ----------------------------------------------------------------------
def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta):
    """Rotary embedding. x: (..., T, n, hd); positions: (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., T, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]  # broadcast over heads
    cos = cos[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------------
# Attention block (GQA, optional qk-norm / bias / sliding window)
# ----------------------------------------------------------------------
def attn_init(key, cfg: ArchConfig, dtype) -> Params:
    hd, h, kv, d = cfg.hd, cfg.num_heads, cfg.num_kv_heads, cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "ln": rmsnorm_init(d, dtype),
        "wq": _dense_init(ks[0], d, h * hd, dtype),
        "wk": _dense_init(ks[1], d, kv * hd, dtype),
        "wv": _dense_init(ks[2], d, kv * hd, dtype),
        "wo": _dense_init(ks[3], h * hd, d, dtype,
                          scale=1.0 / math.sqrt(h * hd * 2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _qkv(p, h_in, cfg: ArchConfig, positions, caps, prefix,
         seq_par_ok: bool = True):
    b, t, _ = h_in.shape
    hd, nh, kv = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    q = linear(h_in, p["wq"], p.get("bq"), caps=caps, name=f"{prefix}wq")
    k = linear(h_in, p["wk"], p.get("bk"), caps=caps, name=f"{prefix}wk")
    v = linear(h_in, p["wv"], p.get("bv"), caps=caps, name=f"{prefix}wv")
    q = q.reshape(b, t, nh, hd)
    k = k.reshape(b, t, kv, hd)
    v = v.reshape(b, t, kv, hd)
    if SEQ_PAR_ATTN and seq_par_ok and t >= SEQ_PAR_MIN_T:
        # reshard head→sequence parallelism BEFORE rope/qk-norm, so the
        # per-position elementwise ops never touch head-sharded tensors
        q = _seq_constrain(q)
        k = _seq_constrain(k)
        v = _seq_constrain(v)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if positions is not None:  # rope
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, nh, kv):
    """Grouped scaled-dot-product attention.

    q: (B,T,H,hd), k/v: (B,S,KV,hd), mask: broadcastable to (B,KV,G,T,S).
    """
    b, t, _, hd = q.shape
    g = nh // kv
    qg = q.reshape(b, t, kv, g, hd)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(b, t, nh * hd)


# full-sequence attention switches to the online-softmax path when the
# score matrix would exceed this many elements per (T,S) pair — 32k
# prefill would otherwise materialize T² scores (flash-attention
# algorithm, expressed as a lax.scan over KV chunks so it stays
# SPMD-partitionable in the dry-run; the Pallas kernel is the TPU
# drop-in for the same math).
ONLINE_ATTN_THRESHOLD = 8192
ONLINE_ATTN_CHUNK = 1024
# §Perf iteration (beyond-paper): sliding-window layers compute only the
# (chunk, chunk+window) band instead of the full (T, S) score matrix —
# T·(chunk+w) score work, a ~16× cut for gemma3's 1024-window locals at
# 32k. Off by default so the baseline roofline reflects the naive path.
BANDED_LOCAL_ATTN = False

# §Perf iteration (beyond-paper): sequence-parallel long attention.
# With GQA kv-heads < TP degree, GSPMD shards q/k/v on head_dim and the
# score contraction emits an all-reduce INSIDE the KV-chunk scan —
# ×(chunks × layers) on the wire (the dominant baseline cost at 32k
# prefill). Constraining q/k/v to be sharded on the SEQUENCE dim over
# the model axis makes every score matmul local; the only traffic is
# streaming each (small) KV chunk to all shards.
SEQ_PAR_ATTN = False
SEQ_PAR_MIN_T = 2048      # apply to train-length sequences too


def _seq_constrain(x, seq_dim=1):
    """Shard dim0 over the data axes and ``seq_dim`` over model (active
    mesh only — no-op in single-device tests)."""
    from repro.dist.api import constrain, current_ctx
    ctx = current_ctx()
    if ctx is None:
        return x
    tp = ctx.mesh.shape[ctx.tp_axis]
    if x.shape[seq_dim] % tp or x.shape[0] % ctx.dp:
        return x
    spec = [None] * x.ndim
    spec[0] = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    spec[seq_dim] = ctx.tp_axis
    return constrain(x, *spec)


def _dp_only_constrain(x):
    """Batch-sharded, replicated over model — one explicit all-gather."""
    from repro.dist.api import constrain, current_ctx
    ctx = current_ctx()
    if ctx is None or x.shape[0] % ctx.dp:
        return x
    spec = [None] * x.ndim
    spec[0] = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    return constrain(x, *spec)


def _sdpa_banded(q, k, v, nh, kv, window, chunk=ONLINE_ATTN_CHUNK):
    """Windowed causal grouped attention over the diagonal band only."""
    b, t, _, hd = q.shape
    g = nh // kv
    assert t % chunk == 0, f"T={t} % chunk={chunk}"
    nq = t // chunk
    band = chunk + window
    if band >= t:            # window covers everything — no banding win
        mask = causal_mask(t, t, window)
        return _sdpa(q, k, v, mask, nh, kv)
    # banded layers: leave sharding entirely to GSPMD — q seq-sharded
    # re-gathers all of q per chunk step (5×275GB measured), and even
    # explicit once-per-layer K/V gathers cost 45×537MB; head-parallel
    # banded attention needs neither (gemma3: 16 q-heads = TP)
    qg = (q.reshape(b, t, kv, g, hd).astype(jnp.float32)
          / math.sqrt(hd))
    qs = jnp.moveaxis(qg.reshape(b, nq, chunk, kv, g, hd), 1, 0)

    def body(_, xs):
        qc, ci = xs                       # qc: (b, chunk, kv, g, hd)
        start = jnp.maximum(ci * chunk - window, 0)
        kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        kpos = start + jnp.arange(band, dtype=jnp.int32)
        qpos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        ok = ((kpos[None, :] <= qpos[:, None])
              & (kpos[None, :] > qpos[:, None] - window))
        sc = jnp.einsum("bckgd,bskd->bkgcs", qc, kc.astype(jnp.float32))
        sc = jnp.where(ok[None, None, None], sc, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        oc = jnp.einsum("bkgcs,bskd->bckgd", p, vc.astype(jnp.float32))
        return None, oc

    _, outs = jax.lax.scan(
        body, None, (qs, jnp.arange(nq, dtype=jnp.int32)))
    out = jnp.moveaxis(outs, 0, 1)        # (b, nq, chunk, kv, g, hd)
    return out.reshape(b, t, nh * hd).astype(v.dtype)


def _sdpa_online(q, k, v, nh, kv, *, window=None, prefix_len=None,
                 chunk=ONLINE_ATTN_CHUNK):
    """Causal grouped attention via online softmax over KV chunks.

    Same semantics as _sdpa with a causal (+window/prefix) mask, but
    peak memory is O(T·chunk) instead of O(T·S).
    """
    b, t, _, hd = q.shape
    g = nh // kv
    s = k.shape[1]
    assert s % chunk == 0, f"S={s} not divisible by chunk={chunk}"
    nck = s // chunk
    if SEQ_PAR_ATTN:
        q = _seq_constrain(q)
        # gather K/V across the model axis ONCE per layer (explicit AG);
        # otherwise the chunk scan's dynamic-slice over a seq-sharded
        # operand re-gathers the full K/V every iteration (measured:
        # 2×268MB × chunks × layers — the dominant baseline wire cost)
        k = _dp_only_constrain(k)
        v = _dp_only_constrain(v)
    qg = (q.reshape(b, t, kv, g, hd).astype(jnp.float32)
          / math.sqrt(hd))
    ks = jnp.moveaxis(k.reshape(b, nck, chunk, kv, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nck, chunk, kv, hd), 1, 0)
    qpos = jnp.arange(t, dtype=jnp.int32)

    def body(carry, xs):
        m, lsum, acc = carry
        kc, vc, ci = xs
        kpos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        ok = kpos[None, :] <= qpos[:, None]                  # (t, chunk)
        if window is not None:
            ok &= kpos[None, :] > qpos[:, None] - window
        if prefix_len is not None:
            ok |= kpos[None, :] < prefix_len
        sc = jnp.einsum("btkgd,bckd->bkgtc", qg,
                        kc.astype(jnp.float32))              # (b,kv,g,t,c)
        sc = jnp.where(ok[None, None, None], sc, -jnp.inf)
        m_cur = jnp.max(sc, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        # avoid NaN from (-inf) - (-inf) on fully-masked rows
        msafe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(sc - msafe[..., None])
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - msafe), 0.0)
        lsum = lsum * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgtc,bckd->bkgtd", p, vc.astype(jnp.float32))
        return (m_new, lsum, acc), None

    m0 = jnp.full((b, kv, g, t), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g, t), jnp.float32)
    a0 = jnp.zeros((b, kv, g, t, hd), jnp.float32)
    if SEQ_PAR_ATTN:
        # keep the online-softmax carries sequence-sharded too, or XLA
        # reshards (b,kv,g,t[,hd]) between chunk steps inside the scan
        m0 = _seq_constrain(m0, seq_dim=3)
        l0 = _seq_constrain(l0, seq_dim=3)
        a0 = _seq_constrain(a0, seq_dim=3)
    (m, lsum, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (ks, vs, jnp.arange(nck, dtype=jnp.int32)))
    out = acc / jnp.maximum(lsum, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1)                            # (b,t,kv,g,hd)
    return out.reshape(b, t, nh * hd).astype(v.dtype)


def causal_mask(t, s, window: Optional[int] = None, offset: int = 0,
                prefix_len: Optional[int] = None):
    """(T,S) boolean mask. offset = absolute position of query 0;
    prefix_len = leading bidirectional prefix (VLM prefix-LM)."""
    qpos = jnp.arange(t)[:, None] + offset
    kpos = jnp.arange(s)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    if prefix_len is not None:
        ok |= kpos < prefix_len
    return ok[None, None, None]  # (1,1,1,T,S)


def _paged_write(pages: jax.Array, vals: jax.Array,
                 flat_idx: jax.Array) -> jax.Array:
    """Scatter K/V rows into a paged pool.

    pages: (P, page_size, KV, hd); vals: (..., KV, hd) with leading dims
    matching flat_idx: (...,) flat token slots (page*page_size+offset).
    Duplicate indices (everything clamped to the scrap page 0) are
    garbage-on-garbage — never read back because attention masks by
    length.
    """
    p_, ps_, kvh, hd = pages.shape
    flat = pages.reshape(p_ * ps_, kvh, hd)
    flat = flat.at[flat_idx.reshape(-1)].set(
        vals.reshape(-1, kvh, hd).astype(pages.dtype))
    return flat.reshape(p_, ps_, kvh, hd)


def _paged_write_q8(pages: jax.Array, scales: jax.Array, vals: jax.Array,
                    flat_idx: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Quantizing twin of :func:`_paged_write` for int8 KV pages
    (ServeConfig.kv_dtype="int8"): each written row quantizes per
    (token, kv-head) — scale = amax(|row|)/127 over head_dim — and the
    scale scatters into the pool's (P, page_size, KV) f32 scale leaf at
    the same flat slot, so dequant at the gather needs no second
    lookup structure."""
    p_, ps_, kvh, hd = pages.shape
    rows = vals.reshape(-1, kvh, hd).astype(jnp.float32)
    s = jnp.max(jnp.abs(rows), axis=-1) / 127.0            # (R, KV)
    q = jnp.round(rows / jnp.maximum(s, 1e-8)[..., None]).astype(jnp.int8)
    flat = pages.reshape(p_ * ps_, kvh, hd)
    flat = flat.at[flat_idx.reshape(-1)].set(q)
    sflat = scales.reshape(p_ * ps_, kvh)
    sflat = sflat.at[flat_idx.reshape(-1)].set(s)
    return flat.reshape(p_, ps_, kvh, hd), sflat.reshape(p_, ps_, kvh)


def _paged_scatter(cache: Params, k: jax.Array, v: jax.Array,
                   flat: jax.Array) -> Params:
    """Scatter K/V rows into the paged pool leaves, quantizing on write
    when the cache carries scale leaves (int8 KV pages).  Returns the
    dict of updated leaves."""
    if "k_scale" not in cache:
        return {"k": _paged_write(cache["k"], k, flat),
                "v": _paged_write(cache["v"], v, flat)}
    kq, ks = _paged_write_q8(cache["k"], cache["k_scale"], k, flat)
    vq, vs = _paged_write_q8(cache["v"], cache["v_scale"], v, flat)
    return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}


def attn_paged_cache_init(cfg: ArchConfig, num_pages: int, page_size: int,
                          dtype) -> Params:
    """Paged pool leaves.  int8 adds per-row f32 scale leaves alongside
    the quantized pages (quantize at the scatter, dequantize at the
    gather); any other dtype keeps the two-leaf layout byte-identical
    to the pre-ISSUE-9 tree."""
    kv, hd = cfg.num_kv_heads, cfg.hd
    cache = {
        "k": jnp.zeros((num_pages, page_size, kv, hd), dtype),
        "v": jnp.zeros((num_pages, page_size, kv, hd), dtype),
    }
    if jnp.dtype(dtype) == jnp.int8:
        cache["k_scale"] = jnp.zeros((num_pages, page_size, kv), jnp.float32)
        cache["v_scale"] = jnp.zeros((num_pages, page_size, kv), jnp.float32)
    return cache


def attn_apply(
    p: Params,
    h: jax.Array,
    cfg: ArchConfig,
    *,
    kind: str = "attn",
    caps=None,
    cache: Optional[Params] = None,
    pos=None,
    prefix: str = "attn.",
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,
    prefix_len: Optional[int] = None,
    paged: Optional[Params] = None,
    page_size: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Pre-norm attention with residual. Returns (h_out, new_cache).

    Modes:
      full-sequence (cache=None): causal over T (optionally windowed);
                     ``causal=False`` = encoder self-attention;
                     ``prefix_len`` = bidirectional prefix (VLM prefix-LM);
      decode        (cache given): h is (B,1,D), writes K/V at ``pos`` and
                     attends over positions <= pos;
      cross         (cross_kv given): encoder-decoder cross attention —
                     no cache update, no rope, full visibility.

    Paged modes (``paged`` given — the continuous-batching serve runtime,
    docs/serving.md): ``cache`` holds (num_pages, page_size, KV, hd)
    pool leaves; ``paged["block_tables"]`` (B, P_max) maps each
    request's logical positions to physical pages.  Prefill additionally
    takes ``paged["lengths"]`` (padded prompt tails write to the scrap
    page 0); a chunked prefill (``paged["start"]`` given) writes the
    chunk's K/V at absolute positions ``start + arange(T)`` and attends
    over the *gathered slot context* — earlier chunks' keys read back
    from the pages — so a prompt of any length runs as fixed-size
    chunks through one jitted shape; decode takes per-request ``pos``
    (B,), -1 marking idle slots.
    """
    window = cfg.window if kind == "attn_local" else None
    b, t, _ = h.shape
    nh, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    h_in = rmsnorm(p["ln"], h, cfg.norm_eps)

    if cross_kv is not None:
        q = linear(h_in, p["wq"], p.get("bq"), caps=caps, name=f"{prefix}wq")
        q = q.reshape(b, t, nh, hd)
        k, v = cross_kv
        s = k.shape[1]
        mask = jnp.ones((1, 1, 1, t, s), bool)
        out = _sdpa(q, k, v, mask, nh, kv)
        y = linear(out, p["wo"], caps=caps, name=f"{prefix}wo")
        return h + y, cache

    if cache is not None and t > 1 and paged is not None \
            and paged.get("start") is not None:
        # chunked paged prefill (B=1 per request): one fixed-size token
        # chunk at absolute positions start..start+T-1.  The chunk's
        # K/V is scattered into the pages FIRST, then attention runs
        # over the gathered slot context — intra-chunk keys and earlier
        # chunks' keys both read back from the pool, so every chunk of
        # every prompt shares one jitted shape.
        lengths = paged["lengths"]                           # (B,)
        bt = paged["block_tables"]                           # (B, P_max)
        tpos = paged["start"] + jnp.arange(t, dtype=jnp.int32)
        positions = tpos[None, :]
        q, k, v = _qkv(p, h_in, cfg, positions, caps, prefix,
                       seq_par_ok=False)
        page = jnp.take_along_axis(
            bt, tpos[None, :] // page_size, axis=1)          # (B, T)
        flat = page * page_size + tpos[None, :] % page_size
        flat = jnp.where(tpos[None, :] < lengths[:, None], flat, 0)
        upd = _paged_scatter(cache, k, v, flat)
        s_len = bt.shape[1] * page_size
        kc = upd["k"][bt].reshape(b, s_len, kv, hd)
        vc = upd["v"][bt].reshape(b, s_len, kv, hd)
        if "k_scale" in upd:
            # int8 pages: dequantize the gathered slot context row-wise
            kc = kc.astype(jnp.float32) * upd["k_scale"][bt].reshape(
                b, s_len, kv)[..., None]
            vc = vc.astype(jnp.float32) * upd["v_scale"][bt].reshape(
                b, s_len, kv)[..., None]
        kpos = jnp.arange(s_len, dtype=jnp.int32)
        ok = kpos[None, None, :] <= positions[:, :, None]    # (B, T, S)
        if window is not None:
            ok &= kpos[None, None, :] > positions[:, :, None] - window
        out = _sdpa(q, kc, vc, ok[:, None, None], nh, kv)
        y = linear(out, p["wo"], caps=caps, name=f"{prefix}wo")
        new_cache = dict(cache)
        new_cache.update(upd)
        return h + y, new_cache

    if cache is None or t > 1:
        positions = jnp.arange(t)[None, :]
        banded = (BANDED_LOCAL_ATTN and causal and window is not None
                  and prefix_len is None and t > ONLINE_ATTN_THRESHOLD)
        q, k, v = _qkv(p, h_in, cfg, positions, caps, prefix,
                       seq_par_ok=not banded)
        if (BANDED_LOCAL_ATTN and causal and window is not None
                and prefix_len is None and t > ONLINE_ATTN_THRESHOLD):
            out = _sdpa_banded(q, k, v, nh, kv, window)
        elif causal and t > ONLINE_ATTN_THRESHOLD:
            out = _sdpa_online(q, k, v, nh, kv, window=window,
                               prefix_len=prefix_len)
        else:
            if SEQ_PAR_ATTN and t >= SEQ_PAR_MIN_T:
                # q rows stay seq-sharded; K/V gathered once per layer
                k = _dp_only_constrain(k)
                v = _dp_only_constrain(v)
            if causal:
                mask = causal_mask(t, t, window, prefix_len=prefix_len)
            else:
                mask = jnp.ones((1, 1, 1, t, t), bool)
            out = _sdpa(q, k, v, mask, nh, kv)
        y = linear(out, p["wo"], caps=caps, name=f"{prefix}wo")
        if cache is None:
            return h + y, None
        if paged is not None:
            # paged prefill: scatter the prompt's K/V into this request's
            # pages; padded tail positions (>= lengths) go to scrap page 0
            lengths = paged["lengths"]                       # (B,)
            bt = paged["block_tables"]                       # (B, P_max)
            tpos = jnp.arange(t, dtype=jnp.int32)
            page = jnp.take_along_axis(
                bt, tpos[None, :] // page_size, axis=1)      # (B, T)
            flat = page * page_size + tpos[None, :] % page_size
            flat = jnp.where(tpos[None, :] < lengths[:, None], flat, 0)
            new_cache = dict(cache)
            new_cache.update(_paged_scatter(cache, k, v, flat))
            return h + y, new_cache
        # prefill: write the prompt's K/V into cache[0:t]
        new_cache = dict(cache)
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        return h + y, new_cache

    # decode: t == 1
    if paged is not None:
        # paged decode: per-request write position (pos (B,), -1 = idle
        # slot); block-table attention over the page pool
        from repro.kernels import ops as _kops

        bt = paged["block_tables"]                           # (B, P_max)
        wpos = jnp.maximum(pos, 0)
        positions = wpos[:, None]
        q, k1, v1 = _qkv(p, h_in, cfg, positions, caps, prefix)
        page = jnp.take_along_axis(
            bt, (wpos // page_size)[:, None], axis=1)[:, 0]  # (B,)
        flat = page * page_size + wpos % page_size
        flat = jnp.where(pos >= 0, flat, 0)                  # idle → scrap
        upd = _paged_scatter(cache, k1[:, 0], v1[:, 0], flat)
        lengths = jnp.maximum(pos + 1, 0)                    # idle → 0
        qg = q[:, 0].reshape(b, kv, nh // kv, hd)
        out = _kops.paged_attention(qg, upd["k"], upd["v"], bt, lengths,
                                    window=window,
                                    k_scale=upd.get("k_scale"),
                                    v_scale=upd.get("v_scale"))
        out = out.reshape(b, 1, nh * hd)
        y = linear(out, p["wo"], caps=caps, name=f"{prefix}wo")
        new_cache = dict(cache)
        new_cache.update(upd)
        return h + y, new_cache

    positions = jnp.full((b, t), pos, dtype=jnp.int32)
    q, k1, v1 = _qkv(p, h_in, cfg, positions, caps, prefix)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k1.astype(cache["k"].dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v1.astype(cache["v"].dtype), (0, pos, 0, 0))
    s = k_cache.shape[1]
    kpos = jnp.arange(s)[None, :]
    ok = kpos <= pos
    if window is not None:
        ok &= kpos > pos - window
    if prefix_len is not None:
        ok |= kpos < prefix_len
    mask = ok[:, None, None, None, :]  # (1,1,1,1,S) broadcast over T=1
    out = _sdpa(q, k_cache, v_cache, mask, nh, kv)
    y = linear(out, p["wo"], caps=caps, name=f"{prefix}wo")
    new_cache = dict(cache)
    new_cache["k"] = k_cache
    new_cache["v"] = v_cache
    return h + y, new_cache


def attn_cache_init(cfg: ArchConfig, batch, max_len, dtype):
    kv, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


# ----------------------------------------------------------------------
# Dense MLP (swiglu / geglu / gelu)
# ----------------------------------------------------------------------
def mlp_init(key, cfg: ArchConfig, dtype, d_ff=None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "ln": rmsnorm_init(d, dtype),
        "wi": _dense_init(ks[0], d, f, dtype),
        "wo": _dense_init(ks[1], f, d, dtype,
                          scale=1.0 / math.sqrt(f * 2 * cfg.num_layers)),
    }
    if cfg.mlp_kind in ("swiglu", "geglu"):
        p["wg"] = _dense_init(ks[2], d, f, dtype)
    return p


def mlp_apply(p, h, cfg: ArchConfig, *, caps=None, prefix="mlp."):
    h_in = rmsnorm(p["ln"], h, cfg.norm_eps)
    # glu gates fuse their activation into the projection epilogue (a
    # no-op for dense weights, a true in-kernel epilogue for 2:4-packed
    # ones); jax.nn.gelu's default approximate=True matches the fused
    # "gelu" epilogue in kernels.ref.activate
    if cfg.mlp_kind == "swiglu":
        up = linear(h_in, p["wi"], caps=caps, name=f"{prefix}wi")
        act = linear(h_in, p["wg"], caps=caps, name=f"{prefix}wg",
                     activation="silu") * up
    elif cfg.mlp_kind == "geglu":
        up = linear(h_in, p["wi"], caps=caps, name=f"{prefix}wi")
        act = linear(h_in, p["wg"], caps=caps, name=f"{prefix}wg",
                     activation="gelu") * up
    else:
        act = linear(h_in, p["wi"], caps=caps, name=f"{prefix}wi",
                     activation="gelu")
    y = linear(act, p["wo"], caps=caps, name=f"{prefix}wo")
    return h + y


# ----------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------
def embed_init(key, cfg: ArchConfig, dtype) -> Params:
    p = {"tok": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                                   jnp.float32) * 0.02).astype(dtype)}
    if cfg.frontend is not None:
        k2 = jax.random.fold_in(key, 1)
        p["frontend_proj"] = _dense_init(k2, cfg.frontend_dim, cfg.d_model, dtype)
    return p


def embed_apply(p, tokens, cfg: ArchConfig):
    h = p["tok"][tokens]
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return h


def frontend_apply(p, feats, cfg: ArchConfig):
    """Stub modality frontend: project precomputed patch/frame embeddings."""
    return feats.astype(p["frontend_proj"].dtype) @ p["frontend_proj"]


def unembed_init(key, cfg: ArchConfig, dtype) -> Params:
    p = {"ln": rmsnorm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(key, cfg.d_model, cfg.vocab_size, dtype)
    return p


# §Perf iteration 1b: GSPMD leaves h feature-sharded entering the LM
# head, so the vocab-parallel matmul contracts a sharded dim and
# all-reduces the full f32 LOGITS (40GB/dev at 4k×256×152k) — plus the
# mirrored all-gather in the backward. Gathering h (bf16, ~0.5GB) first
# makes the head a clean column-parallel matmul. Off by default
# (baseline faithfulness); enabled by OptFlags.fsdp_embed_fix.
HEAD_GATHER = False


def unembed_apply(p, embed_p, h, cfg: ArchConfig):
    h = rmsnorm(p["ln"], h, cfg.norm_eps)
    if HEAD_GATHER:
        h = _dp_only_constrain(h)
    if cfg.tie_embeddings:
        return h @ embed_p["tok"].T.astype(h.dtype)
    return h @ p["head"].astype(h.dtype)
