"""Architecture configuration shared by the whole model zoo.

One :class:`ArchConfig` describes any of the assigned architectures:
decoder-only transformers (dense/MoE/local-global), Mamba/xLSTM SSM blocks,
hybrid interleaves, encoder-decoder, and modality-frontend stubs.

Layer structure = ``prefix`` blocks (unrolled) followed by ``periods``
repeats of ``period`` (scanned — keeps the lowered HLO O(one period) deep
regardless of depth).  Block kinds:

  attn         global causal attention
  attn_local   sliding-window attention (cfg.window)
  mamba        Mamba-1 selective SSM
  mlstm        xLSTM matrix-memory block
  slstm        xLSTM scalar-memory block (recurrent mixing)

Each block kind carries its own MLP unless the kind is self-contained
(mamba/mlstm/slstm have none by default; cfg.ssm_mlp adds one).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0           # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads

    # layer layout
    prefix: Tuple[str, ...] = ()            # unrolled leading blocks
    period: Tuple[str, ...] = ("attn",)     # scanned repeating unit
    # MLP kind per attention block: swiglu | geglu | gelu | none
    mlp_kind: str = "swiglu"
    # which period/prefix slots carry a MoE MLP instead of dense (by kind)
    moe: Optional[MoEConfig] = None
    moe_slots: Tuple[int, ...] = ()         # period slot indices with MoE MLP
    moe_prefix_slots: Tuple[int, ...] = ()

    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None            # for attn_local
    rope_theta: float = 10_000.0
    embed_scale: bool = False               # gemma: h *= sqrt(d_model)
    tie_embeddings: bool = False

    # ssm details
    ssm_state: int = 16                     # mamba N
    ssm_expand: int = 2                     # d_inner = expand * d_model
    ssm_conv: int = 4
    mlstm_proj: int = 2                     # mLSTM up-projection factor
    ssm_mlp: bool = False                   # ssm blocks carry an FFN (jamba)

    # encoder-decoder
    encdec: bool = False
    enc_layers: int = 0

    # modality frontend stub ("patch" | "audio" | None)
    frontend: Optional[str] = None
    frontend_dim: int = 0                   # raw embedding dim from the stub
    frontend_len: int = 0                   # number of frontend positions

    # numerics / training
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "none"                     # none | full
    scan_layers: bool = True                # lax.scan over periods (False:
                                            # unrolled — cost-analysis runs)
    # shapes this arch skips, name -> reason (recorded in EXPERIMENTS.md)
    skip_shapes: Dict[str, str] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------
    def __post_init__(self):
        n_periodic = self.num_layers - len(self.prefix)
        if n_periodic < 0 or (self.period and n_periodic % len(self.period)):
            raise ValueError(
                f"{self.name}: {self.num_layers} layers != "
                f"{len(self.prefix)} prefix + k*{len(self.period)} period")

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def n_periods(self) -> int:
        return (self.num_layers - len(self.prefix)) // len(self.period)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, self.d_model // 16)

    def block_has_mlp(self, kind: str) -> bool:
        if kind in ("attn", "attn_local", "dec_attn", "enc_attn"):
            return self.mlp_kind != "none"
        if kind in ("mamba", "mlstm", "slstm"):
            return self.ssm_mlp and self.mlp_kind != "none"
        return False

    def slot_is_moe(self, slot: int, in_prefix: bool) -> bool:
        if self.moe is None:
            return False
        slots = self.moe_prefix_slots if in_prefix else self.moe_slots
        return slot in slots
