"""Learning-rate schedules (as step → lr callables for AdamW.lr)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr


def warmup_linear(peak: float, warmup: int, total: int, floor: float = 0.0):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        lin = peak + (floor - peak) * prog
        return jnp.where(s < warmup, warm, lin)
    return lr
