"""AdamW with configurable moment dtype + global-norm clipping.

Moments can be stored bf16 (halves optimizer HBM — the dominant training
-state term at scale; see EXPERIMENTS.md §Dry-run memory table).  Master
computation is always f32; params keep their storage dtype (bf16 weights
+ f32 update math = standard mixed precision).  ZeRO-1 sharding of the
moments is a *sharding* concern: dist.sharding assigns moments the same
specs as their params plus the fsdp axes, so the optimizer is agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array       # () int32
    mu: Any               # first moments (pytree like params)
    nu: Any               # second moments


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"         # "float32" | "bfloat16"

    # ------------------------------------------------------------------
    def init(self, params: Any) -> OptState:
        mdt = jnp.dtype(self.moment_dtype)

        def zeros(p):
            return jnp.zeros(p.shape, mdt)

        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads: Any, state: OptState, params: Any
               ) -> Tuple[Any, OptState, dict]:
        """Returns (new_params, new_state, stats)."""
        step = state.step + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        gnorm = jnp.sqrt(sum(
            jnp.sum(g * g) for g in jax.tree.leaves(g32)))
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)
        mdt = jnp.dtype(self.moment_dtype)

        def upd(p, g, mu, nu):
            mu32 = mu.astype(jnp.float32) * b1 + g * (1 - b1)
            nu32 = nu.astype(jnp.float32) * b2 + (g * g) * (1 - b2)
            mhat = mu32 / bc1
            vhat = nu32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

        out = jax.tree.map(upd, params, g32, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step, new_mu, new_nu), {
            "grad_norm": gnorm, "lr": lr}
