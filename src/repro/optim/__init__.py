"""Optimizers, LR schedules, gradient compression."""

from repro.optim.adamw import AdamW, OptState
from repro.optim.schedules import warmup_cosine, warmup_linear
from repro.optim.compression import (
    ef_quantize,
    ef_init,
    compressed_psum,
    quantize_int8,
    dequantize_int8,
)

__all__ = [
    "AdamW",
    "OptState",
    "warmup_cosine",
    "warmup_linear",
    "ef_quantize",
    "ef_init",
    "compressed_psum",
    "quantize_int8",
    "dequantize_int8",
]
