"""Int8 gradient compression with error feedback (cross-pod DP traffic).

At 2×16×16 the inter-pod gradient all-reduce crosses DCN (slow links);
int8 compression cuts its bytes 4× (vs f32) / 2× (vs bf16).  Plain
quantization biases the update — error feedback (Seide et al. 2014;
Karimireddy et al. 2019) accumulates the quantization residual locally
and re-adds it next step, restoring convergence (tested in
tests/test_optim.py by matching full-precision training loss).

Two layers:
  - ``ef_quantize``: pure pytree transform (residual carried in state) —
    what the trainer calls on grads before the psum when enabled;
  - ``compressed_psum``: shard_map collective — reduce-scatter the int8
    payload + per-chunk scales, dequantize-sum locally, all-gather int8.
    Wire bytes ≈ 2·N·1B instead of 2·N·4B.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q int8, scale f32 scalar)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_init(params: Any) -> Any:
    """Zero error-feedback residuals, shaped like params (f32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_quantize(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Quantize (grads + residual) to int8-and-back; return
    (dequantized grads, new residual)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return deq, x - deq

    out = jax.tree.map(one, grads, residual)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, res


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce-**mean** of 1-D ``x`` over ``axis_name``, int8 on the wire.

    Call INSIDE shard_map.  Scheme (reduce-scatter + all-gather, both in
    int8 with per-chunk f32 scales):

      1. split x into n chunks, quantize each (per-chunk scale);
      2. all_to_all: shard i receives chunk i from every peer (int8);
      3. dequantize + mean locally; re-quantize;
      4. all_gather the int8 result chunks (+ scales).

    Wire ≈ 2·N·1B vs 8·N·1B for an f32 ring all-reduce (4×).  Length of
    x must divide the axis size (trainer pads the flattened grads).
    """
    n = jax.lax.psum(1, axis_name)
    chunks = x.reshape(n, -1)                               # (n, N/n)
    # per-chunk quantization
    amax = jnp.max(jnp.abs(chunks), axis=1)
    scales = jnp.maximum(amax, 1e-12) / 127.0               # (n,)
    q = jnp.clip(jnp.round(chunks / scales[:, None]),
                 -127, 127).astype(jnp.int8)
    # shard i collects chunk i from all peers: (n, N/n) int8
    recv = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    peer_scales = jax.lax.all_to_all(
        scales.reshape(n, 1), axis_name, split_axis=0, concat_axis=0,
        tiled=False)                                        # (n, 1)
    local = jnp.sum(
        recv.astype(jnp.float32) * peer_scales, axis=0) / n  # (N/n,)
    q2, s2 = quantize_int8(local)
    out = jax.lax.all_gather(q2, axis_name, tiled=True)     # (N,) int8
    out_scales = jax.lax.all_gather(s2, axis_name)          # (n,)
    return (out.reshape(n, -1).astype(jnp.float32)
            * out_scales[:, None]).reshape(-1)
