"""Atomic sharded checkpoints with manifest + content hashes.

Layout:
  <dir>/step_000042/            one directory per step
    arrays.npz                  every pytree leaf, path-keyed
    manifest.json               {step, keys, shapes, dtypes, sha256, extra}
  <dir>/LATEST                  text file: the last *complete* step dir

Crash safety: writes go to ``step_X.tmp-<pid>`` and are atomically
``os.replace``d into place, LATEST is updated last — a reader can never
observe a half-written checkpoint.  Hash verification on load catches
torn/corrupted files (a node dying mid-fsync).

Elastic restore: leaves load as host numpy; the trainer re-device_puts
them under the *current* mesh's shardings — restoring a 2-pod checkpoint
onto 1 pod (or a different mesh shape) is the same code path (tested).
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


# ----------------------------------------------------------------------
# pytree <-> flat path dict
# ----------------------------------------------------------------------
def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for keypath, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        out[path] = np.asarray(jax.device_get(leaf))
    return out


def _unflatten_into(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for keypath, tmpl in paths:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        if path not in flat:
            raise KeyError(f"checkpoint missing leaf {path!r}")
        arr = flat[path]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"leaf {path!r}: checkpoint shape {arr.shape} != "
                f"model shape {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_pytree(path: str, tree: Any, extra: Optional[dict] = None) -> None:
    """Atomic single-file-pair save of a pytree into directory ``path``."""
    flat = _flatten(tree)
    tmp = f"{path}.tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    buf = io.BytesIO()
    np.savez(buf, **flat)
    data = buf.getvalue()
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "sha256": hashlib.sha256(data).hexdigest(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.isdir(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


def load_pytree(path: str, template: Any = None,
                verify: bool = True) -> Tuple[Any, dict]:
    """Load (tree-or-flat-dict, extra). Verifies content hash."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "arrays.npz"), "rb") as f:
        data = f.read()
    if verify:
        digest = hashlib.sha256(data).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {path}: sha256 mismatch (corrupt)")
    arrs = dict(np.load(io.BytesIO(data)))
    if template is None:
        return arrs, manifest.get("extra", {})
    return _unflatten_into(template, arrs), manifest.get("extra", {})


# ----------------------------------------------------------------------
class CheckpointStore:
    """Step-indexed checkpoint directory with retention + LATEST pointer."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        path = self._step_dir(step)
        save_pytree(path, tree, extra={"step": step, **(extra or {})})
        latest_tmp = os.path.join(self.root, f".LATEST.tmp-{os.getpid()}")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(path))
            f.flush()
            os.fsync(f.fileno())
        os.replace(latest_tmp, os.path.join(self.root, "LATEST"))
        self._retain()
        return path

    def _retain(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def list_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[len("step_"):]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.root, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                name = f.read().strip()
            path = os.path.join(self.root, name)
            if os.path.isdir(path):
                try:
                    return int(name[len("step_"):])
                except ValueError:
                    pass
        steps = self.list_steps()   # fall back to scanning (LATEST torn)
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Optional[Tuple[int, Any, dict]]:
        """Restore the newest *valid* checkpoint ≤ step (or latest).

        Walks backwards past corrupt checkpoints (torn writes on a dead
        node) until a hash-valid one loads.
        """
        steps = [s for s in self.list_steps() if step is None or s <= step]
        for s in reversed(steps):
            try:
                tree, extra = load_pytree(self._step_dir(s), template)
                return s, tree, extra
            except Exception:  # corrupt — keep walking back
                continue
        return None


# ----------------------------------------------------------------------
class PruneProgressStore:
    """Per-segment pruning progress (core.engine fault tolerance)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.path = os.path.join(root, "prune_progress")

    def save(self, next_segment: int, params: Any) -> None:
        save_pytree(self.path, params, extra={"next_segment": next_segment})

    def load(self) -> Optional[Tuple[int, Any]]:
        if not os.path.isdir(self.path):
            return None
        flat, extra = load_pytree(self.path, template=None)
        return extra["next_segment"], flat

    def load_into(self, template: Any) -> Optional[Tuple[int, Any]]:
        if not os.path.isdir(self.path):
            return None
        tree, extra = load_pytree(self.path, template)
        return extra["next_segment"], tree

    def finalize(self) -> None:
        if os.path.isdir(self.path):
            shutil.rmtree(self.path)
