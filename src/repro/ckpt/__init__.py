"""Fault-tolerant checkpointing: atomic npz + manifest, elastic restore."""

from repro.ckpt.store import (
    CheckpointStore,
    PruneProgressStore,
    save_pytree,
    load_pytree,
)

__all__ = [
    "CheckpointStore",
    "PruneProgressStore",
    "save_pytree",
    "load_pytree",
]
