"""Training driver: ``python -m repro.launch.train --arch paper-tiny-lm``.

CPU-scale end-to-end: builds the model, synthetic pipeline, AdamW, and
runs the fault-tolerant Trainer (resumable; kill and rerun to test).
On a real cluster the same entry point runs under the production mesh
(``--mesh production`` inside a multi-host jax.distributed setup) — the
pipeline and Trainer resolve the mesh from the ``use_mesh`` context.
"""

from __future__ import annotations

import argparse


from repro import configs as cfglib
from repro.data import DataPipeline
from repro.dist import add_mesh_argument, mesh_context
from repro.models import LM
from repro.optim import AdamW
from repro.optim.schedules import warmup_cosine
from repro.train import Trainer, TrainConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_tiny_lm")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--out", default="/tmp/repro_train")
    ap.add_argument("--seed", type=int, default=0)
    add_mesh_argument(ap)
    args = ap.parse_args()

    cfg = (cfglib.get_smoke(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    with mesh_context(args.mesh):
        model = LM(cfg)
        pipe = DataPipeline(cfg, args.batch, args.seq, seed=args.seed)
        opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10, args.steps))
        tc = TrainConfig(
            total_steps=args.steps, global_batch=args.batch,
            seq_len=args.seq, ckpt_every=args.ckpt_every, out_dir=args.out,
            microbatches=args.microbatches,
            grad_compression=args.grad_compression)
        trainer = Trainer(model, opt, pipe, tc)
        params, _, info = trainer.run()
    print(f"trained {info['steps']} steps "
          f"(stragglers: {info['straggler_events']}); "
          f"checkpoints in {args.out}")


if __name__ == "__main__":
    main()
