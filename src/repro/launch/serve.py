"""Serving driver: continuous-batching decode off a (optionally
2:4-pruned) checkpoint — batch CLI or a streaming HTTP server.

  # batch: N random-prompt requests through the router, print a summary
  python -m repro.launch.serve --arch paper-tiny-lm \\
      --params /tmp/pruned/pruned_params --sparse --requests 8

  # server: OpenAI-style /v1/completions with SSE streaming
  python -m repro.launch.serve --arch paper-tiny-lm --server --port 8000 \\
      --replicas 2 --queue-depth 64

Both paths go through the SAME serve.frontend request/response objects
(docs/serving_frontend.md): the batch mode builds CompletionRequests
and calls ``Router.complete`` — it is a client of the server's code
path, not parallel plumbing.  ``--serve-mode static`` keeps the legacy
bucketed engine (no sessions/streaming: the batch path lowers the same
wire objects straight onto ``ServeEngine.generate``).

Every runtime knob funnels through ONE :class:`repro.serve.ServeConfig`
built here by ``ServeConfig.from_args`` and handed down whole —
engine, replicas, router (docs/serving.md).  The continuous runtime's
paged-pool knobs include ``--page-size`` / ``--num-pages`` plus the
ISSUE-7 prefix/swap switches ``--prefix-cache/--no-prefix-cache`` and
``--host-swap-pages``.
"""

from __future__ import annotations

import argparse
import asyncio
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.ckpt import load_pytree
from repro.dist import add_mesh_argument, mesh_context
from repro.models import LM
from repro.obs import Obs
from repro.serve import ServeConfig, ServeEngine, sparsify_params
from repro.serve.frontend import (CompletionRequest, CompletionResponse,
                                  Replica, Router, Supervisor, run_server,
                                  to_engine_request)


def install_sigterm_handler() -> None:
    """Route SIGTERM (the orchestrator's stop signal) through the SAME
    KeyboardInterrupt path as Ctrl-C: drain-first shutdown, then the
    ``finally`` trace export — instead of dying mid-step with KV state
    on the floor (ISSUE-10 satellite)."""

    def _raise(signum, frame):
        raise KeyboardInterrupt

    try:
        signal.signal(signal.SIGTERM, _raise)
    except ValueError:
        pass   # not the main thread (tests import and call main())


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_tiny_lm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--params", default=None,
                    help="pruned_params dir (default: random init)")
    ap.add_argument("--sparse", action="store_true",
                    help="pack 2:4 weights → nm_spmm kernel path")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="serve slots per engine replica")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--sampling", default="greedy",
                    choices=("greedy", "temperature", "top-k", "top-p"),
                    help="decode sampling: greedy argmax, plain "
                         "temperature, or top-k / top-p (nucleus) "
                         "filtering — all keyed per (uid, step) in "
                         "continuous mode, so preemption-recompute "
                         "replays identical tokens")
    ap.add_argument("--top-k", type=int, default=40,
                    help="k for --sampling top-k")
    ap.add_argument("--top-p", type=float, default=0.9,
                    help="nucleus mass for --sampling top-p")
    ap.add_argument("--serve-mode", default="continuous",
                    choices=("continuous", "static"),
                    help="continuous batching (paged KV) or the legacy "
                         "static bucketed path")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (continuous mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="KV pool size in pages (default: dense-cache "
                         "capacity equivalent)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens per chunked-prefill step "
                         "(continuous mode; one jitted shape)")
    ap.add_argument("--steps-per-sync", type=int, default=8,
                    help="fused decode steps per host sync (continuous "
                         "mode): the device runs K sample/record/advance "
                         "steps in one burst and the host only wakes for "
                         "scheduler events — tokens are bit-identical "
                         "for every K (docs/serving.md)")
    ap.add_argument("--prefix-cache", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="hash-based prefix reuse over refcounted KV "
                         "pages: cached prompt pages attach shared "
                         "without prefill, copy-on-write on divergence "
                         "(continuous mode; token streams are "
                         "bit-identical either way)")
    ap.add_argument("--host-swap-pages", type=int, default=None,
                    help="host-memory swap arena capacity in pages: "
                         "preemption evicts a victim's exclusive pages "
                         "to the host tier and streams them back on "
                         "resume instead of recomputing (default: "
                         "pool-sized; 0 disables → recompute-only)")
    ap.add_argument("--kv-dtype", default="fp32",
                    choices=("fp32", "int8"),
                    help="KV page storage dtype: int8 quantizes pages "
                         "on write with per-row scales (half the page "
                         "bytes — the default pool sizing then holds "
                         "2x the tokens; greedy streams match fp32 "
                         "within a small tolerance, docs/serving.md)")
    # ---------------------------------------------- server front end
    ap.add_argument("--server", action="store_true",
                    help="run the streaming HTTP front end instead of "
                         "a one-shot batch (docs/serving_frontend.md)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel ServeEngine replicas behind the "
                         "least-loaded router (--server / batch "
                         "continuous mode)")
    ap.add_argument("--queue-depth", type=int, default=None,
                    help="per-replica wait-queue cap; a full queue "
                         "answers 429 instead of buffering unboundedly")
    # ---------------------------------------------- observability
    ap.add_argument("--metrics", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="serve metrics registry (counters/gauges/"
                         "histograms behind /metrics, /stats and the "
                         "end-of-run report); --no-metrics turns every "
                         "instrumentation point into a zero-cost no-op "
                         "(docs/observability.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record request-lifecycle spans (admit wait, "
                         "prefill chunks, decode bursts, preemption/"
                         "swap/CoW events) and write Chrome-trace JSON "
                         "here on exit — load in chrome://tracing or "
                         "Perfetto; token streams are bit-identical "
                         "with tracing on or off")
    # ---------------------------------------------- chaos injection
    ap.add_argument("--inject-fault", action="append", default=None,
                    metavar="SITE[:K=V,...]",
                    help="deterministic fault injection for chaos "
                         "testing (repeatable). SITE is one of "
                         "engine_step|replica_worker|pool_alloc|"
                         "slow_burst|swap_error; keys: after=N (skip N "
                         "passes), count=N (fire N times), delay_s=S "
                         "(slow_burst stall), replica=rK (scope to one "
                         "replica). e.g. "
                         "--inject-fault replica_worker:after=2,replica=r0")
    add_mesh_argument(ap)
    return ap


def load_model(args):
    cfg = (cfglib.get_smoke(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    model = LM(cfg)
    if args.params:
        tpl = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                           jax.eval_shape(model.init, jax.random.key(0)))
        params, extra = load_pytree(args.params, tpl)
        params = jax.tree.map(jnp.asarray, params)
        print(f"loaded params ({extra})")
    else:
        params = model.init(jax.random.key(0))
    if args.sparse:
        params = sparsify_params(params)
        print("packed 2:4-sparse weights (nm_spmm path)")
    return cfg, model, params


def make_engine(model, params, config: ServeConfig,
                obs: Obs = None) -> ServeEngine:
    # the engine resolves the active mesh: params go resident
    # tensor-parallel, the paged pool / bucket batches shard by the
    # dist rules
    return ServeEngine(model, params, config, obs=obs)


def make_router(model, params, config: ServeConfig,
                obs: Obs = None) -> Router:
    # every replica shares one seed: a request's stream is identical
    # regardless of which replica serves it (per-(uid, step) keys).
    # Replica reads its wait-queue cap off engine.config.queue_depth.
    #
    # One obs bundle is shared by every replica — each writes its own
    # ``replica``-labelled series into the single registry, which is
    # what /metrics scrapes and the end-of-run report reads.
    if obs is None:
        obs = Obs.create(metrics=config.metrics, trace=config.trace)
    reps = [Replica(make_engine(model, params, config,
                                obs=obs.labelled(f"r{i}")),
                    name=f"r{i}", seed=0)
            for i in range(config.replicas)]
    return Router(reps)


def _random_requests(cfg, args):
    rng = np.random.default_rng(0)
    return [
        CompletionRequest(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=8,
                                dtype=np.int32).tolist(),
            max_tokens=args.max_new)
        for i in range(args.requests)
    ]


def run_batch(cfg, model, params, args, config: ServeConfig,
              obs: Obs) -> None:
    creqs = _random_requests(cfg, args)
    eng = None
    t0 = time.monotonic()
    if config.mode == "continuous":
        router = make_router(model, params, config, obs=obs)
        eng = router.replicas[0].engine
        if eng.mode != "continuous":
            # arch fell back to static: no sessions — drop to the
            # static path below on the already-built engine
            router.close()
        else:
            t0 = time.monotonic()
            results = router.complete(creqs)
            dt = time.monotonic() - t0
            router.drain(timeout=30)
            _summary(results, [r.engine for r in router.replicas], dt)
            return
    if eng is None:
        eng = make_engine(model, params, config, obs=obs.labelled("r0"))
    if eng.mode != config.mode:
        print(f"note: {config.mode} unsupported for {cfg.name} — "
              f"fell back to {eng.mode}")
    # static engines have no session/streaming path; same wire objects,
    # lowered straight onto generate()
    t0 = time.monotonic()
    raw = eng.generate([to_engine_request(c, c.uid) for c in creqs])
    dt = time.monotonic() - t0
    _summary([CompletionResponse.from_result(r) for r in raw], [eng], dt)


def _registries(engines):
    regs = []
    for e in engines:
        reg = e.obs.metrics
        if reg.enabled and all(reg is not x for x in regs):
            regs.append(reg)
    return regs


def _summary(results, engines, dt) -> None:
    """End-of-run report, read from the obs registry (ISSUE-8): one
    source of truth with the /metrics endpoint instead of a parallel
    sum over per-engine stat dicts."""
    toks = sum(len(r.tokens) for r in results)
    for r in results[:4]:
        print(f"req {r.uid}: {list(r.tokens)}"
              + (f"  [{r.replica}]" if r.replica else ""))
    preempts = sum(r.preemptions for r in results)
    regs = _registries(engines)

    def total(name: str) -> float:
        return sum(f.total() for f in (reg.get(name) for reg in regs)
                   if f is not None)

    syncs = total("serve_host_syncs_total")
    burst = total("serve_device_steps_total") / syncs if syncs else 0.0
    slot_steps = total("serve_slot_steps_total")
    # aggregate utilization: emitted tokens per slot-step occupied —
    # the registry-level view of Result.utilization
    util = total("serve_tokens_total") / slot_steps if slot_steps else 0.0
    mode = engines[0].mode
    print(f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s) "
          f"[{mode}] host-syncs/token {syncs / max(1, toks):.2f} "
          f"burst {burst:.1f} util {util:.2f}"
          + (f" preemptions {preempts}" if preempts else ""))
    from repro.obs.metrics import merge_histograms

    ttft = merge_histograms(
        [f for f in (reg.get("serve_ttft_seconds") for reg in regs)
         if f is not None])
    if ttft is not None and ttft.count:
        print(f"ttft p50 {ttft.quantile(0.5) * 1e3:.1f}ms "
              f"p95 {ttft.quantile(0.95) * 1e3:.1f}ms "
              f"(n={ttft.count})")


def _export_trace(obs: Obs, path) -> None:
    if path and obs.tracer.enabled:
        n = obs.tracer.export(path)
        print(f"wrote {n} trace events -> {path}")


def run_frontend(cfg, model, params, args, config: ServeConfig,
                 obs: Obs) -> None:
    if config.mode != "continuous":
        raise SystemExit("--server needs the continuous runtime "
                         "(streaming sessions); drop --serve-mode static")
    router = make_router(model, params, config, obs=obs)
    if router.replicas[0].engine.mode != "continuous":
        raise SystemExit(f"--server unsupported for {cfg.name}: the arch "
                         f"falls back to the static bucketed engine")
    # supervision (ISSUE-10): restart crashed/stalled workers and fail
    # their in-flight requests over to healthy siblings
    sup = Supervisor(router)
    sup.start()
    try:
        asyncio.run(run_server(router, args.host, args.port))
    except KeyboardInterrupt:
        print("draining...")
        sup.stop()
        router.drain(timeout=30)
    finally:
        sup.stop()


def main() -> None:
    args = build_parser().parse_args()
    install_sigterm_handler()
    config = ServeConfig.from_args(args)   # the ONE knob intake point
    # ONE obs bundle for the whole process: every replica labels its
    # series into this registry/tracer (docs/observability.md)
    obs = Obs.create(metrics=config.metrics, trace=config.trace)
    with mesh_context(args.mesh):
        cfg, model, params = load_model(args)
        try:
            if args.server:
                run_frontend(cfg, model, params, args, config, obs)
            else:
                run_batch(cfg, model, params, args, config, obs)
        finally:
            _export_trace(obs, args.trace_out)


if __name__ == "__main__":
    main()
