"""Serving driver: batched decode off a (optionally 2:4-pruned) checkpoint.

  python -m repro.launch.serve --arch paper-tiny-lm \\
      --params /tmp/pruned/pruned_params --sparse --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.ckpt import load_pytree
from repro.dist import add_mesh_argument, mesh_context
from repro.models import LM
from repro.serve import Request, ServeEngine, sparsify_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_tiny_lm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--params", default=None,
                    help="pruned_params dir (default: random init)")
    ap.add_argument("--sparse", action="store_true",
                    help="pack 2:4 weights → nm_spmm kernel path")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    add_mesh_argument(ap)
    args = ap.parse_args()

    cfg = (cfglib.get_smoke(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    with mesh_context(args.mesh):
        model = LM(cfg)
        if args.params:
            tpl = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                               jax.eval_shape(model.init, jax.random.key(0)))
            params, extra = load_pytree(args.params, tpl)
            params = jax.tree.map(jnp.asarray, params)
            print(f"loaded params ({extra})")
        else:
            params = model.init(jax.random.key(0))
        if args.sparse:
            params = sparsify_params(params)
            print("packed 2:4-sparse weights (nm_spmm path)")

        # the engine resolves the active mesh: params go resident
        # tensor-parallel, batches shard over the data axes
        eng = ServeEngine(model, params, max_batch=8, max_len=args.max_len,
                          temperature=args.temperature)
        rng = np.random.default_rng(0)
        reqs = [
            Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=8,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)
        ]
        t0 = time.monotonic()
        results = eng.generate(reqs)
        dt = time.monotonic() - t0
    toks = sum(len(r.tokens) for r in results)
    for r in results[:4]:
        print(f"req {r.uid}: {r.tokens.tolist()}")
    print(f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
