"""Serving driver: continuous-batching decode off a (optionally
2:4-pruned) checkpoint.

  python -m repro.launch.serve --arch paper-tiny-lm \\
      --params /tmp/pruned/pruned_params --sparse --requests 8

``--serve-mode static`` selects the legacy bucketed path; the default
continuous runtime takes ``--page-size`` / ``--num-pages`` for the paged
KV pool (docs/serving.md).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.ckpt import load_pytree
from repro.dist import add_mesh_argument, mesh_context
from repro.models import LM
from repro.serve import Request, ServeEngine, sparsify_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_tiny_lm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--params", default=None,
                    help="pruned_params dir (default: random init)")
    ap.add_argument("--sparse", action="store_true",
                    help="pack 2:4 weights → nm_spmm kernel path")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--sampling", default="greedy",
                    choices=("greedy", "temperature", "top-k", "top-p"),
                    help="decode sampling: greedy argmax, plain "
                         "temperature, or top-k / top-p (nucleus) "
                         "filtering — all keyed per (uid, step) in "
                         "continuous mode, so preemption-recompute "
                         "replays identical tokens")
    ap.add_argument("--top-k", type=int, default=40,
                    help="k for --sampling top-k")
    ap.add_argument("--top-p", type=float, default=0.9,
                    help="nucleus mass for --sampling top-p")
    ap.add_argument("--serve-mode", default="continuous",
                    choices=("continuous", "static"),
                    help="continuous batching (paged KV) or the legacy "
                         "static bucketed path")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (continuous mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="KV pool size in pages (default: dense-cache "
                         "capacity equivalent)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens per chunked-prefill step "
                         "(continuous mode; one jitted shape)")
    ap.add_argument("--steps-per-sync", type=int, default=8,
                    help="fused decode steps per host sync (continuous "
                         "mode): the device runs K sample/record/advance "
                         "steps in one burst and the host only wakes for "
                         "scheduler events — tokens are bit-identical "
                         "for every K (docs/serving.md)")
    add_mesh_argument(ap)
    args = ap.parse_args()

    cfg = (cfglib.get_smoke(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    with mesh_context(args.mesh):
        model = LM(cfg)
        if args.params:
            tpl = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                               jax.eval_shape(model.init, jax.random.key(0)))
            params, extra = load_pytree(args.params, tpl)
            params = jax.tree.map(jnp.asarray, params)
            print(f"loaded params ({extra})")
        else:
            params = model.init(jax.random.key(0))
        if args.sparse:
            params = sparsify_params(params)
            print("packed 2:4-sparse weights (nm_spmm path)")

        temperature = args.temperature
        top_k = top_p = None
        if args.sampling == "top-k":
            top_k = args.top_k
        elif args.sampling == "top-p":
            top_p = args.top_p
        if args.sampling != "greedy" and temperature <= 0.0:
            temperature = 1.0          # sampling modes need a live draw

        # the engine resolves the active mesh: params go resident
        # tensor-parallel, the paged pool / bucket batches shard by the
        # dist rules
        eng = ServeEngine(model, params, max_batch=8, max_len=args.max_len,
                          temperature=temperature, top_k=top_k, top_p=top_p,
                          mode=args.serve_mode, page_size=args.page_size,
                          num_pages=args.num_pages,
                          prefill_chunk=args.prefill_chunk,
                          steps_per_sync=args.steps_per_sync)
        if eng.mode != args.serve_mode:
            print(f"note: {args.serve_mode} unsupported for {cfg.name} — "
                  f"fell back to {eng.mode}")
        rng = np.random.default_rng(0)
        reqs = [
            Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=8,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)
        ]
        t0 = time.monotonic()
        results = eng.generate(reqs)
        dt = time.monotonic() - t0
    toks = sum(len(r.tokens) for r in results)
    for r in results[:4]:
        print(f"req {r.uid}: {r.tokens.tolist()}")
    util = float(np.mean([r.utilization for r in results]))
    preempts = sum(r.preemptions for r in results)
    syncs = eng.stats["host_syncs"] / max(1, eng.stats["tokens"])
    print(f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s) "
          f"[{eng.mode}] slot-utilization {util:.0%} "
          f"host-syncs/token {syncs:.2f}"
          + (f" preemptions {preempts}" if preempts else ""))


if __name__ == "__main__":
    main()
