"""Pruning driver: the paper's Algorithm 1 over a whole checkpointed model.

  python -m repro.launch.prune --arch paper-tiny-lm \\
      --ckpt /tmp/repro_train --sparsity 2:4 --method SM --out /tmp/pruned

Resumable: progress is checkpointed per segment (kill + rerun continues
at the interrupted transformer block).  SIGTERM lands on the same path
as Ctrl-C: the current segment's checkpointed progress survives and the
stage trace (``--trace-out``) is exported on the way out.
"""

from __future__ import annotations

import argparse
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.ckpt import CheckpointStore, PruneProgressStore, save_pytree
from repro.core import PruningEngine
from repro.core.engine import summarize
from repro.data import DataPipeline, calibration_batches
from repro.dist import add_mesh_argument, mesh_context
from repro.models import LM
from repro.obs import Obs


def load_trained_params(model: LM, ckpt_dir: str):
    tpl = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                       jax.eval_shape(model.init, jax.random.key(0)))
    store = CheckpointStore(ckpt_dir)
    restored = store.restore({"params": tpl, "opt": None, "ef": None})
    if restored is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    _, tree, _ = restored
    return jax.tree.map(jnp.asarray, tree["params"])


def eval_ppl(model: LM, params, pipe: DataPipeline, n: int = 8) -> float:
    tot = cnt = 0.0
    for i in range(n):
        _, m = model.loss_fn(params, pipe.eval_batch(i))
        tot += float(m["ce"]) * float(m["tokens"])
        cnt += float(m["tokens"])
    return float(np.exp(tot / cnt))


def install_sigterm_handler() -> None:
    """Orchestrator SIGTERM → KeyboardInterrupt: the per-segment
    progress store has already checkpointed everything solved so far
    (rerun resumes), and the ``finally`` below still exports the stage
    trace instead of losing it (ISSUE-10 satellite)."""

    def _raise(signum, frame):
        raise KeyboardInterrupt

    try:
        signal.signal(signal.SIGTERM, _raise)
    except ValueError:
        pass   # not the main thread


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_tiny_lm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", required=True)
    ap.add_argument("--sparsity", default="2:4",
                    help='"0.5" unstructured or "N:M"')
    ap.add_argument("--method", default="SM",
                    choices=("magnitude", "wanda", "SS", "SM", "MS", "MM"))
    ap.add_argument("--blocksize", type=int, default=64)
    ap.add_argument("--gamma", type=float, default=0.01)
    ap.add_argument("--calib-samples", type=int, default=32)
    ap.add_argument("--calib-seq", type=int, default=64)
    ap.add_argument("--pipeline", default="auto",
                    choices=("auto", "on", "off"),
                    help="batched/async calibration-solve scheduler "
                         "(core.pipeline); 'off' = the paper's serial loop")
    ap.add_argument("--calib-shard", default="auto",
                    choices=("auto", "on", "off"),
                    help="accumulate calibration Hessians per data(+pod) "
                         "shard and merge with hessian_allreduce")
    ap.add_argument("--out", default="/tmp/repro_pruned")
    ap.add_argument("--metrics", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="prune-pipeline stage timing through the obs "
                         "registry (prune_stage_seconds_total{stage}; "
                         "docs/observability.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write Chrome-trace JSON of the pipelined "
                         "capture/solve/propagate stage spans here")
    add_mesh_argument(ap)
    args = ap.parse_args()
    install_sigterm_handler()

    cfg = (cfglib.get_smoke(args.arch) if args.smoke
           else cfglib.get_config(args.arch))
    # created up front so an interrupted run (Ctrl-C / SIGTERM) still
    # exports whatever stage spans it recorded before dying
    obs = Obs.create(metrics=args.metrics, trace=args.trace_out is not None)
    try:
        _run(args, cfg, obs)
    finally:
        if args.trace_out:
            n = obs.tracer.export(args.trace_out)
            print(f"wrote {n} trace events -> {args.trace_out}")


def _run(args, cfg, obs: Obs) -> None:
    with mesh_context(args.mesh):
        model = LM(cfg)
        params = load_trained_params(model, args.ckpt)
        pipe = DataPipeline(cfg, 16, args.calib_seq, seed=0)
        print(f"dense ppl: {eval_ppl(model, params, pipe):.4f}")

        calib = calibration_batches(
            cfg, n_samples=args.calib_samples, seq_len=args.calib_seq)
        # the engine resolves the active mesh: layer solves run
        # row-parallel over the `model` axis when one is present
        engine = PruningEngine(
            model, args.sparsity, method=args.method,
            blocksize=args.blocksize, gamma=args.gamma,
            progress_store=PruneProgressStore(args.out),
            pipeline=args.pipeline, calib_shard=args.calib_shard)
        # stage timing + spans flow through the same registry/tracer
        # the serve stack uses (core.pipeline reads engine.obs)
        engine.obs = obs
        pruned, reports = engine.run(params, calib)
        s = summarize(reports)
        print(f"pruned {s['linears']} linears, mean sparsity "
              f"{s['mean_sparsity']:.3f}, total recon error "
              f"{s['total_recon_error']:.4f}")
        ps = engine.last_pipeline_stats
        if ps is not None:
            print(f"pipeline: {ps.segments} segments, "
                  f"{ps.calib_shards} calib shard(s), {ps.compiles} "
                  f"jitted stage fn(s), wall {ps.wall_s:.2f}s")
        print(f"{args.method} {args.sparsity} ppl: "
              f"{eval_ppl(model, pruned, pipe):.4f}")
    save_pytree(os.path.join(args.out, "pruned_params"), pruned,
                extra={"method": args.method, "sparsity": args.sparsity})
    print(f"saved to {args.out}/pruned_params")


if __name__ == "__main__":
    main()
