"""Production mesh construction (functions only — importing this module
never touches jax device state; jax locks the device count on first use,
and the dry-run must set XLA_FLAGS before that happens)."""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 (512 chips, 2 pods).

    Axes: ``pod`` (DCN, gradient/batch outer axis), ``data`` (batch +
    FSDP), ``model`` (tensor/expert parallel).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> Tuple[str, ...]:
    """The batch-sharding axes of a production mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_host_mesh():
    """1×1 mesh over the local device (CPU tests of mesh-aware code)."""
    return jax.make_mesh((1, 1), ("data", "model"))
