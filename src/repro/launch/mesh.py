"""Back-compat shim: mesh construction lives in :mod:`repro.dist.mesh`
(still functions only — importing never touches jax device state)."""

from repro.dist.mesh import (  # noqa: F401
    dp_axes_of,
    make_host_mesh,
    make_production_mesh,
    mesh_from_spec,
)

__all__ = [
    "dp_axes_of",
    "make_host_mesh",
    "make_production_mesh",
    "mesh_from_spec",
]
