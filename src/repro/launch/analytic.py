"""Analytic FLOPs / HBM-byte model for the roofline (§Roofline).

Why analytic: XLA's CPU-backend ``cost_analysis()`` counts while-loop
bodies ONCE (verified: a 2-layer and an 8-layer scanned model report
identical flops), so scanned-layer models under-report by ~num_layers.
The dry-run therefore reports BOTH: (a) these closed-form counts (used
for the roofline terms), and (b) the HLO numbers extrapolated from
k=1 / k=2 unrolled-depth compiles (collectives — exact, since cost is
affine in depth; see launch/dryrun.py).

Conventions:
  - matmul = 2·m·n·k flops; train = fwd + 2×bwd (+1 fwd when remat=full);
  - causal attention context Σ_t ctx(t) = T(T+1)/2, windowed ≈ Σ min(t+1,w);
  - MoE: top_k (+shared) experts per token for flops; weight *traffic*
    counts every expert the batch plausibly touches;
  - bytes are per-step HBM traffic estimates: weights + optimizer state +
    activations (c_act·B·T·d per layer R/W) + logits + KV/state caches.
All numbers are GLOBAL; divide by chip count for per-device terms.
"""

from __future__ import annotations

from typing import Dict

from repro.models.base import ArchConfig

BF16 = 2
F32 = 4


# ----------------------------------------------------------------------
# per-token weight-matmul sizes (Σ m·n over the block's linears)
# ----------------------------------------------------------------------
def _attn_weights(cfg: ArchConfig) -> float:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    return 2 * d * h * hd + 2 * d * kv * hd


def _ffn_weights(cfg: ArchConfig) -> float:
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return 3 * cfg.d_model * cfg.d_ff
    if cfg.mlp_kind == "gelu":
        return 2 * cfg.d_model * cfg.d_ff
    return 0.0


def _moe_weights_per_token(cfg: ArchConfig) -> float:
    mc = cfg.moe
    d, fe = cfg.d_model, mc.d_ff_expert
    per_expert = 3 * d * fe
    return (mc.top_k + mc.num_shared) * per_expert + d * mc.num_experts


def _mamba_weights(cfg: ArchConfig) -> float:
    d, di, n, r, ck = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.dt_rank, cfg.ssm_conv)
    return (2 * d * di + di * ck + di * (r + 2 * n) + r * di + di * d)


def _mlstm_weights(cfg: ArchConfig) -> float:
    d = cfg.d_model
    di = cfg.mlstm_proj * d
    return 4 * d * di + 2 * d * cfg.num_heads


def _slstm_weights(cfg: ArchConfig) -> float:
    d = cfg.d_model
    hd = d // cfg.num_heads
    return 5 * d * d + 4 * d * hd


_MIXER_WEIGHTS = {
    "attn": _attn_weights, "attn_local": _attn_weights,
    "enc_attn": _attn_weights,
    "mamba": _mamba_weights, "mlstm": _mlstm_weights,
    "slstm": _slstm_weights,
}


def _ctx_sum(t: int, window=None, impl: str = "dense") -> float:
    """Σ_t effective-context for the attention score matmuls over T.

    impl='dense':  baseline _sdpa/_sdpa_online — FULL (t,s) score matrix,
                   masked entries still burn MXU ⇒ Σ = t².
    impl='banded': _sdpa_banded for windowed layers (Σ ≈ t·(chunk+w)),
                   causal layers still dense.
    impl='flash':  block-skipping flash kernel — causal Σ = t(t+1)/2,
                   windowed capped at the band.
    """
    if impl == "dense" or (impl == "banded" and window is None):
        return float(t) * t
    if impl == "banded":
        from repro.models.layers import ONLINE_ATTN_CHUNK
        return float(t) * min(t, window + ONLINE_ATTN_CHUNK)
    if window is None or window >= t:
        return t * (t + 1) / 2
    w = window
    return w * (w + 1) / 2 + (t - w) * w


def _block_flops_per_seq(cfg: ArchConfig, kind: str, is_moe: bool,
                         b: int, t: int, mode: str, s_ctx: int,
                         attn_impl: str = "dense") -> float:
    """Forward flops of ONE block over a (b, t) slab.

    mode: 'seq' (train/prefill over t tokens) or 'decode' (t==1 against
    an s_ctx-deep history)."""
    d = cfg.d_model
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    tokens = b * t
    fl = 0.0
    if kind in ("attn", "attn_local", "enc_attn", "dec_attn"):
        fl += 2 * tokens * _attn_weights(cfg)
        window = cfg.window if kind == "attn_local" else None
        if mode == "seq":
            csum = (t * t if kind == "enc_attn"
                    else _ctx_sum(t, window, attn_impl))
            fl += 4 * b * csum * h * hd
        else:
            ctx = min(window, s_ctx) if window else s_ctx
            fl += 4 * b * ctx * h * hd
        if kind == "dec_attn":   # cross attention (xq/xo per dec token,
            fl += 2 * tokens * (d * h * hd + h * hd * d)
            fl += 4 * tokens * cfg.frontend_len * h * hd   # scores vs enc
            if mode == "seq":    # xk/xv computed once per sequence
                fl += 2 * b * cfg.frontend_len * (2 * d * kv * hd)
    elif kind == "mamba":
        fl += 2 * tokens * _mamba_weights(cfg)
        fl += 9 * tokens * cfg.d_inner * cfg.ssm_state      # selective scan
    elif kind == "mlstm":
        di = cfg.mlstm_proj * d
        fl += 2 * tokens * _mlstm_weights(cfg)
        if mode == "seq":
            from repro.models.ssm import MLSTM_CHUNK, MLSTM_CHUNK_THRESHOLD
            if t > MLSTM_CHUNK_THRESHOLD:
                # chunkwise form: intra-chunk t·C scores + inter-chunk
                # state read/write per chunk
                ctx = t * MLSTM_CHUNK + (t // MLSTM_CHUNK) * 3 * (
                    di // cfg.num_heads)
            else:
                ctx = _ctx_sum(t, None, attn_impl)
            fl += 4 * b * ctx * di
        else:
            fl += 6 * b * di * (di // cfg.num_heads)        # state update
    elif kind == "slstm":
        fl += 2 * tokens * _slstm_weights(cfg)
        fl += 12 * tokens * d                               # gates/state
    if cfg.block_has_mlp(kind):
        if is_moe:
            fl += 2 * tokens * _moe_weights_per_token(cfg)
        else:
            fl += 2 * tokens * _ffn_weights(cfg)
    return fl


def flops_forward(cfg: ArchConfig, b: int, t: int, mode: str = "seq",
                  s_ctx: int = 0, attn_impl: str = "dense") -> float:
    """Global forward flops of one step (train fwd / prefill / decode)."""
    fl = 0.0
    for i, kind in enumerate(cfg.prefix):
        fl += _block_flops_per_seq(cfg, kind, cfg.slot_is_moe(i, True),
                                   b, t, mode, s_ctx, attn_impl)
    for j, kind in enumerate(cfg.period):
        fl += cfg.n_periods * _block_flops_per_seq(
            cfg, kind, cfg.slot_is_moe(j, False), b, t, mode, s_ctx,
            attn_impl)
    if cfg.encdec and mode != "decode":
        f = cfg.frontend_len
        fl += cfg.enc_layers * _block_flops_per_seq(
            cfg, "enc_attn", False, b, f, "seq", 0, attn_impl)
    fl += 2 * b * t * cfg.d_model * cfg.vocab_size          # lm head
    return fl


# ----------------------------------------------------------------------
def _params_bytes(cfg: ArchConfig, touched_experts_per_layer=None) -> float:
    """Weight bytes touched in one pass (MoE: only routed experts)."""
    from repro.models.transformer import LM
    counts = LM(cfg).param_counts()
    total_b = counts["total"] * BF16
    if cfg.moe is None or touched_experts_per_layer is None:
        return total_b
    mc = cfg.moe
    frac = min(1.0, touched_experts_per_layer / mc.num_experts)
    # split expert vs non-expert params analytically
    n_moe_layers = sum(
        1 for j in range(len(cfg.period)) if cfg.slot_is_moe(j, False)
    ) * cfg.n_periods + sum(
        1 for i in range(len(cfg.prefix)) if cfg.slot_is_moe(i, True))
    expert_params = (n_moe_layers * mc.num_experts * 3
                     * cfg.d_model * mc.d_ff_expert)
    rest = counts["total"] - expert_params
    return (rest + expert_params * frac) * BF16


def _cache_bytes(cfg: ArchConfig, b: int, s: int) -> float:
    """Decode-cache bytes read per step (KV up to pos + states)."""
    by = 0.0
    all_kinds = ([(k, True) for k in cfg.prefix]
                 + [(k, False) for k in cfg.period] * cfg.n_periods)
    for kind, _ in all_kinds:
        if kind in ("attn", "attn_local", "dec_attn"):
            window = cfg.window if kind == "attn_local" else None
            ctx = min(window, s) if window else s
            by += 2 * b * ctx * cfg.num_kv_heads * cfg.hd * BF16
            if kind == "dec_attn":
                by += 2 * b * cfg.frontend_len * cfg.num_kv_heads \
                    * cfg.hd * BF16
        elif kind == "mamba":
            by += b * cfg.d_inner * (cfg.ssm_state * F32 * 2
                                     + cfg.ssm_conv * F32)
        elif kind == "mlstm":
            di = cfg.mlstm_proj * cfg.d_model
            hd = di // cfg.num_heads
            by += 2 * b * di * hd * F32
        elif kind == "slstm":
            by += 8 * b * cfg.d_model * F32
    return by


# activation-traffic constant: ~12 intermediate (B,T,d) tensors read+
# written per block in a fused TPU program (norms, projections, residual,
# gate products) — a calibrated engineering estimate, documented in
# EXPERIMENTS.md §Roofline.
C_ACT = 24


def bytes_step(cfg: ArchConfig, b: int, t: int, mode: str,
               s_ctx: int = 0, moment_bytes: int = BF16) -> Dict[str, float]:
    """Global HBM bytes of one step, split by source (see module doc).

    Returns {"total", "weights", "cache", "act", "logits", "opt"}."""
    nl = cfg.num_layers + (cfg.enc_layers if cfg.encdec else 0)
    d = cfg.d_model
    act = C_ACT * b * t * d * BF16 * nl
    logits = 3 * b * t * cfg.vocab_size * BF16 if mode != "decode" else \
        3 * b * cfg.vocab_size * BF16
    if mode == "train":
        p = _params_bytes(cfg)                 # all experts get grads
        n_params = p / BF16
        weights = (3 * p if cfg.remat == "full" else 2 * p) + p  # + grads
        opt = 4 * n_params * moment_bytes + p  # m,v R/W + param write
        scores = _scores_bytes(cfg, b, t)
        return {"total": weights + opt + 3 * act + logits + scores,
                "weights": weights, "cache": 0.0, "act": 3 * act + scores,
                "logits": logits, "opt": opt}
    if mode == "prefill":
        p = _params_bytes(cfg)
        cache_w = _cache_bytes(cfg, b, t)      # write K/V once
        sc = _scores_bytes(cfg, b, t)
        return {"total": p + act + logits + cache_w + sc,
                "weights": p, "cache": cache_w, "act": act + sc,
                "logits": logits, "opt": 0.0}
    # decode
    touched = (b * cfg.moe.top_k + cfg.moe.num_shared) if cfg.moe else None
    p = _params_bytes(cfg, touched_experts_per_layer=touched)
    cache = _cache_bytes(cfg, b, s_ctx)
    act_d = C_ACT * b * d * BF16 * nl
    return {"total": p + cache + act_d + logits,
            "weights": p, "cache": cache, "act": act_d,
            "logits": logits, "opt": 0.0}


def _scores_bytes(cfg: ArchConfig, b: int, t: int) -> float:
    """Attention-score traffic for seq modes (online-softmax tiles: the
    (t, chunk) tiles stay in VMEM — count K/V re-reads per chunk pass)."""
    by = 0.0
    for kind in list(cfg.prefix) + list(cfg.period) * cfg.n_periods:
        if kind in ("attn", "attn_local", "dec_attn", "enc_attn"):
            by += 2 * b * t * cfg.num_kv_heads * cfg.hd * BF16
    return by


# 2:4-packed weights: values at half count + int8 indices (2-bit on TPU)
SPARSE_24_WEIGHT_FACTOR = 0.5625


def analytic_cell(cfg: ArchConfig, shape_kind: str, b: int, t: int,
                  attn_impl: str = "dense",
                  sparse_24: bool = False) -> Dict[str, float]:
    """All analytic numbers for a dry-run cell (GLOBAL totals).

    ``sparse_24``: serve the paper's 2:4-pruned weights through the
    nm_spmm packed format — weight HBM traffic × 0.5625."""
    if shape_kind == "train":
        fwd = flops_forward(cfg, b, t, "seq", attn_impl=attn_impl)
        mult = 4.0 if cfg.remat == "full" else 3.0
        by = bytes_step(cfg, b, t, "train")
        return {"flops": mult * fwd, "bytes": by["total"],
                "bytes_split": by}
    if shape_kind == "prefill":
        by = bytes_step(cfg, b, t, "prefill")
        total = by["total"]
        if sparse_24:
            total -= by["weights"] * (1 - SPARSE_24_WEIGHT_FACTOR)
        return {"flops": flops_forward(cfg, b, t, "seq",
                                       attn_impl=attn_impl),
                "bytes": total, "bytes_split": by}
    by = bytes_step(cfg, b, 1, "decode", s_ctx=t)
    total = by["total"]
    if sparse_24:
        total -= by["weights"] * (1 - SPARSE_24_WEIGHT_FACTOR)
    return {"flops": flops_forward(cfg, b, 1, "decode", s_ctx=t),
            "bytes": total, "bytes_split": by}
