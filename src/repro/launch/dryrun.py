import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent without
hardware: ``jax.jit(step, in_shardings=…).lower(**ShapeDtypeStructs)``
must partition (sharding propagation succeeds), ``.compile()`` must
produce an SPMD executable (collectives legal, memory analyzable), and we
record ``memory_analysis()`` / ``cost_analysis()`` + the HLO collective
byte census for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod both] \
      --out experiments/dryrun

The XLA_FLAGS line above MUST run before any jax import (device count is
locked at backend init) — keep it the first statement of this module, and
never set it globally (smoke tests/benches want 1 device).
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.dist.compat import cost_analysis_dict
from repro.dist.sharding import (
    batch_sharding,
    named_shardings,
    param_specs,
    replicated,
)
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.models.transformer import LM
from repro.optim import AdamW
from repro.train import make_train_step
from repro.utils.hlo import collective_bytes

# TPU v5e per-chip constants (roofline)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


@dataclasses.dataclass(frozen=True)
class OptFlags:
    """§Perf hillclimb switches (all off = paper-faithful baseline)."""
    fsdp_embed_fix: bool = False   # iter 1: no FSDP on embed/lm-head
    serve_resident: bool = False   # iter 2a: no FSDP for prefill/decode
    serve_moe_2d: bool = False     # iter 2b: MoE experts model×data 2-D
    banded_local: bool = False     # iter 3a: banded sliding-window attn
    flash_acct: bool = False       # iter 3b: flash-kernel flop accounting
    seq_par_attn: bool = False     # iter 4: sequence-parallel long attn
    sparse_24: bool = False        # iter 5: 2:4-packed serving weights
    seq_cache: bool = False        # iter 6: S-sharded decode KV cache

    @staticmethod
    def level(n: int) -> "OptFlags":
        """1: head/embed fix · 2: +resident serving · 3: +banded/flash
        attention · 4: +sequence-parallel attention · 5: +S-sharded
        decode cache · 6: +2:4-packed serving weights (the paper's
        technique applied). serve_moe_2d is cell-specific (kimi HBM
        feasibility) and set explicitly."""
        return OptFlags(
            fsdp_embed_fix=n >= 1,
            serve_resident=n >= 2,
            banded_local=n >= 3, flash_acct=n >= 3,
            seq_par_attn=n >= 4,
            seq_cache=n >= 5,
            sparse_24=n >= 6)


def depth_variant(cfg, k: int):
    """Same arch at depth k periods, scan disabled — used to extrapolate
    HLO costs that XLA's CPU cost model counts once per while body
    (cost(depth n) = A + n·B; two compiles solve for A, B)."""
    kw = dict(num_layers=len(cfg.prefix) + k * len(cfg.period),
              scan_layers=False)
    if cfg.encdec:
        kw["enc_layers"] = k
    return dataclasses.replace(cfg, **kw)


def build_lowerable(arch_id: str, shape: str, mesh, *,
                    fsdp: bool = True, remat: Optional[str] = None,
                    depth_k: Optional[int] = None,
                    cfg_override=None, opt: Optional[OptFlags] = None):
    """Returns (fn, args, in_shardings) ready for jit().lower()."""
    from repro.dist.sharding import FSDP_EXCLUDE_EMBED
    from repro.models import layers as layers_lib

    opt = opt or OptFlags()
    cfg = cfg_override or cfglib.get_config(arch_id)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if depth_k is not None:
        cfg = depth_variant(cfg, depth_k)
    from repro.models import moe as moe_lib
    layers_lib.BANDED_LOCAL_ATTN = opt.banded_local
    layers_lib.SEQ_PAR_ATTN = opt.seq_par_attn
    layers_lib.HEAD_GATHER = opt.fsdp_embed_fix
    moe_lib.FORCE_PLAIN_GSPMD = opt.serve_moe_2d
    model = LM(cfg)
    sp = cfglib.SHAPES[shape]
    dp = dp_axes_of(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    specs = cfglib.input_specs(cfg, shape)
    params = model.init_shapes()
    if sp.kind != "train" and opt.serve_resident:
        fsdp = False
    fsdp_axes = dp if fsdp else ()
    pspec = param_specs(
        params, mesh, fsdp_axes=fsdp_axes,
        fsdp_exclude=FSDP_EXCLUDE_EMBED if opt.fsdp_embed_fix else (),
        serve_moe=(sp.kind != "train" and opt.serve_moe_2d))
    psh = named_shardings(mesh, pspec)
    # batch < #data-shards (long_500k): replicate batch, shard the cache's
    # sequence dim over data instead (context parallelism)
    seq_shard = sp.global_batch % dp_total != 0
    bsh = replicated(mesh) if seq_shard else batch_sharding(mesh, dp)
    rep = replicated(mesh)

    if sp.kind == "train":
        optimizer = AdamW(lr=1e-4, moment_dtype="bfloat16")
        step_fn = make_train_step(model, optimizer)
        opt_state = jax.eval_shape(optimizer.init, params)
        ef = jax.ShapeDtypeStruct((), jnp.float32)
        batch = {k: specs[k] for k in specs}
        osh = type(opt_state)(rep, psh, psh)
        args = (params, opt_state, ef, batch)
        shardings = (psh, osh, rep,
                     {k: bsh for k in batch})
        return step_fn, args, shardings, model

    if sp.kind == "prefill":
        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)
        cache = specs["cache"]
        csh = named_shardings(mesh, model.cache_specs(mesh, dp, seq_shard=seq_shard,
                                          prefer_seq=opt.seq_cache))
        batch = {k: v for k, v in specs.items() if k != "cache"}
        args = (params, batch, cache)
        shardings = (psh, {k: bsh for k in batch}, csh)
        return prefill_step, args, shardings, model

    # decode
    def serve_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)
    csh = named_shardings(mesh, model.cache_specs(mesh, dp, seq_shard=seq_shard,
                                      prefer_seq=opt.seq_cache))
    args = (params, specs["token"], specs["cache"], specs["pos"])
    shardings = (psh, bsh, csh, rep)
    return serve_step, args, shardings, model


def _compile_cell(arch_id, shape, mesh, *, fsdp, depth_k=None,
                  cfg_override=None, opt=None):
    from repro.dist.api import use_mesh
    from repro.launch.mesh import dp_axes_of as _dp

    fn, args, shardings, model = build_lowerable(
        arch_id, shape, mesh, fsdp=fsdp, depth_k=depth_k,
        cfg_override=cfg_override, opt=opt)
    with use_mesh(mesh, dp_axes=_dp(mesh)):
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        compiled = lowered.compile()
    return compiled, model


def _extrapolate(v1: float, v2: float, n: int) -> float:
    """cost(k) = A + k·B from k=1,2 → cost(n); clamped non-negative."""
    b = max(0.0, v2 - v1)
    a = max(0.0, v1 - b)
    return a + n * b


def run_cell(arch_id: str, shape: str, *, multi_pod: bool,
             fsdp: bool = True, verbose: bool = True,
             extra_tag: str = "", cfg_override=None,
             opt: Optional[OptFlags] = None) -> Dict[str, Any]:
    """Lower + compile one cell; return the §Roofline record.

    Compute & memory roofline terms come from launch.analytic (closed
    form — XLA's CPU cost model counts while bodies once, so raw HLO
    flops/bytes are kept as diagnostics only); the collective term is
    measured from the compiled HLO with scan-body collectives scaled by
    their statically-known trip counts (op_name loop-nesting metadata).
    """
    from repro.launch.analytic import analytic_cell

    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = f"{arch_id}×{shape}×{mesh_name}{extra_tag}"
    cfg = cfg_override or cfglib.get_config(arch_id)
    ok, reason = cfglib.shape_is_applicable(cfg, shape)
    if not ok:
        if verbose:
            print(f"[skip] {cell}: {reason}")
        return {"arch": arch_id, "shape": shape, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    opt = opt or OptFlags()
    t0 = time.monotonic()
    try:
        compiled, model = _compile_cell(
            arch_id, shape, mesh, fsdp=fsdp, cfg_override=cfg_override,
            opt=opt)
        t_compile = time.monotonic() - t0
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)

        # --- trip-count-scaled collective census --------------------------
        # Collectives inside scan bodies appear once in the HLO text; the
        # op_name metadata records the loop nesting, and we know every
        # loop's trip count statically: level 0 = the layer scan
        # (n_periods; the encoder scan in enc-dec archs has the same trip
        # count by construction), level 1 = the inner sequential scan
        # (online-attention KV chunks, or the sLSTM token recurrence).
        sp = cfglib.SHAPES[shape]
        n_per = max(1, cfg.n_periods)
        if "slstm" in cfg.period and sp.kind != "decode":
            inner = sp.seq_len                       # sLSTM token scan
        elif sp.kind == "prefill" and sp.seq_len > 8192:
            from repro.models.layers import ONLINE_ATTN_CHUNK
            inner = max(1, sp.seq_len // ONLINE_ATTN_CHUNK)
        else:
            inner = 1
        trips = (n_per, inner)
        coll = collective_bytes(compiled.as_text(), trip_counts=trips)
        coll_wire = coll.wire_bytes
        coll_total = coll.total_bytes
        coll_counts = coll.counts
        coll_op_bytes = dict(coll.operand_bytes)
        # raw HLO numbers (loop bodies counted ONCE — diagnostic only)
        flops_hlo = float(cost.get("flops", 0.0))
        bytes_hlo = float(cost.get("bytes accessed", 0.0))

        # --- analytic roofline terms -------------------------------------
        attn_impl = ("flash" if opt.flash_acct
                     else "banded" if opt.banded_local else "dense")
        ana = analytic_cell(cfg, sp.kind, sp.global_batch, sp.seq_len,
                            attn_impl=attn_impl, sparse_24=opt.sparse_24)
        flops_dev = ana["flops"] / chips
        bytes_dev = ana["bytes"] / chips
        t_compute = flops_dev / PEAK_FLOPS
        t_memory = bytes_dev / HBM_BW
        t_coll = coll_wire / ICI_BW
        dominant = max(
            (("compute", t_compute), ("memory", t_memory),
             ("collective", t_coll)), key=lambda kv: kv[1])[0]
        bound = max(t_compute, t_memory, t_coll)
        counts = model.param_counts()
        tokens = sp.global_batch * sp.seq_len if sp.kind == "train" else (
            sp.global_batch * (sp.seq_len if sp.kind == "prefill" else 1))
        mult = 6 if sp.kind == "train" else 2
        model_flops = mult * counts["active"] * tokens / chips
        rec = {
            "arch": arch_id, "shape": shape, "mesh": mesh_name,
            "status": "ok", "chips": chips,
            "compile_s": round(t_compile, 1),
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "flops_hlo_per_device": flops_hlo,
            "bytes_hlo_per_device": bytes_hlo,
            "collective_bytes_per_device": coll_total,
            "collective_wire_bytes": coll_wire,
            "collective_counts": coll_counts,
            "collective_op_bytes": coll_op_bytes,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "roofline_fraction": t_compute / bound if bound else None,
            "model_flops_per_device": model_flops,
            "useful_flop_ratio": (model_flops / flops_dev
                                  if flops_dev else None),
            "peak_memory_per_device": int(
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "params_total": counts["total"],
            "params_active": counts["active"],
            "opt": dataclasses.asdict(opt),
        }
        if verbose:
            print(f"[ok]  {cell}: compile {t_compile:.0f}s | "
                  f"{flops_dev/1e9:.1f} GF/dev {bytes_dev/1e6:.1f} MB/dev "
                  f"coll {coll_wire/1e6:.1f} MB/dev → {dominant}-bound "
                  f"(c={t_compute*1e3:.2f}ms m={t_memory*1e3:.2f}ms "
                  f"x={t_coll*1e3:.2f}ms) roofline={rec['roofline_fraction']:.2f}")
        return rec
    except Exception as e:  # a failure here is a bug in the system
        if verbose:
            print(f"[FAIL] {cell}: {e}")
            traceback.print_exc()
        return {"arch": arch_id, "shape": shape, "mesh": mesh_name,
                "status": "failed", "error": f"{type(e).__name__}: {e}"}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("off", "on", "both"),
                    default="off")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--opt-level", type=int, default=0,
                    help="§Perf hillclimb level (0=baseline)")
    ap.add_argument("--out", default=None, help="JSONL output path")
    args = ap.parse_args()

    arch_ids = [a for a in cfglib.ARCH_IDS if a != "paper_tiny_lm"] \
        if (args.all or args.arch is None) else [cfglib.canonical(args.arch)]
    shapes = list(cfglib.SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]

    records = []
    for arch in arch_ids:
        for shape in shapes:
            for mp in pods:
                rec = run_cell(arch, shape, multi_pod=mp,
                               fsdp=not args.no_fsdp,
                               opt=OptFlags.level(args.opt_level))
                records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    failed = [r for r in records if r["status"] == "failed"]
    print(f"\n{len(records)} cells: "
          f"{sum(r['status'] == 'ok' for r in records)} ok, "
          f"{sum(r['status'] == 'skipped' for r in records)} skipped, "
          f"{len(failed)} FAILED")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
