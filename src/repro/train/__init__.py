"""Fault-tolerant distributed training loop."""

from repro.train.loop import (
    StragglerError,
    TrainConfig,
    Trainer,
    make_train_step,
)

__all__ = ["Trainer", "TrainConfig", "make_train_step", "StragglerError"]
