"""Fault-tolerant training loop.

Production behaviors (all unit-tested):
  - step-indexed deterministic data (resume = continue the counter);
  - atomic checkpoints every ``ckpt_every`` steps, resume from the
    newest *valid* one (hash-verified; walks past torn writes);
  - elastic restore: checkpoint leaves are host numpy → re-placed under
    the *current* mesh's shardings, so restarting on a different mesh
    shape (chips died, pod removed) just works;
  - straggler watchdog: per-step wall clock vs a running median; slow
    steps are logged + counted, and after ``straggler_abort`` consecutive
    hits the loop checkpoints and raises (the cluster launcher restarts
    elsewhere — standard TPU practice, simulated in tests);
  - microbatch gradient accumulation via lax.scan (keeps the HLO one
    microbatch deep) with optional int8 error-feedback gradient
    compression on the accumulated grads;
  - loss/metric NaN guard: a non-finite loss step is skipped (params
    untouched) and counted — one bad host can't poison the run.

The step function is pjit'd with explicit param/batch shardings from
dist.sharding; XLA inserts the DP gradient psum + TP collectives.  The
mesh comes from the constructor or, when omitted, from the active
``repro.dist`` context (``use_mesh``) — with neither, everything runs
single-device.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import statistics
import time
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointStore
from repro.models.transformer import LM
from repro.optim import AdamW, OptState, ef_init, ef_quantize

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    global_batch: int = 8
    seq_len: int = 64
    ckpt_every: int = 20
    keep_ckpts: int = 3
    out_dir: str = "/tmp/repro_train"
    microbatches: int = 1            # grad-accumulation chunks
    grad_compression: bool = False   # int8 EF on accumulated grads
    straggler_factor: float = 5.0    # step > factor×median ⇒ straggler
    straggler_abort: int = 3         # consecutive stragglers ⇒ abort
    log_every: int = 10


def make_train_step(
    model: LM,
    opt: AdamW,
    microbatches: int = 1,
    grad_compression: bool = False,
) -> Callable:
    """(params, opt_state, ef_state, batch) → (params, opt_state,
    ef_state, metrics).  Pure — jit/pjit it with the caller's shardings."""

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def split_micro(batch):
        return jax.tree.map(
            lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                *x.shape[1:]),
            batch)

    def step(params, opt_state: OptState, ef_state, batch):
        if microbatches > 1:
            micro = split_micro(batch)

            def accum(carry, mb):
                gsum, lsum = carry
                (loss, metrics), grads = grad_fn(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, grads)
                return (gsum, lsum + loss), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), metrics = jax.lax.scan(
                accum, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if grad_compression:
            grads, ef_state = ef_quantize(grads, ef_state)

        # NaN guard: skip the update (identity) when loss is non-finite.
        ok = jnp.isfinite(loss)
        new_params, new_opt, stats = opt.update(grads, opt_state, params)
        new_params = jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new_params, params)
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new_opt, opt_state)
        metrics = {**metrics, **stats, "loss": loss,
                   "skipped": (~ok).astype(jnp.float32)}
        return new_params, new_opt, ef_state, metrics

    return step


class StragglerError(RuntimeError):
    pass


class Trainer:
    def __init__(
        self,
        model: LM,
        opt: AdamW,
        pipeline,
        cfg: TrainConfig,
        mesh=None,
        fsdp_axes: Optional[Sequence[str]] = None,
    ):
        self.model = model
        self.opt = opt
        self.pipeline = pipeline
        self.cfg = cfg
        # None = unspecified (resolve from the context); an explicit ()
        # disables FSDP even under use_mesh (tensor-parallel only)
        if mesh is None:
            from repro.dist import current_ctx

            ctx = current_ctx()
            if ctx is not None:
                mesh = ctx.mesh
                if fsdp_axes is None:
                    fsdp_axes = ctx.dp_axes
        self.mesh = mesh
        self.fsdp_axes = tuple(fsdp_axes) if fsdp_axes is not None else ()
        self.store = CheckpointStore(cfg.out_dir, keep=cfg.keep_ckpts)
        self.metrics_path = os.path.join(cfg.out_dir, "metrics.jsonl")
        self.straggler_events = 0

        self._step_fn = jax.jit(make_train_step(
            model, opt, cfg.microbatches, cfg.grad_compression),
            donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.key(seed))
        if self.mesh is not None:
            from repro.dist.sharding import shard_params
            # head_dim: whole heads per model shard — the jax 0.4.x CPU
            # partitioner mis-executes rope/attention when the model
            # axis splits a head (ROADMAP; reproduced on the training
            # path by tests/test_train.py::test_mesh_headsplit_parity)
            params = shard_params(params, self.mesh, self.fsdp_axes,
                                  head_dim=self.model.cfg.hd)
        opt_state = self.opt.init(params)
        ef_state = (ef_init(params) if self.cfg.grad_compression
                    else jnp.zeros(()))
        return params, opt_state, ef_state

    def _state_template(self):
        params, opt_state, ef_state = jax.eval_shape(self.init_state)
        return {"params": params, "opt": opt_state, "ef": ef_state}

    def restore_or_init(self):
        """Returns (start_step, params, opt_state, ef_state)."""
        template = jax.tree.map(
            lambda s: np.zeros(s.shape, s.dtype), self._state_template())
        restored = self.store.restore(template)
        if restored is None:
            params, opt_state, ef_state = self.init_state()
            return 0, params, opt_state, ef_state
        step, tree, _ = restored
        log.info("restored checkpoint at step %d", step)
        params, opt_state, ef_state = (
            tree["params"], tuple(tree["opt"]), tree["ef"])
        opt_state = OptState(*opt_state)
        if self.mesh is not None:   # elastic: re-shard onto current mesh
            from repro.dist.sharding import param_shardings
            psh = param_shardings(params, self.mesh, self.fsdp_axes,
                                  head_dim=self.model.cfg.hd)
            params = jax.device_put(params, psh)
            opt_state = OptState(
                jax.device_put(opt_state.step),
                jax.device_put(opt_state.mu, psh),
                jax.device_put(opt_state.nu, psh),
            )
        else:
            params = jax.device_put(params)
            opt_state = jax.device_put(opt_state)
        ef_state = jax.device_put(ef_state)
        return step, params, opt_state, ef_state

    def _log_metrics(self, step: int, metrics: Dict[str, Any],
                     seconds: float) -> None:
        rec = {"step": step, "seconds": seconds}
        rec.update({k: float(jax.device_get(v)) for k, v in metrics.items()})
        with open(self.metrics_path, "a") as f:
            f.write(json.dumps(rec) + "\n")

    # ------------------------------------------------------------------
    def run(self, max_steps: Optional[int] = None):
        """Train until cfg.total_steps (resuming automatically)."""
        cfg = self.cfg
        start, params, opt_state, ef_state = self.restore_or_init()
        end = min(cfg.total_steps, start + (max_steps or cfg.total_steps))
        durations: list = []
        consecutive_stragglers = 0

        step = start
        while step < end:
            batch = self.pipeline.batch_at(step)
            t0 = time.monotonic()
            params, opt_state, ef_state, metrics = self._step_fn(
                params, opt_state, ef_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0

            # straggler watchdog
            if len(durations) >= 5:
                med = statistics.median(durations[-20:])
                if dt > cfg.straggler_factor * med:
                    self.straggler_events += 1
                    consecutive_stragglers += 1
                    log.warning(
                        "straggler step %d: %.3fs vs median %.3fs",
                        step, dt, med)
                    if consecutive_stragglers >= cfg.straggler_abort:
                        self.store.save(step + 1, {
                            "params": params, "opt": opt_state,
                            "ef": ef_state})
                        raise StragglerError(
                            f"{consecutive_stragglers} consecutive "
                            f"straggler steps at step {step}")
                else:
                    consecutive_stragglers = 0
            durations.append(dt)

            step += 1
            if step % cfg.log_every == 0 or step == end:
                self._log_metrics(step, metrics, dt)
            if step % cfg.ckpt_every == 0 or step == end:
                self.store.save(step, {
                    "params": params, "opt": opt_state, "ef": ef_state})

        return params, opt_state, {
            "steps": step - start,
            "straggler_events": self.straggler_events,
        }
