"""Shared utilities: pytree helpers, HLO collective parsing, logging."""

from repro.utils.trees import (
    tree_bytes,
    tree_count,
    tree_slice_layer,
    tree_stack,
    tree_unstack,
)
from repro.utils.hlo import collective_bytes, parse_collectives

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_slice_layer",
    "tree_stack",
    "tree_unstack",
    "collective_bytes",
    "parse_collectives",
]
