"""Parse collective ops + byte counts out of lowered/compiled HLO text.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
traffic, so the roofline's collective term is derived here by scanning the
module text for ``all-reduce`` / ``all-gather`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` ops and summing their operand sizes
(per the spec).  Works on both post-optimization HLO (``compiled.as_text()``)
and StableHLO (``lowered.as_text()``).

Conventions:
  - SPMD modules are per-device programs, so summed operand bytes are
    *per-device* bytes.  ``collective_bytes`` in the roofline is defined as
    global bytes = per-device bytes x chips, making the spec's
    ``collective_bytes / (chips x link_bw)`` come out as per-device bytes
    over per-device link bandwidth.
  - ``wire_bytes`` additionally applies the standard ring-cost multipliers
    (all-reduce 2(k-1)/k ~ 2x, others (k-1)/k ~ 1x) for a tighter estimate;
    both are reported.
  - async pairs (``all-reduce-start``/``-done``) are counted once (at start).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    # stablehlo spellings
    "i1": 1, "i8": 1, "i16": 2, "i32": 4, "i64": 8, "ui8": 1, "ui16": 2,
    "ui32": 4, "ui64": 8,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# ring-cost multiplier in units of operand bytes (k->inf limit)
_WIRE_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# hlo:  f32[128,256]{1,0}   |   bf16[4,8]
_HLO_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
# `%x = f32[256,4096]{1,0} all-reduce(%y), ...` — group(1) captures the
# RESULT type (post-opt HLO names operands, sizes live in the result
# type).  Result size == wire-relevant size for all-reduce / all-to-all /
# collective-permute / all-gather (the gathered output); reduce-scatter
# is undercounted by ~group size (XLA emits RS rarely in these modules —
# caveat recorded in EXPERIMENTS.md §Roofline).
_HLO_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
# stablehlo:  stablehlo.all_reduce ... : (tensor<512x1024xf32>, ...) -> ...
_SHLO_OP_RE = re.compile(
    r"(?:stablehlo|mhlo)\.(all_reduce|all_gather|reduce_scatter|all_to_all|"
    r"collective_permute|collective_broadcast)"
)
_SHLO_TENSOR_RE = re.compile(r"tensor<([0-9x]*)x?([a-z0-9]+)>")


@dataclasses.dataclass
class CollectiveStats:
    """Aggregated per-opcode collective statistics for one module."""

    counts: Dict[str, int]
    operand_bytes: Dict[str, int]   # per-device bytes by opcode

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())

    @property
    def total_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def wire_bytes(self) -> float:
        return sum(
            _WIRE_MULT.get(op, 1.0) * b for op, b in self.operand_bytes.items()
        )


def _type_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype)
    if size is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            d = d.strip()
            if d:
                n *= int(d)
    return n * size


def _shlo_tensor_bytes(shape_part: str, dtype: str) -> int:
    size = _DTYPE_BYTES.get(dtype)
    if size is None:
        return 0
    n = 1
    if shape_part:
        for d in shape_part.split("x"):
            if d:
                n *= int(d)
    return n * size


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _loop_depth(line: str) -> int:
    """How many nested scan/while bodies the op executes inside — each
    lax.scan level contributes one 'while/body' segment to the jax
    op_name metadata.  XLA emits (and costs) loop bodies once; the true
    per-step execution count is the product of the enclosing trip counts
    (launch/dryrun.py supplies them per cell)."""
    m = _OPNAME_RE.search(line)
    if not m:
        return 0
    return m.group(1).count("while/body")


def parse_collectives(hlo_text: str) -> List[dict]:
    """Record per collective op: {op, operand_bytes, loop_depth, line}."""
    records: List[dict] = []
    for line in hlo_text.splitlines():
        m = _HLO_OP_RE.search(line)
        if m:
            op = m.group(2)
            types = _HLO_TYPE_RE.findall(m.group(1))   # result type(s)
            obytes = sum(_type_bytes(dt, dims) for dt, dims in types)
            records.append({"op": op, "operand_bytes": obytes,
                            "loop_depth": _loop_depth(line),
                            "line": line.strip()})
            continue
        m = _SHLO_OP_RE.search(line)
        if m:
            op = m.group(1).replace("_", "-")
            tensors = _SHLO_TENSOR_RE.findall(line)
            if tensors:
                # first tensor(s) are operands; take the first (input) tensor
                shape, dt = tensors[0]
                obytes = _shlo_tensor_bytes(shape, dt)
            else:
                obytes = 0
            records.append({"op": op, "operand_bytes": obytes,
                            "loop_depth": _loop_depth(line),
                            "line": line.strip()})
    return records


def collective_bytes(hlo_text: str,
                     trip_counts: tuple = ()) -> CollectiveStats:
    """Aggregate per-device collective bytes by opcode.

    ``trip_counts``: execution multiplier per loop-nesting level — ops at
    loop_depth d are scaled by Π trip_counts[:d] (defaults: no scaling,
    matching raw single-execution HLO text).
    """
    counts: Dict[str, int] = defaultdict(int)
    obytes: Dict[str, int] = defaultdict(int)
    for rec in parse_collectives(hlo_text):
        mult = 1.0
        for lvl in range(min(rec["loop_depth"], len(trip_counts))):
            mult *= trip_counts[lvl]
        counts[rec["op"]] += max(1, round(mult))
        obytes[rec["op"]] += rec["operand_bytes"] * mult
    return CollectiveStats(counts=dict(counts), operand_bytes=dict(obytes))
