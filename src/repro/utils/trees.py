"""Pytree helpers used across the framework."""

from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree: Any) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes of a pytree (by leaf dtype)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def tree_stack(trees: Sequence[Any]) -> Any:
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: Any, n: int) -> List[Any]:
    """Inverse of tree_stack: split leading axis of every leaf."""
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_slice_layer(tree: Any, i) -> Any:
    """Select layer ``i`` from a stacked pytree (leaf[i])."""
    return jax.tree.map(lambda x: x[i], tree)
