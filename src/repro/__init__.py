"""repro: MRP post-training pruning framework (EMNLP 2024) in JAX.

Implements "Pruning Foundation Models for High Accuracy without Retraining"
(Zhao et al., EMNLP 2024 Findings) as a production-grade, multi-pod JAX
framework: the Multiple Removal Problem (MRP) closed-form pruning solutions
(S/M for mask selection and compensation), SparseGPT/Wanda/Magnitude
baselines, a model zoo covering the 10 assigned architectures, distributed
pruning/training/serving, and Pallas TPU kernels for the hot paths.
"""

__version__ = "1.0.0"

from repro.core import (
    HessianAccumulator,
    PruneResult,
    PruningEngine,
    SparsitySpec,
    prune_matrix,
)
from repro.dist import current_ctx, use_mesh

__all__ = [
    "HessianAccumulator",
    "PruneResult",
    "PruningEngine",
    "SparsitySpec",
    "prune_matrix",
    "current_ctx",
    "use_mesh",
    "__version__",
]
