"""Observability: metrics registry + request tracing (docs/observability.md).

The unit components share is :class:`Obs` — a (registry, tracer, label)
bundle.  The launcher builds ONE enabled bundle and hands each replica
a labelled view (``obs.labelled("r1")``) so every serve series carries
a ``replica`` label while all replicas write to the same registry (this
is what makes the frontend's ``/stats`` aggregation race-free: worker
threads bump atomic registry counters instead of a per-engine dict the
server thread reads concurrently).  A bare engine or pool with no
bundle supplied builds its own metrics-only one; ``Obs.disabled()``
turns every call site into a no-op.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from .metrics import (COUNT_BUCKETS, LATENCY_BUCKETS, NULL_REGISTRY,
                      MetricsRegistry, exp_buckets)
from .trace import NULL_TRACER, Tracer

__all__ = [
    "Obs", "MetricsRegistry", "Tracer",
    "NULL_REGISTRY", "NULL_TRACER",
    "LATENCY_BUCKETS", "COUNT_BUCKETS", "exp_buckets",
]


@dataclass(frozen=True)
class Obs:
    """Shared observability bundle: one registry + tracer + the label
    identifying the emitting replica/component."""

    metrics: MetricsRegistry
    tracer: Tracer
    label: str = "r0"

    @classmethod
    def create(cls, metrics: bool = True, trace: bool = False,
               label: str = "r0") -> "Obs":
        return cls(metrics=MetricsRegistry(enabled=metrics),
                   tracer=Tracer(enabled=trace), label=label)

    @classmethod
    def disabled(cls) -> "Obs":
        return cls(metrics=NULL_REGISTRY, tracer=NULL_TRACER)

    @property
    def enabled(self) -> bool:
        return self.metrics.enabled or self.tracer.enabled

    def labelled(self, label: str) -> "Obs":
        """Same registry/tracer, different emitting label."""
        return replace(self, label=label)
