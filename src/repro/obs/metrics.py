"""Low-overhead metrics registry: counters, gauges, histograms.

One :class:`MetricsRegistry` per deployment unit (the launcher builds a
single registry shared by every serve replica; a bare engine or pool
builds a private one) holds *metric families* keyed by name.  A family
carries the Prometheus metadata (type, help, label names) and a child
per label-value combination; components bind children once at
construction and the hot path is a single ``inc``/``observe`` under one
registry-wide lock — cheap enough for the serve step loop, and safe for
the frontend's per-replica worker threads (the counters the racy
``/stats`` dict merge used to read now live here).

Conventions (docs/observability.md):

  - counters are monotonic and named ``*_total`` (``*_seconds_total``
    for accumulated wall time); per-run deltas are the CONSUMER's job
    (``ServeEngine.stats`` snapshots a base at ``generate()``);
  - gauges may be callback-backed (:meth:`Gauge.set_fn`) — evaluated at
    collection time, e.g. queue depth / free pages / replica health;
  - histograms use fixed buckets chosen at bind time
    (:func:`exp_buckets` for latencies) and expose approximate
    quantiles by linear interpolation within a bucket — the benchmark
    and ``/stats`` summaries derive TTFT/TPOT percentiles from them
    instead of keeping private timing lists.

``MetricsRegistry(enabled=False)`` is the zero-cost switch: every
``counter``/``gauge``/``histogram`` call returns a shared no-op family
whose methods do nothing, so instrumented code needs no ``if`` guards.
:func:`MetricsRegistry.render` emits the Prometheus text exposition
format (the frontend's ``GET /metrics``).
"""

from __future__ import annotations

import bisect
import threading
from typing import Callable, Dict, Iterable, List, Optional, Tuple

# default latency buckets: ~12% geometric spacing, 100µs .. ~80s.  The
# spacing bounds the interpolation error of quantile() to well under
# the bench gate's 20% threshold.
LATENCY_BUCKETS = None  # filled below (exp_buckets defined first)


def exp_buckets(start: float, factor: float, count: int) -> Tuple[float, ...]:
    """Geometric bucket upper bounds: start, start*factor, ..."""
    if start <= 0 or factor <= 1.0 or count < 1:
        raise ValueError("exp_buckets needs start > 0, factor > 1, "
                         "count >= 1")
    out, v = [], start
    for _ in range(count):
        # round to 4 significant digits: tidy ``le`` labels, and the
        # rounding error is far below the spacing itself
        out.append(float(f"{v:.4g}"))
        v *= factor
    return tuple(out)


LATENCY_BUCKETS = exp_buckets(1e-4, 1.12, 120)
# small-integer buckets (burst lengths, pages per event)
COUNT_BUCKETS = (1.0, 2.0, 3.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0,
                 48.0, 64.0, 96.0, 128.0)


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integral values without the .0."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _fmt_le(b: float) -> str:
    return "+Inf" if b == float("inf") else _fmt(b)


class Counter:
    """Monotonic counter child.  ``inc`` only ever adds >= 0."""

    __slots__ = ("_lock", "_v")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._v = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._v += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class Gauge:
    """Settable gauge child; ``set_fn`` makes it callback-backed
    (evaluated at collection time — queue depths, health bits)."""

    __slots__ = ("_lock", "_v", "_fn")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._v = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)
            self._fn = None

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._v += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    def set_fn(self, fn: Callable[[], float]) -> None:
        with self._lock:
            self._fn = fn

    @property
    def value(self) -> float:
        with self._lock:
            fn = self._fn
            if fn is None:
                return self._v
        try:                       # outside the lock: fn may take others
            return float(fn())
        except Exception:          # a dead callback must not kill /metrics
            return 0.0


class Histogram:
    """Fixed-bucket histogram child.

    ``buckets`` are the finite upper bounds (``le``); an implicit +Inf
    bucket catches the tail.  ``observe`` is one bisect + two adds.
    """

    __slots__ = ("_lock", "bounds", "_counts", "_sum", "_count")

    def __init__(self, lock: threading.Lock, bounds: Tuple[float, ...]):
        self._lock = lock
        self.bounds = bounds
        self._counts = [0] * (len(bounds) + 1)      # +Inf tail
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.bounds, v)      # le is inclusive
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def cumulative(self) -> List[int]:
        """Cumulative counts per bucket (Prometheus ``le`` semantics),
        +Inf last."""
        with self._lock:
            counts = list(self._counts)
        out, acc = [], 0
        for c in counts:
            acc += c
            out.append(acc)
        return out

    def quantile(self, q: float) -> float:
        """Approximate quantile by linear interpolation inside the
        bucket the rank lands in (histogram_quantile semantics).  The
        +Inf bucket clamps to the highest finite bound."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        cum = self.cumulative()
        total = cum[-1]
        if total == 0:
            return 0.0
        rank = q * total
        for i, c in enumerate(cum):
            if c >= rank:
                if i >= len(self.bounds):           # +Inf bucket
                    return self.bounds[-1] if self.bounds else 0.0
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                prev = cum[i - 1] if i > 0 else 0
                width = c - prev
                frac = (rank - prev) / width if width else 1.0
                return lo + (hi - lo) * frac
        return self.bounds[-1] if self.bounds else 0.0


def merge_histograms(fams) -> Optional[Histogram]:
    """Merge every child of the given histogram families (which must
    share one bucket layout) into a standalone :class:`Histogram` —
    one TTFT percentile across N replicas, or across N registries when
    replicas were built independently.  None when there are no
    children."""
    kids = [c for fam in fams for _, c in fam.children()]
    if not kids:
        return None
    merged = Histogram(threading.Lock(), kids[0].bounds)
    for k in kids:
        with k._lock:
            for i, c in enumerate(k._counts):
                merged._counts[i] += c
            merged._sum += k._sum
            merged._count += k._count
    return merged


_CHILD_TYPES = {"counter": Counter, "gauge": Gauge}


class MetricFamily:
    """One named metric: metadata + a child per label-value tuple.

    Unlabelled families delegate ``inc``/``set``/``observe``/``value``
    etc. to their single default child, so
    ``registry.counter("x_total").inc()`` just works.
    """

    def __init__(self, registry: "MetricsRegistry", name: str, kind: str,
                 help: str, labelnames: Tuple[str, ...],
                 buckets: Optional[Tuple[float, ...]] = None):
        self.registry = registry
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = labelnames
        self.buckets = buckets
        self._children: Dict[Tuple[str, ...], object] = {}

    def labels(self, **kv):
        """Get-or-create the child for one label-value combination."""
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.labelnames)}")
        key = tuple(str(kv[n]) for n in self.labelnames)
        with self.registry._lock:
            child = self._children.get(key)
            if child is None:
                if self.kind == "histogram":
                    child = Histogram(self.registry._vlock, self.buckets)
                else:
                    child = _CHILD_TYPES[self.kind](self.registry._vlock)
                self._children[key] = child
            return child

    def _default(self):
        return self.labels()

    # unlabelled convenience surface
    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def set(self, v: float) -> None:
        self._default().set(v)

    def set_fn(self, fn: Callable[[], float]) -> None:
        self._default().set_fn(fn)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    @property
    def value(self) -> float:
        return self._default().value

    def children(self) -> List[Tuple[Tuple[str, ...], object]]:
        with self.registry._lock:
            return sorted(self._children.items())

    # ------------------------------------------------ aggregate reads
    def total(self) -> float:
        """Sum of every child's value (counters/gauges)."""
        return sum(c.value for _, c in self.children())

    def quantile(self, q: float) -> float:
        """Histogram quantile over ALL children merged (e.g. one TTFT
        percentile across every replica)."""
        merged = merge_histograms([self])
        return merged.quantile(q) if merged is not None else 0.0

    def hist_count(self) -> int:
        return sum(c.count for _, c in self.children())

    def hist_sum(self) -> float:
        return sum(c.sum for _, c in self.children())


class _NullChild:
    """Shared do-nothing child for disabled registries."""

    __slots__ = ()

    def inc(self, amount: float = 1.0) -> None:
        pass

    def dec(self, amount: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def set_fn(self, fn) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    value = 0.0
    count = 0
    sum = 0.0
    mean = 0.0

    def quantile(self, q: float) -> float:
        return 0.0

    def cumulative(self):
        return []


class _NullFamily(_NullChild):
    """Disabled-mode family: ``labels()`` and every child method are
    free no-ops, so instrumented code runs unguarded at zero cost."""

    __slots__ = ()

    def labels(self, **kv):
        return self

    def children(self):
        return []

    def total(self) -> float:
        return 0.0

    def hist_count(self) -> int:
        return 0

    def hist_sum(self) -> float:
        return 0.0


_NULL_FAMILY = _NullFamily()


class MetricsRegistry:
    """Thread-safe named-metric registry with Prometheus text export.

    ``counter``/``gauge``/``histogram`` are get-or-create: binding the
    same name twice returns the same family (a kind or label-name
    mismatch raises).  ``enabled=False`` turns every bind into a shared
    no-op — the zero-overhead disabled mode.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()       # family/child creation
        self._vlock = threading.Lock()      # child value mutation
        self._families: Dict[str, MetricFamily] = {}

    # ------------------------------------------------------------- bind
    def _bind(self, name: str, kind: str, help: str,
              labels: Iterable[str],
              buckets: Optional[Tuple[float, ...]] = None) -> MetricFamily:
        if not self.enabled:
            return _NULL_FAMILY
        labelnames = tuple(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = MetricFamily(self, name, kind, help, labelnames,
                                   buckets=buckets)
                self._families[name] = fam
                return fam
        if fam.kind != kind:
            raise ValueError(f"metric {name!r} already bound as "
                             f"{fam.kind}, not {kind}")
        if fam.labelnames != labelnames:
            raise ValueError(f"metric {name!r} label names {fam.labelnames}"
                             f" != {labelnames}")
        return fam

    def counter(self, name: str, help: str = "",
                labels: Iterable[str] = ()) -> MetricFamily:
        return self._bind(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Iterable[str] = ()) -> MetricFamily:
        return self._bind(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Iterable[str] = (),
                  buckets: Tuple[float, ...] = LATENCY_BUCKETS
                  ) -> MetricFamily:
        return self._bind(name, "histogram", help, labels,
                          buckets=tuple(buckets))

    def get(self, name: str) -> Optional[MetricFamily]:
        with self._lock:
            return self._families.get(name)

    def families(self) -> List[MetricFamily]:
        with self._lock:
            return [self._families[n] for n in sorted(self._families)]

    def reset(self) -> None:
        """Zero every child (benchmarks isolating a measured run from
        its warmup; never called on a live server — Prometheus counters
        are meant to be monotonic)."""
        for fam in self.families():
            for _, child in fam.children():
                with self._vlock:
                    if isinstance(child, Histogram):
                        child._counts = [0] * len(child._counts)
                        child._sum = 0.0
                        child._count = 0
                    elif isinstance(child, Counter):
                        child._v = 0.0
                    # callback gauges keep their fn; plain gauges zero
                    elif child._fn is None:
                        child._v = 0.0

    # ----------------------------------------------------------- export
    def render(self) -> str:
        """Prometheus text exposition format (``GET /metrics``)."""
        if not self.enabled:
            return ""
        out: List[str] = []
        for fam in self.families():
            if fam.help:
                out.append(f"# HELP {fam.name} {fam.help}")
            out.append(f"# TYPE {fam.name} {fam.kind}")
            for values, child in fam.children():
                base = ",".join(f'{n}="{v}"'
                                for n, v in zip(fam.labelnames, values))
                if fam.kind == "histogram":
                    cum = child.cumulative()
                    bounds = (*child.bounds, float("inf"))
                    for b, c in zip(bounds, cum):
                        lab = (f'{base},le="{_fmt_le(b)}"' if base
                               else f'le="{_fmt_le(b)}"')
                        out.append(f"{fam.name}_bucket{{{lab}}} {c}")
                    suffix = f"{{{base}}}" if base else ""
                    out.append(f"{fam.name}_sum{suffix} {_fmt(child.sum)}")
                    out.append(f"{fam.name}_count{suffix} {child.count}")
                else:
                    suffix = f"{{{base}}}" if base else ""
                    out.append(f"{fam.name}{suffix} {_fmt(child.value)}")
        return "\n".join(out) + ("\n" if out else "")

    def collect(self) -> Dict[str, Dict]:
        """JSON-friendly snapshot (the trace-enriched ``/stats``)."""
        snap: Dict[str, Dict] = {}
        for fam in self.families():
            entry: Dict = {"type": fam.kind}
            samples: Dict[str, float] = {}
            for values, child in fam.children():
                key = ",".join(f"{n}={v}"
                               for n, v in zip(fam.labelnames, values)) or ""
                if fam.kind == "histogram":
                    samples[key] = {"count": child.count, "sum": child.sum,
                                    "p50": child.quantile(0.5),
                                    "p95": child.quantile(0.95)}
                else:
                    samples[key] = child.value
            entry["samples"] = samples
            snap[fam.name] = entry
        return snap


NULL_REGISTRY = MetricsRegistry(enabled=False)
