"""Request-lifecycle tracing with Chrome-trace JSON export.

A :class:`Tracer` accumulates events in the Chrome trace event format
(the ``{"traceEvents": [...]}`` JSON that chrome://tracing and
Perfetto load).  The serve stack emits:

  - one async span per request uid (``ph: b``/``e``, ``id: uid``)
    bracketing submit → retire;
  - retroactive complete spans (``ph: X``) for admit-queue wait,
    prefill chunks, and each decode burst's dispatch→readback window —
    recorded from ``(start, end)`` monotonic stamps after the fact so
    the hot loop never touches the tracer mid-flight;
  - instant events (``ph: i``) for preemption (swap vs recompute),
    CoW page copies, prefix attach, and swap-in/out.

Timestamps are microseconds relative to the tracer's construction,
taken from ``time.monotonic()`` — only deltas matter to the viewer.
``pid`` is always 0; ``tid`` names the emitting replica/component so
each one gets its own track.  A disabled tracer (``NULL_TRACER``)
no-ops every call, which keeps token streams bit-identical with
tracing on or off (pinned by tests/test_obs.py).
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional


class Tracer:
    """Thread-safe Chrome-trace event accumulator."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._t0 = time.monotonic()
        self._tids: Dict[str, int] = {}

    # ------------------------------------------------------------ time
    def now(self) -> float:
        """Monotonic stamp for later retroactive spans."""
        return time.monotonic()

    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids)
            self._tids[track] = tid
            self._events.append({
                "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                "args": {"name": track},
            })
        return tid

    def _emit(self, ev: dict, track: str) -> None:
        with self._lock:
            ev["pid"] = 0
            ev["tid"] = self._tid(track)
            self._events.append(ev)

    # ---------------------------------------------------------- events
    def complete(self, name: str, start: float, end: float, *,
                 track: str = "main",
                 args: Optional[dict] = None) -> None:
        """Retroactive span from two ``now()`` stamps (ph X)."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "X", "ts": self._us(start),
              "dur": max(0.0, (end - start) * 1e6)}
        if args:
            ev["args"] = args
        self._emit(ev, track)

    @contextmanager
    def span(self, name: str, *, track: str = "main",
             args: Optional[dict] = None):
        """Context-manager span; zero-cost when disabled."""
        if not self.enabled:
            yield
            return
        start = time.monotonic()
        try:
            yield
        finally:
            self.complete(name, start, time.monotonic(),
                          track=track, args=args)

    def instant(self, name: str, *, track: str = "main",
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "ts": self._us(time.monotonic()),
              "s": "t"}
        if args:
            ev["args"] = args
        self._emit(ev, track)

    def async_begin(self, name: str, uid: int, *, track: str = "main",
                    args: Optional[dict] = None) -> None:
        """Open the per-request lifecycle span (ph b, id=uid)."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "b", "cat": "request", "id": int(uid),
              "ts": self._us(time.monotonic())}
        if args:
            ev["args"] = args
        self._emit(ev, track)

    def async_end(self, name: str, uid: int, *, track: str = "main",
                  args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "ph": "e", "cat": "request", "id": int(uid),
              "ts": self._us(time.monotonic())}
        if args:
            ev["args"] = args
        self._emit(ev, track)

    # --------------------------------------------------------- readout
    def events(self, name: Optional[str] = None,
               ph: Optional[str] = None) -> List[dict]:
        """Snapshot of recorded events, optionally filtered (tests)."""
        with self._lock:
            evs = list(self._events)
        if name is not None:
            evs = [e for e in evs if e.get("name") == name]
        if ph is not None:
            evs = [e for e in evs if e.get("ph") == ph]
        return evs

    def export(self, path: str) -> int:
        """Write Chrome-trace JSON; returns the number of events."""
        with self._lock:
            evs = list(self._events)
        with open(path, "w") as f:
            json.dump({"traceEvents": evs,
                       "displayTimeUnit": "ms"}, f)
        return len(evs)

    def clear(self) -> None:
        with self._lock:
            self._events = [e for e in self._events
                            if e.get("ph") == "M"]


NULL_TRACER = Tracer(enabled=False)
