"""Checkpoint store: atomicity, hashes, retention, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ckpt import CheckpointStore, load_pytree, save_pytree


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    path = str(tmp_path / "ck")
    save_pytree(path, t, extra={"step": 7})
    loaded, extra = load_pytree(path, t)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hash_detects_corruption(tmp_path):
    path = str(tmp_path / "ck")
    save_pytree(path, _tree())
    with open(os.path.join(path, "arrays.npz"), "r+b") as f:
        f.seek(50)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        load_pytree(path, _tree())


def test_shape_mismatch_rejected(tmp_path):
    path = str(tmp_path / "ck")
    save_pytree(path, _tree())
    bad = _tree()
    bad["a"] = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        load_pytree(path, bad)


def test_store_retention_and_latest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (5, 10, 15, 20):
        store.save(s, _tree(s))
    assert store.list_steps() == [15, 20]
    assert store.latest_step() == 20
    got = store.restore(_tree())
    assert got is not None and got[0] == 20


def test_store_walks_past_corrupt(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=5)
    store.save(1, _tree(1))
    store.save(2, _tree(2))
    with open(str(tmp_path / "step_00000002/arrays.npz"), "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00\x00")
    step, tree, _ = store.restore(_tree())
    assert step == 1


def test_elastic_restore_different_sharding(tmp_path):
    """Save under one sharding, restore under another mesh/sharding —
    values identical (the trainer's elastic-restart path)."""
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    t = _tree()
    t_sharded = jax.device_put(
        t, NamedSharding(mesh1, P()))
    path = str(tmp_path / "ck")
    save_pytree(path, t_sharded)

    mesh2 = jax.make_mesh((1,), ("x",))
    loaded, _ = load_pytree(path, t)
    placed = jax.device_put(loaded, NamedSharding(mesh2, P()))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(placed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_partial_visible(tmp_path):
    """A failed save never leaves a readable-but-wrong checkpoint."""
    store = CheckpointStore(str(tmp_path), keep=3)
    store.save(1, _tree(1))
    # simulate a crash mid-save: a stale tmp dir lying around
    os.makedirs(str(tmp_path / "step_00000002.tmp-9999"), exist_ok=True)
    assert store.latest_step() == 1
    got = store.restore(_tree())
    assert got[0] == 1
