"""Algorithm 1 (core.pruner): methods, sparsity exactness, orderings."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import random_psd_hessian
from repro.core import masks as masks_lib
from repro.core.pruner import METHODS, prune_matrix, reconstruction_error
from repro.core.sparsity import SparsitySpec


@pytest.fixture(scope="module")
def problem():
    key = jax.random.key(0)
    n, m = 32, 128
    w = jax.random.normal(key, (n, m)) * (
        1.0 + jnp.arange(m)[None, :] / m)     # mild column structure
    h = random_psd_hessian(jax.random.key(1), m)
    return w, h


@pytest.mark.parametrize("method", METHODS)
def test_nm_sparsity_exact(problem, method):
    w, h = problem
    res = prune_matrix(w, h, "2:4", method=method, blocksize=64)
    assert masks_lib.validate_nm(np.asarray(res.mask), 2, 4)
    assert bool(jnp.all(jnp.where(res.mask, res.w, 0.0) == 0.0))
    assert abs(res.sparsity - 0.5) < 1e-6


@pytest.mark.parametrize("method", ["magnitude", "wanda", "SS", "SM"])
def test_unstructured_sparsity_exact(problem, method):
    w, h = problem
    res = prune_matrix(w, h, "0.5", method=method, blocksize=64)
    n, m = w.shape
    assert int(np.asarray(res.mask).sum()) == pytest.approx(
        n * m // 2, abs=n)  # per-block rounding
    assert bool(jnp.all(jnp.where(res.mask, res.w, 0.0) == 0.0))


def test_m_mask_rejected_for_unstructured(problem):
    w, h = problem
    with pytest.raises(ValueError):
        prune_matrix(w, h, "0.5", method="MM")


def test_reconstruction_orderings(problem):
    """The paper's central claim at layer level:
    recon(SM) ≤ recon(SS) and recon(MM) ≤ recon(MS); compensated methods
    beat score-only baselines."""
    w, h = problem
    errs = {}
    for method in METHODS:
        res = prune_matrix(w, h, "2:4", method=method, blocksize=64)
        errs[method] = reconstruction_error(w, res.w, h)
    assert errs["SM"] <= errs["SS"] * 1.01
    assert errs["MM"] <= errs["MS"] * 1.01
    assert errs["SM"] <= errs["wanda"]
    assert errs["SM"] <= errs["magnitude"]
    assert errs["SS"] <= errs["magnitude"]


def test_unstructured_sm_beats_ss(problem):
    w, h = problem
    e = {}
    for method in ("SS", "SM"):
        res = prune_matrix(w, h, "0.5", method=method, blocksize=32)
        e[method] = reconstruction_error(w, res.w, h)
    assert e["SM"] <= e["SS"] * 1.01


def test_blocksize_all_vs_blocks(problem):
    """S=all (one block) must also satisfy SM ≤ SS; and both blockings
    produce valid N:M masks."""
    w, h = problem
    m = w.shape[1]
    for bs in (32, m):
        r_ss = prune_matrix(w, h, "2:4", method="SS", blocksize=bs)
        r_sm = prune_matrix(w, h, "2:4", method="SM", blocksize=bs)
        assert reconstruction_error(w, r_sm.w, h) <= \
            reconstruction_error(w, r_ss.w, h) * 1.01


def test_row_balanced_traceable(problem):
    """row_balanced unstructured pruning must be jit-able (static shapes,
    no host sync) — the distributed row-parallel path depends on it."""
    w, h = problem

    @jax.jit
    def run(w, h):
        res = prune_matrix(w, h, SparsitySpec.parse("0.5"), method="SM",
                           blocksize=64, row_balanced=True)
        return res.w, res.mask

    w_new, mask = run(w, h)
    assert (np.asarray(mask).sum(1) == w.shape[1] // 2).all()
    assert bool(jnp.all(jnp.where(mask, w_new, 0.0) == 0.0))


def test_sm_compensation_updates_left_blocks(problem):
    """SparseGPT freezes columns left of the current block; our SM must
    keep refining them (the paper's fix). Verify some weight in block 0
    changes again while pruning block 1."""
    w, h = problem
    res1 = prune_matrix(w, h, "2:4", method="SM", blocksize=64)
    # prune only the first 64 columns (one block) by slicing: first-block
    # compensation in isolation
    res_first = prune_matrix(w[:, :128], h[:128, :128], "2:4", method="SM",
                             blocksize=128)
    # the first block's unpruned weights in the full run differ from the
    # isolated run — proof the later block's solve updated them again
    m0 = ~np.asarray(res1.mask)[:, :64]
    a = np.asarray(res1.w)[:, :64][m0]
    b = np.asarray(res_first.w)[:, :64][m0]
    assert np.abs(a - b).max() > 1e-6
