"""Streaming Hessian accumulation + dampened inversion."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hessian import (
    HessianAccumulator,
    dampened_inverse,
    dampened_inverse_np,
)


def test_streaming_equals_batch():
    key = jax.random.key(0)
    m, total = 24, 256
    x = jax.random.normal(key, (m, total))
    acc = HessianAccumulator(m)
    for i in range(0, total, 64):
        acc.update(x[:, i:i + 64])
    h = acc.finalize()
    ref = 2.0 * np.asarray(x, np.float64) @ np.asarray(x, np.float64).T / total
    np.testing.assert_allclose(np.asarray(h), ref, rtol=1e-4)
    assert float(acc.count) == total


def test_uneven_chunks_equal():
    x = jax.random.normal(jax.random.key(1), (8, 100))
    a, b = HessianAccumulator(8), HessianAccumulator(8)
    a.update(x)
    for lo, hi in [(0, 7), (7, 50), (50, 100)]:
        b.update(x[:, lo:hi])
    # chunk boundaries reassociate the f32 sums — tolerance, not equality
    np.testing.assert_allclose(np.asarray(a.h), np.asarray(b.h),
                               rtol=1e-4, atol=1e-6)


def test_merge_matches_concat():
    x = jax.random.normal(jax.random.key(2), (8, 96))
    a, b, c = (HessianAccumulator(8) for _ in range(3))
    a.update(x[:, :32])
    b.update(x[:, 32:])
    c.update(x)
    merged = a.merge(b)
    np.testing.assert_allclose(np.asarray(merged.h), np.asarray(c.h),
                               rtol=1e-4)


def test_weighted_equals_subset():
    """Weighted update with 0/1 weights == plain update on the kept
    columns (the MoE routed-token Hessian)."""
    x = jax.random.normal(jax.random.key(3), (8, 64))
    keep = np.zeros(64, bool)
    keep[::3] = True
    a = HessianAccumulator(8)
    a.update_weighted(x, jnp.asarray(keep, jnp.float32))
    b = HessianAccumulator(8)
    b.update(x[:, keep])
    np.testing.assert_allclose(np.asarray(a.h), np.asarray(b.h), rtol=1e-4)


def test_dampened_inverse_pd_and_matches_np():
    x = jax.random.normal(jax.random.key(4), (16, 40))
    h = 2.0 * x @ x.T / 40
    inv = dampened_inverse(h, 0.01)
    assert bool(jnp.all(jnp.isfinite(inv)))
    ref = dampened_inverse_np(np.asarray(h, np.float64), 0.01)
    np.testing.assert_allclose(np.asarray(inv), ref, rtol=2e-3)
    # eigenvalues of the inverse must be positive (PD)
    eig = np.linalg.eigvalsh(np.asarray(inv, np.float64))
    assert eig.min() > 0


def test_dampened_inverse_rank_deficient():
    """Rank-1 H (single calibration token) must still invert cleanly."""
    v = jax.random.normal(jax.random.key(5), (12, 1))
    h = 2.0 * v @ v.T
    inv = dampened_inverse(h, 0.01)
    assert bool(jnp.all(jnp.isfinite(inv)))


def test_zero_activations_floor():
    h = jnp.zeros((6, 6))
    inv = dampened_inverse(h, 0.01)
    assert bool(jnp.all(jnp.isfinite(inv)))
