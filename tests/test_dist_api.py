"""repro.dist context API — host-mesh only (1×1 over the local CPU
device, no virtual devices needed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import (
    FSDP_EXCLUDE_EMBED,
    batch_spec,
    constrain,
    current_ctx,
    dp_axes_of,
    make_host_mesh,
    mesh_from_spec,
    param_specs,
    shard_params,
    use_mesh,
)


def test_current_ctx_none_outside_mesh():
    assert current_ctx() is None


def test_use_mesh_populates_context():
    mesh = make_host_mesh()
    with use_mesh(mesh) as ctx:
        assert current_ctx() is ctx
        assert ctx.mesh is mesh
        assert ctx.dp_axes == ("data",)
        assert ctx.dp == 1
        assert ctx.tp_axis == "model"
        assert ctx.tp == 1
    assert current_ctx() is None


def test_use_mesh_without_model_axis_degrades_tp():
    mesh = jax.make_mesh((1,), ("data",))
    with use_mesh(mesh) as ctx:
        assert ctx.tp_axis is None
        assert ctx.tp == 1


def test_nested_use_mesh_restores_outer_context():
    outer = make_host_mesh()
    inner = jax.make_mesh((1,), ("data",))
    with use_mesh(outer) as octx:
        with use_mesh(inner) as ictx:
            assert current_ctx() is ictx
        assert current_ctx() is octx
    assert current_ctx() is None


def test_use_mesh_pops_context_on_error():
    mesh = make_host_mesh()
    with pytest.raises(RuntimeError):
        with use_mesh(mesh):
            raise RuntimeError("boom")
    assert current_ctx() is None


def test_constrain_noop_without_context():
    x = jnp.arange(8.0).reshape(2, 4)
    assert constrain(x, "data", None) is x


def test_constrain_identity_on_host_mesh():
    x = jnp.arange(8.0).reshape(2, 4)
    with use_mesh(make_host_mesh()):
        y = constrain(x, "data", "model")
        y2 = jax.jit(lambda a: constrain(a, "data", None))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(x))


def test_shard_params_respects_fsdp_exclude_embed():
    from repro.configs import get_smoke
    from repro.models import LM

    model = LM(get_smoke("qwen3_14b"))
    params = model.init(jax.random.key(0))
    mesh = make_host_mesh()
    specs = param_specs(params, mesh, fsdp_axes=("data",),
                        fsdp_exclude=FSDP_EXCLUDE_EMBED)
    # excluded params never carry a data (FSDP) axis...
    def axes_of(spec):
        out = set()
        for entry in spec:
            if entry is None:
                continue
            out.update(entry if isinstance(entry, tuple) else (entry,))
        return out

    assert "data" not in axes_of(specs["embed"]["tok"])
    if "head" in specs["unembed"]:
        assert "data" not in axes_of(specs["unembed"]["head"])
    # ...while regular block kernels do
    included = param_specs(params, mesh, fsdp_axes=("data",))
    assert "data" in axes_of(included["embed"]["tok"])
    wq = specs["layers"]["s0"]["attn"]["wq"]
    assert "data" in axes_of(wq) and "model" in axes_of(wq)

    # placement round-trips values on the host mesh
    placed = shard_params(params, mesh, fsdp_axes=("data",),
                          fsdp_exclude=FSDP_EXCLUDE_EMBED)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(placed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shard_params_no_context_is_identity():
    params = {"w": jnp.ones((4, 4))}
    assert shard_params(params) is params


def test_batch_spec_covers_pod_data_axes():
    mesh = make_host_mesh()
    assert batch_spec(mesh) == P("data")
    assert batch_spec(mesh, ()) == P()
    assert dp_axes_of(mesh) == ("data",)


def test_mesh_from_spec():
    assert mesh_from_spec("none") is None
    assert mesh_from_spec(None) is None
    host = mesh_from_spec("host")
    assert host.axis_names == ("data", "model")
    explicit = mesh_from_spec("1x1")
    assert explicit.axis_names == ("data", "model")
    with pytest.raises(ValueError):
        mesh_from_spec("not-a-mesh")
