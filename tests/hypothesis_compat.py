"""Import hypothesis, or degrade gracefully when it is not installed.

``from hypothesis_compat import given, settings, st`` behaves exactly
like the real thing when hypothesis is available (the pinned CI env);
otherwise ``@given`` replaces the test with a skip marker so the rest of
the module's plain tests still collect and run.
"""

import pytest

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")

            # keep the test's name for reporting, but NOT its signature
            # (pytest would read wrapped params as fixture requests)
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
