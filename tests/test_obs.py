"""ISSUE-8 observability layer: registry, tracer, serve + prune wiring.

Covers the tentpole acceptance surface: metrics-registry unit behavior
(atomic concurrent increments, histogram bucket edges and interpolated
quantiles, the zero-cost disabled mode, get-or-create binding and
kind-mismatch rejection, Prometheus text rendering), Chrome-trace
export, the request-lifecycle span taxonomy through a real engine run
(submit/queue-wait/prefill/decode-burst/first-token/retire, plus both
preemption flavors with swap-resume), the satellite pin that tracing
on vs off produces bit-identical token streams (greedy + sampled,
steps_per_sync 1 vs 8), the legacy ``ServeEngine.stats`` flat-dict
back-compat view, and the prune pipeline's stage counters/spans
flowing through the same registry.
"""

import json
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM
from repro.obs import (COUNT_BUCKETS, LATENCY_BUCKETS, Obs,
                       MetricsRegistry, Tracer, exp_buckets)
from repro.obs.metrics import merge_histograms
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def tiny_random():
    """Random-init tiny LM with a sharpened head (greedy gaps robust to
    reduction-order rounding) — same recipe as test_serve_paged."""
    cfg = get_config("paper_tiny_lm")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    params["unembed"]["head"] = params["unembed"]["head"] * 8.0
    return model, params


def _mixed_requests(vocab, n=10):
    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                prompt=rng.integers(0, vocab, size=(4, 7, 12)[i % 3],
                                    dtype=np.int32),
                max_new_tokens=(2, 5, 9, 14)[i % 4])
        for i in range(n)
    ]


# ======================================================================
# registry: counters / gauges / histograms
# ======================================================================
def test_counter_concurrent_increments():
    """The satellite fix for the racy /stats dict merge: N threads
    hammering one counter child lose no increments."""
    reg = MetricsRegistry()
    fam = reg.counter("x_total", "t", ("replica",))
    child = fam.labels(replica="r0")
    other = fam.labels(replica="r1")
    n_threads, per = 8, 2000

    def work():
        for _ in range(per):
            child.inc()
            other.inc(2.0)

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert child.value == n_threads * per
    assert other.value == n_threads * per * 2.0
    assert fam.total() == n_threads * per * 3.0


def test_counter_rejects_negative():
    reg = MetricsRegistry()
    c = reg.counter("x_total")
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1.0)


def test_histogram_bucket_edges():
    """``le`` is inclusive: a value exactly on a bound lands in that
    bucket; past the last bound lands in +Inf."""
    reg = MetricsRegistry()
    h = reg.histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 9.0):
        h.observe(v)
    child = h.labels()
    assert child.cumulative() == [2, 4, 5, 6]
    assert child.count == 6
    assert child.sum == pytest.approx(18.0)
    assert child.mean == pytest.approx(3.0)


def test_histogram_quantile_interpolation():
    reg = MetricsRegistry()
    h = reg.histogram("h", buckets=(1.0, 2.0, 4.0)).labels()
    for _ in range(100):
        h.observe(1.5)                    # all in the (1, 2] bucket
    # linear interpolation inside the bucket the rank lands in
    assert h.quantile(0.5) == pytest.approx(1.5)
    assert h.quantile(1.0) == pytest.approx(2.0)
    h.observe(100.0)                      # +Inf tail clamps to last bound
    assert h.quantile(0.9999) == pytest.approx(4.0)
    with pytest.raises(ValueError):
        h.quantile(1.5)
    empty = reg.histogram("h2", buckets=(1.0,)).labels()
    assert empty.quantile(0.5) == 0.0


def test_exp_buckets():
    b = exp_buckets(1e-4, 1.12, 10)
    assert len(b) == 10 and b[0] == pytest.approx(1e-4)
    assert all(x < y for x, y in zip(b, b[1:]))
    assert all(len(repr(v)) <= 12 for v in b)      # 4-sig-digit labels
    with pytest.raises(ValueError):
        exp_buckets(0.0, 2.0, 4)
    assert len(LATENCY_BUCKETS) == 120
    assert COUNT_BUCKETS[0] == 1.0


def test_gauge_set_fn_and_dead_callback():
    reg = MetricsRegistry()
    g = reg.gauge("g")
    g.set(5.0)
    assert g.value == 5.0
    g.labels().set_fn(lambda: 3.0)        # callback-backed (queue depth)
    assert g.value == 3.0

    def boom():
        raise RuntimeError("replica died")

    g.labels().set_fn(boom)
    assert g.value == 0.0                 # must not kill /metrics
    g.set(7.0)                            # set() clears the callback
    assert g.value == 7.0


def test_get_or_create_and_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "help", ("replica",))
    b = reg.counter("x_total", "ignored", ("replica",))
    assert a is b                          # same family, same children
    assert a.labels(replica="r0") is b.labels(replica="r0")
    with pytest.raises(ValueError, match="already bound"):
        reg.gauge("x_total", labels=("replica",))
    with pytest.raises(ValueError, match="label names"):
        reg.counter("x_total", labels=("zone",))
    with pytest.raises(ValueError, match="labels"):
        a.labels(zone="us")                # undeclared label name


def test_disabled_registry_is_noop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("x_total", "t", ("replica",))
    h = reg.histogram("h")
    g = reg.gauge("g")
    assert c.labels(replica="r9") is c     # shared null family
    c.inc(100)
    h.observe(1.0)
    g.set(5.0)
    assert c.value == 0.0 and h.count == 0 and g.value == 0.0
    assert reg.render() == ""
    assert c.total() == 0.0 and h.quantile(0.5) == 0.0
    # same shared object across registries — zero allocation per bind
    assert MetricsRegistry(enabled=False).counter("y_total") is c


def test_render_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("serve_tokens_total", "Tokens emitted",
                ("replica",)).labels(replica="r0").inc(42)
    reg.gauge("serve_queue_depth", labels=("replica",)
              ).labels(replica="r0").set(3)
    reg.histogram("serve_ttft_seconds", "TTFT", ("replica",),
                  buckets=(0.1, 1.0)).labels(replica="r0").observe(0.5)
    text = reg.render()
    assert "# HELP serve_tokens_total Tokens emitted" in text
    assert "# TYPE serve_tokens_total counter" in text
    assert 'serve_tokens_total{replica="r0"} 42' in text   # int formatting
    assert 'serve_queue_depth{replica="r0"} 3' in text
    assert "# TYPE serve_ttft_seconds histogram" in text
    assert 'serve_ttft_seconds_bucket{replica="r0",le="0.1"} 0' in text
    assert 'serve_ttft_seconds_bucket{replica="r0",le="1"} 1' in text
    assert 'serve_ttft_seconds_bucket{replica="r0",le="+Inf"} 1' in text
    assert 'serve_ttft_seconds_sum{replica="r0"} 0.5' in text
    assert 'serve_ttft_seconds_count{replica="r0"} 1' in text
    assert text.endswith("\n")


def test_registry_reset_and_collect():
    reg = MetricsRegistry()
    c = reg.counter("x_total")
    c.inc(5)
    h = reg.histogram("h", buckets=(1.0, 2.0))
    h.observe(0.5)
    g = reg.gauge("depth")
    g.set_fn(lambda: 11.0)
    snap = reg.collect()
    assert snap["x_total"]["samples"][""] == 5.0
    assert snap["h"]["samples"][""]["count"] == 1
    assert snap["depth"]["samples"][""] == 11.0
    reg.reset()
    assert c.value == 0.0 and h.hist_count() == 0
    assert g.value == 11.0                 # callback gauges survive reset


def test_merge_histograms_across_registries():
    """One TTFT percentile across independently-built replica
    registries (the multi-replica router summary path)."""
    regs = [MetricsRegistry() for _ in range(2)]
    for i, reg in enumerate(regs):
        fam = reg.histogram("serve_ttft_seconds", buckets=(1.0, 2.0, 4.0),
                            labels=("replica",))
        for _ in range(50):
            fam.labels(replica=f"r{i}").observe(1.5 if i == 0 else 3.0)
    fams = [r.get("serve_ttft_seconds") for r in regs]
    merged = merge_histograms(fams)
    assert merged.count == 100
    assert merged.quantile(0.25) == pytest.approx(1.5)
    assert merged.quantile(0.75) == pytest.approx(3.0)
    assert merge_histograms([]) is None


# ======================================================================
# tracer
# ======================================================================
def test_tracer_events_and_export(tmp_path):
    tr = Tracer()
    t0 = tr.now()
    tr.async_begin("request", 7, args={"prompt_len": 4})
    tr.instant("preempt_swap", track="r0", args={"uid": 7})
    tr.complete("decode_burst", t0, tr.now(), track="r0",
                args={"steps": 8})
    with tr.span("solve", track="prune"):
        pass
    tr.async_end("request", 7)
    assert len(tr.events("request", ph="b")) == 1
    assert tr.events("request", ph="b")[0]["id"] == 7
    assert len(tr.events("preempt_swap", ph="i")) == 1
    burst = tr.events("decode_burst", ph="X")[0]
    assert burst["dur"] >= 0 and burst["args"]["steps"] == 8
    assert len(tr.events("solve", ph="X")) == 1

    path = tmp_path / "trace.json"
    n = tr.export(str(path))
    doc = json.loads(path.read_text())     # loadable Chrome-trace JSON
    assert len(doc["traceEvents"]) == n
    # thread-name metadata gives each track its own lane
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M"}
    assert {"main", "r0", "prune"} <= names
    tr.clear()
    assert tr.events(ph="X") == [] and tr.events(ph="M") != []


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    tr.async_begin("request", 1)
    tr.instant("x")
    tr.complete("y", 0.0, 1.0)
    with tr.span("z"):
        pass
    assert tr.events() == []


# ======================================================================
# serve wiring: lifecycle spans, legacy stats, /metrics content
# ======================================================================
def test_request_lifecycle_spans(tiny_random):
    """A traced engine run emits the full span taxonomy: one async
    request span per uid (balanced b/e), one queue-wait span and one
    first-token instant per request, burst windows, and the latency
    histograms the summaries derive from."""
    model, params = tiny_random
    obs = Obs.create(metrics=True, trace=True)
    eng = ServeEngine(model, params, max_batch=4, max_len=48,
                      page_size=8, prefill_chunk=4, obs=obs)
    reqs = _mixed_requests(model.cfg.vocab_size)
    res = eng.generate(reqs)
    uids = sorted(r.uid for r in reqs)
    tr = obs.tracer
    assert sorted(e["id"] for e in tr.events("request", ph="b")) == uids
    assert sorted(e["id"] for e in tr.events("request", ph="e")) == uids
    assert len(tr.events("queue_wait", ph="X")) == len(reqs)
    assert len(tr.events("first_token", ph="i")) == len(reqs)
    bursts = (tr.events("decode_burst", ph="X")
              + tr.events("prefill_burst", ph="X"))
    assert len(bursts) == eng.stats["host_syncs"] > 0
    assert all(b["dur"] > 0 for b in bursts)
    # histograms observed once per request
    assert eng.m.ttft.count == len(reqs)
    assert eng.m.queue_wait.count == len(reqs)
    assert eng.m.tpot.count == sum(1 for r in res if len(r.tokens) > 1)
    assert eng.m.burst_steps.count == eng.stats["host_syncs"]


def test_preemption_spans_recompute_and_swap_resume(tiny_random):
    """Both preemption flavors show up in the trace, and a swap-resumed
    request still closes its async span after re-admission."""
    model, params = tiny_random
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, model.cfg.vocab_size,
                                        (4, 9, 13)[i % 3]).astype(np.int32),
                    max_new_tokens=(22, 9, 26)[i % 3])
            for i in range(7)]
    kw = dict(max_batch=3, max_len=48, page_size=8, num_pages=8,
              prefix_cache=False, steps_per_sync=4)
    rec_obs = Obs.create(metrics=True, trace=True)
    rec = ServeEngine(model, params, host_swap_pages=0, obs=rec_obs, **kw)
    rec.generate(reqs)
    assert rec.stats["preempt_recompute"] > 0
    assert (len(rec_obs.tracer.events("preempt_recompute", ph="i"))
            == rec.stats["preempt_recompute"])
    assert rec_obs.tracer.events("preempt_swap", ph="i") == []

    swp_obs = Obs.create(metrics=True, trace=True)
    swp = ServeEngine(model, params, host_swap_pages=None, obs=swp_obs,
                      **kw)
    swp.generate(reqs)
    tr = swp_obs.tracer
    assert swp.stats["preempt_swap"] > 0
    assert (len(tr.events("preempt_swap", ph="i"))
            == swp.stats["preempt_swap"])
    assert len(tr.events("swap_resume", ph="i")) > 0
    assert len(tr.events("swap_in", ph="X")) > 0
    # every preempted request resumed and retired
    uids = sorted(r.uid for r in reqs)
    assert sorted(e["id"] for e in tr.events("request", ph="e")) == uids
    # queue-wait is first-admission only: one span per request even
    # though swap victims re-enter the wait queue
    assert len(tr.events("queue_wait", ph="X")) == len(reqs)


@pytest.mark.parametrize("sps", [1, 8])
def test_tracing_bit_parity_greedy(tiny_random, sps):
    """Acceptance: tracing + metrics on vs fully disabled emits
    bit-identical greedy token streams at both burst lengths."""
    model, params = tiny_random
    reqs = _mixed_requests(model.cfg.vocab_size)
    kw = dict(max_batch=4, max_len=48, page_size=8, steps_per_sync=sps)
    off = ServeEngine(model, params, obs=Obs.disabled(),
                      **kw).generate(reqs)
    obs = Obs.create(metrics=True, trace=True)
    on = ServeEngine(model, params, obs=obs, **kw).generate(reqs)
    for a, b in zip(off, on):
        assert a.uid == b.uid
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert len(obs.tracer.events("request", ph="e")) == len(reqs)


@pytest.mark.parametrize("sps", [1, 8])
def test_tracing_bit_parity_sampled(tiny_random, sps):
    model, params = tiny_random
    reqs = _mixed_requests(model.cfg.vocab_size, n=8)
    kw = dict(max_batch=4, max_len=48, page_size=8, steps_per_sync=sps,
              temperature=1.0, top_k=20)
    off = ServeEngine(model, params, obs=Obs.disabled(),
                      **kw).generate(reqs, seed=7)
    on = ServeEngine(model, params, obs=Obs.create(metrics=True,
                                                   trace=True),
                     **kw).generate(reqs, seed=7)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_legacy_stats_view_rebases_per_run(tiny_random):
    """``ServeEngine.stats`` keeps its flat per-run dict shape on top of
    the monotonic registry: a second generate() re-bases the view."""
    model, params = tiny_random
    eng = ServeEngine(model, params, max_batch=4, max_len=48, page_size=8)
    reqs = _mixed_requests(model.cfg.vocab_size)
    res = eng.generate(reqs)
    total = sum(len(r.tokens) for r in res)
    s1 = dict(eng.stats)
    for key in ("host_syncs", "device_steps", "prefill_chunks", "tokens",
                "decode_wall_s", "preempt_swap", "preempt_recompute",
                "prefix_hit_tokens", "prefill_tok", "cow_copies",
                "prefix_evictions", "swap_out_pages", "swap_in_pages",
                "swap_in_wall_s"):
        assert key in s1
    assert s1["tokens"] == total
    assert isinstance(s1["tokens"], int)          # legacy int typing
    assert isinstance(s1["decode_wall_s"], float)
    eng.generate(reqs[:3])
    assert eng.stats["tokens"] == sum(
        len(r.tokens) for r in res if r.uid < 3)  # this run only
    # while the registry itself stayed monotonic across both runs
    fam = eng.obs.metrics.get("serve_tokens_total")
    assert fam.total() == total + eng.stats["tokens"]


def test_metrics_render_after_run(tiny_random):
    model, params = tiny_random
    obs = Obs.create(metrics=True, trace=False, label="r3")
    eng = ServeEngine(model, params, max_batch=4, max_len=48,
                      page_size=8, obs=obs)
    eng.generate(_mixed_requests(model.cfg.vocab_size))
    text = obs.metrics.render()
    for series in ("serve_host_syncs_total", "serve_device_steps_total",
                   "serve_tokens_total", "serve_requests_total",
                   "serve_slot_steps_total"):
        assert f'{series}{{replica="r3"}}' in text
    assert 'serve_ttft_seconds_count{replica="r3"}' in text
    assert 'serve_burst_steps_bucket{replica="r3",le="1"}' in text


def test_utilization_from_registry(tiny_random):
    """serve_tokens_total / serve_slot_steps_total reproduces the
    Result accounting the launcher summary prints."""
    model, params = tiny_random
    eng = ServeEngine(model, params, max_batch=2, max_len=32,
                      page_size=8)
    res = eng.generate(
        [Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                 max_new_tokens=2),
         Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                 max_new_tokens=12)])
    toks = eng.obs.metrics.get("serve_tokens_total").total()
    slots = eng.obs.metrics.get("serve_slot_steps_total").total()
    want = (sum(r.decode_steps for r in res) /
            sum(r.decode_steps / r.utilization for r in res))
    assert toks / slots == pytest.approx(want)


# ======================================================================
# prune pipeline: stage counters + spans through the same registry
# ======================================================================
def test_prune_pipeline_stage_metrics(tiny_lm):
    from repro.core import PruningEngine
    from repro.data import calibration_batches

    model, params, _ = tiny_lm
    calib = calibration_batches(model.cfg, n_samples=8, seq_len=64,
                                batch=8)
    eng = PruningEngine(model, "2:4", method="SM", blocksize=64)
    eng.obs = Obs.create(metrics=True, trace=True)
    eng.run(params, calib)
    reg = eng.obs.metrics
    stage = reg.get("prune_stage_seconds_total")
    by_stage = {k[0]: c.value for k, c in stage.children()}
    assert {"capture", "solve", "propagate"} <= set(by_stage)
    assert all(v > 0 for v in by_stage.values())
    assert reg.get("prune_segments_total").total() > 0
    assert reg.get("prune_compiles_total").total() > 0
    # registry seconds mirror the engine's own pipeline stats
    ps = eng.last_pipeline_stats
    assert by_stage["solve"] == pytest.approx(ps.solve_s, rel=1e-6)
    for st in ("capture", "solve", "propagate"):
        assert len(eng.obs.tracer.events(f"prune_{st}", ph="X")) > 0
