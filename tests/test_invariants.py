"""Property-based tests of system-level invariants (hypothesis;
each test degrades to a skip when hypothesis is not installed)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from conftest import random_psd_hessian
from repro.core import masks as masks_lib
from repro.core import mrp
from repro.core.hessian import HessianAccumulator, dampened_inverse
from repro.core.pruner import prune_matrix, reconstruction_error
from repro.kernels import ops, ref
from repro.optim.compression import dequantize_int8, quantize_int8


# ----------------------------------------------------------------------
# Pruning invariants
# ----------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), method=st.sampled_from(["SS", "SM", "MM"]))
def test_prune_idempotent_on_mask(seed, method):
    """Re-running compensation with the SAME mask must not change w
    (the optimal δw of an already-satisfied constraint set is 0)."""
    key = jax.random.key(seed)
    w = jax.random.normal(key, (8, 32))
    h = random_psd_hessian(jax.random.fold_in(key, 1), 32)
    res = prune_matrix(w, h, "2:4", method=method, blocksize=32)
    hinv = dampened_inverse(h)
    w2, loss2 = mrp.mrp_compensate_mask(res.w, hinv, res.mask)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(res.w), atol=1e-4)
    assert float(jnp.max(loss2)) < 1e-6      # pruned weights already zero


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_prune_scale_invariance(seed):
    """Scaling H by a constant must not change mask or compensation
    (Eq. 11–14 are scale-free in H up to dampening)."""
    key = jax.random.key(seed)
    w = jax.random.normal(key, (8, 32))
    h = random_psd_hessian(jax.random.fold_in(key, 1), 32)
    a = prune_matrix(w, h, "2:4", method="SM", blocksize=32)
    b = prune_matrix(w, 7.3 * h, "2:4", method="SM", blocksize=32)
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
    np.testing.assert_allclose(np.asarray(a.w), np.asarray(b.w), atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_compensation_never_hurts(seed):
    """SM (with compensation) ≤ same-mask zeroing without compensation
    in reconstruction error — the optimal δw can't be worse than δw=0."""
    key = jax.random.key(seed)
    w = jax.random.normal(key, (8, 32))
    h = random_psd_hessian(jax.random.fold_in(key, 1), 32)
    res = prune_matrix(w, h, "2:4", method="SM", blocksize=32)
    w_zeroed = jnp.where(res.mask, 0.0, w)
    assert (reconstruction_error(w, res.w, h)
            <= reconstruction_error(w, w_zeroed, h) + 1e-6)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(2, 12),
    seed=st.integers(0, 2**30),
)
def test_row_independence(rows, seed):
    """Remark 4.2: row q's compensation is independent of other rows —
    permuting rows and pruning commutes."""
    key = jax.random.key(seed)
    w = jax.random.normal(key, (rows, 16))
    h = random_psd_hessian(jax.random.fold_in(key, 1), 16)
    hinv = dampened_inverse(h)
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random((rows, 16)) < 0.3)
    perm = rng.permutation(rows)
    a, _ = mrp.mrp_compensate_mask(w, hinv, mask)
    b, _ = mrp.mrp_compensate_mask(w[perm], hinv, mask[perm])
    np.testing.assert_allclose(np.asarray(a)[perm], np.asarray(b),
                               atol=1e-4)


# ----------------------------------------------------------------------
# Hessian invariants
# ----------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), splits=st.integers(1, 5))
def test_hessian_chunking_invariance(seed, splits):
    x = jax.random.normal(jax.random.key(seed), (8, 60))
    whole = HessianAccumulator(8)
    whole.update(x)
    chunked = HessianAccumulator(8)
    bounds = sorted(
        np.random.default_rng(seed).choice(59, splits, replace=False) + 1)
    prev = 0
    for b in list(bounds) + [60]:
        if b > prev:
            chunked.update(x[:, prev:b])
        prev = b
    np.testing.assert_allclose(np.asarray(whole.h), np.asarray(chunked.h),
                               rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------------------
# Kernel invariants
# ----------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**30),
       k=st.sampled_from([64, 128]), n=st.sampled_from([64, 128]))
def test_compress_roundtrip_property(seed, k, n):
    """compress→decompress is the identity on any 2:4-sparse matrix."""
    key = jax.random.key(seed)
    w = jax.random.normal(key, (k, n))
    gt = w.reshape(k // 4, 4, n).transpose(0, 2, 1)
    _, idx = jax.lax.top_k(-jnp.abs(gt), 2)
    m = jax.nn.one_hot(idx, 4).sum(-2) > 0
    wg = jnp.where(m, 0, gt).transpose(0, 2, 1).reshape(k, n)
    vals, pidx = ops.compress_24(wg)
    np.testing.assert_array_equal(
        np.asarray(ref.decompress_24(vals, pidx)), np.asarray(wg))
    # index stream is always in-range and strictly ordered per pair
    pid = np.asarray(pidx).reshape(k // 4, 2, n)
    assert pid.min() >= 0 and pid.max() <= 3


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), scale=st.floats(1e-3, 1e3))
def test_int8_quantization_error_bound(seed, scale):
    x = jax.random.normal(jax.random.key(seed), (256,)) * scale
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-9 * scale


# ----------------------------------------------------------------------
# Mask algebra invariants
# ----------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**30), n=st.integers(1, 3))
def test_nm_mask_count_invariant_under_score_shift(seed, n):
    """Adding a constant to all scores must not change the N:M mask."""
    sc = jax.random.normal(jax.random.key(seed), (6, 24))
    a = masks_lib.nm_mask_from_scores(sc, n, 4)
    b = masks_lib.nm_mask_from_scores(sc + 123.0, n, 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
