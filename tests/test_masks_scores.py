"""Mask construction + sparsity specs — incl. hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from conftest import random_psd_hessian
from repro.core import masks as masks_lib
from repro.core import scores
from repro.core.hessian import dampened_inverse
from repro.core.sparsity import SparsitySpec


# ----------------------------------------------------------------------
# SparsitySpec
# ----------------------------------------------------------------------
def test_spec_parse():
    s = SparsitySpec.parse("0.5")
    assert not s.is_semi_structured and s.fraction == 0.5
    s = SparsitySpec.parse("2:4")
    assert s.is_semi_structured and (s.n, s.m) == (2, 4)
    assert s.fraction == 0.5
    with pytest.raises(ValueError):
        SparsitySpec.parse("4:2")
    with pytest.raises(ValueError):
        SparsitySpec.parse("1.5")


@given(st.integers(1, 7), st.integers(2, 8))
def test_spec_nm_property(n, m):
    if n >= m:
        with pytest.raises(ValueError):
            SparsitySpec.semi_structured(n, m)
        return
    s = SparsitySpec.semi_structured(n, m)
    assert abs(s.fraction - n / m) < 1e-9
    assert s.pruned_per_row_block(4 * m) == 4 * n


# ----------------------------------------------------------------------
# masks (property-based)
# ----------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 24),
    groups=st.integers(1, 12),
    n_prune=st.integers(1, 3),
    seed=st.integers(0, 2**30),
)
def test_nm_mask_valid_for_any_scores(rows, groups, n_prune, seed):
    m_group = 4
    if n_prune >= m_group:
        return
    sc = jax.random.normal(jax.random.key(seed), (rows, groups * m_group))
    mask = masks_lib.nm_mask_from_scores(sc, n_prune, m_group)
    assert masks_lib.validate_nm(np.asarray(mask), n_prune, m_group)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 16),
    cols=st.integers(1, 64),
    frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**30),
)
def test_unstructured_mask_exact_count(rows, cols, frac, seed):
    sc = jax.random.normal(jax.random.key(seed), (rows, cols))
    k = int(round(rows * cols * frac))
    mask = masks_lib.unstructured_mask_from_scores(sc, k)
    assert int(np.asarray(mask).sum()) == min(k, rows * cols)
    # selected entries are exactly the k smallest scores
    if 0 < k < rows * cols:
        chosen = np.sort(np.asarray(sc)[np.asarray(mask)])
        rest = np.asarray(sc)[~np.asarray(mask)]
        assert chosen[-1] <= rest.min() + 1e-6


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 16),
    cols=st.integers(2, 48),
    seed=st.integers(0, 2**30),
    data=st.data(),
)
def test_padded_row_indices_roundtrip(rows, cols, seed, data):
    per_row = data.draw(st.integers(0, cols))
    sc = jax.random.normal(jax.random.key(seed), (rows, cols))
    mask = masks_lib.unstructured_mask_rowwise(sc, per_row)
    counts = np.asarray(mask).sum(1)
    assert (counts == min(per_row, cols)).all()
    k_max = masks_lib.bucket_k(int(counts.max())) if counts.max() else 4
    k_max = min(k_max, cols)
    idx, valid = masks_lib.padded_row_indices(mask, k_max)
    rebuilt = np.zeros((rows, cols), bool)
    idx_n, valid_n = np.asarray(idx), np.asarray(valid)
    for i in range(rows):
        rebuilt[i, idx_n[i][valid_n[i]]] = True
    assert (rebuilt == np.asarray(mask)).all()


# ----------------------------------------------------------------------
# scores
# ----------------------------------------------------------------------
def test_score_shapes_and_orderings():
    key = jax.random.key(0)
    w = jax.random.normal(key, (8, 32))
    h = random_psd_hessian(jax.random.key(1), 32)
    hinv = dampened_inverse(h)
    for name in ("magnitude", "wanda", "obs", "sparsegpt"):
        sc = scores.compute_score(name, w, h, hinv)
        assert sc.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(sc)))
        assert bool(jnp.all(sc >= 0))
    # obs == Eq.14
    ref = np.asarray(w) ** 2 / (2 * np.diag(np.asarray(hinv)))[None, :]
    np.testing.assert_allclose(
        np.asarray(scores.compute_score("obs", w, h, hinv)), ref, rtol=1e-5)


def test_wanda_equals_magnitude_times_actnorm():
    x = jax.random.normal(jax.random.key(2), (16, 100))
    h = 2.0 * x @ x.T / 100
    w = jax.random.normal(jax.random.key(3), (4, 16))
    sc = scores.wanda_score(w, h)
    norms = jnp.sqrt(jnp.sum(x * x, axis=1) / 100) * jnp.sqrt(2.0)
    np.testing.assert_allclose(
        np.asarray(sc), np.asarray(jnp.abs(w) * norms[None, :]), rtol=1e-5)
