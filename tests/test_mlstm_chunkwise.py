"""Chunkwise mLSTM (the §Perf Cell-H form) vs the quadratic parallel
form — must agree for every chunk size, including the state carry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.ssm as S


def _inputs(seed, b=2, t=64, nh=4, hd=8):
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (b, t, nh, hd)) / np.sqrt(hd)
    k = jax.random.normal(ks[1], (b, t, nh, hd))
    v = jax.random.normal(ks[2], (b, t, nh, hd))
    logi = jax.random.normal(ks[3], (b, t, nh)) * 0.5
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, t, nh)) + 2.0)
    return q, k, v, logi, logf


def _parallel_ref(q, k, v, logi, logf):
    t = q.shape[1]
    fcum = jnp.cumsum(logf, axis=1)
    dmat = fcum[:, :, None, :] - fcum[:, None, :, :] + logi[:, None, :, :]
    tri = jnp.tril(jnp.ones((t, t), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)
    dstab = jnp.exp(dmat - m)
    scores = jnp.einsum("bthd,bshd->btsh", q, k) * dstab
    norm = jnp.maximum(jnp.abs(jnp.sum(scores, axis=2)),
                       jnp.exp(-m[:, :, 0, :]))
    return jnp.einsum("btsh,bshd->bthd", scores, v) / norm[..., None]


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
@pytest.mark.parametrize("seed", [0, 7])
def test_chunkwise_matches_parallel(chunk, seed):
    q, k, v, logi, logf = _inputs(seed)
    ref = _parallel_ref(q, k, v, logi, logf)
    got, _ = S._mlstm_chunkwise(q, k, v, logi, logf, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=5e-5)


def test_chunkwise_state_matches_decode_recurrence(tiny_lm=None):
    """The chunkwise final state must continue correctly: decode one more
    token from the carried state == parallel form over T+1."""
    q, k, v, logi, logf = _inputs(3, t=32)
    _, (c, n, m) = S._mlstm_chunkwise(q, k, v, logi, logf, 8)
    # one decode step (the mlstm_apply decode recurrence, inlined)
    ks = jax.random.split(jax.random.key(99), 5)
    b, nh, hd = 2, 4, 8
    q1 = jax.random.normal(ks[0], (b, nh, hd)) / np.sqrt(hd)
    k1 = jax.random.normal(ks[1], (b, nh, hd))
    v1 = jax.random.normal(ks[2], (b, nh, hd))
    li1 = jax.random.normal(ks[3], (b, nh)) * 0.5
    lf1 = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, nh)) + 2.0)
    m1 = jnp.maximum(lf1 + m, li1)
    fw = jnp.exp(lf1 + m - m1)[..., None]
    iw = jnp.exp(li1 - m1)[..., None]
    c1 = fw[..., None] * c + iw[..., None] * (
        k1[..., :, None] * v1[..., None, :])
    n1 = fw * n + iw * k1
    num = jnp.einsum("bhde,bhd->bhe", c1, q1)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n1, q1)),
                      jnp.exp(-m1))
    y_dec = num / den[..., None]

    # reference: full parallel over T+1
    qf = jnp.concatenate([q, q1[:, None]], axis=1)
    kf = jnp.concatenate([k, k1[:, None]], axis=1)
    vf = jnp.concatenate([v, v1[:, None]], axis=1)
    lif = jnp.concatenate([logi, li1[:, None]], axis=1)
    lff = jnp.concatenate([logf, lf1[:, None]], axis=1)
    ref = _parallel_ref(qf, kf, vf, lif, lff)[:, -1]
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(ref),
                               atol=5e-5)
