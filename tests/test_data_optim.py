"""Data pipeline determinism + optimizer/compression numerics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataPipeline, MarkovCorpus, calibration_batches
from repro.optim import AdamW, ef_init, ef_quantize
from repro.optim.compression import dequantize_int8, quantize_int8
from repro.optim.schedules import warmup_cosine


# ----------------------------------------------------------------------
def test_batches_deterministic_by_step():
    cfg = get_config("paper_tiny_lm")
    a = DataPipeline(cfg, 4, 32, seed=0)
    b = DataPipeline(cfg, 4, 32, seed=0)
    for step in (0, 3, 17):
        np.testing.assert_array_equal(
            np.asarray(a.batch_at(step)["tokens"]),
            np.asarray(b.batch_at(step)["tokens"]))
    # different steps/streams/seeds differ
    assert not np.array_equal(np.asarray(a.batch_at(0)["tokens"]),
                              np.asarray(a.batch_at(1)["tokens"]))
    assert not np.array_equal(np.asarray(a.batch_at(0)["tokens"]),
                              np.asarray(a.eval_batch(0)["tokens"]))
    c = DataPipeline(cfg, 4, 32, seed=1)
    assert not np.array_equal(np.asarray(a.batch_at(0)["tokens"]),
                              np.asarray(c.batch_at(0)["tokens"]))


def test_corpus_markov_structure():
    """Transitions follow the chain: successor distribution concentrated."""
    corpus = MarkovCorpus(128, seed=0)
    toks = np.asarray(corpus.batch_at(0, 0, 64, 256))
    assert toks.shape == (64, 256)
    assert toks.min() >= 0 and toks.max() < 128
    # empirical next-token entropy must be far below uniform
    pairs = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    ents = []
    for a, succ in pairs.items():
        if len(succ) >= 30:
            _, counts = np.unique(succ, return_counts=True)
            p = counts / counts.sum()
            ents.append(-(p * np.log(p)).sum())
    assert np.mean(ents) < 0.7 * np.log(128)


def test_calibration_batches_shapes():
    cfg = get_config("paper_tiny_lm")
    batches = calibration_batches(cfg, n_samples=16, seq_len=32, batch=8)
    assert len(batches) == 2
    assert batches[0]["tokens"].shape == (8, 32)


# ----------------------------------------------------------------------
def test_adamw_converges_quadratic():
    """Minimize ||x - target||² — AdamW must get close."""
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    state = opt.init(params)
    for _ in range(300):
        grads = {"x": 2 * (params["x"] - target)}
        params, state, _ = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_bf16_moments_close_to_f32():
    key = jax.random.key(0)
    params = {"w": jax.random.normal(key, (16, 16))}
    g = {"w": jax.random.normal(jax.random.fold_in(key, 1), (16, 16))}
    o32 = AdamW(lr=1e-2, moment_dtype="float32")
    o16 = AdamW(lr=1e-2, moment_dtype="bfloat16")
    p32, s32 = params, o32.init(params)
    p16, s16 = params, o16.init(params)
    for _ in range(5):
        p32, s32, _ = o32.update(g, s32, p32)
        p16, s16, _ = o16.update(g, s16, p16)
    np.testing.assert_allclose(np.asarray(p32["w"]), np.asarray(p16["w"]),
                               atol=2e-2)
    assert s16.mu["w"].dtype == jnp.bfloat16


def test_grad_clip():
    params = {"x": jnp.zeros(4)}
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    state = opt.init(params)
    _, _, stats = opt.update({"x": jnp.full((4,), 100.0)}, state, params)
    assert float(stats["grad_norm"]) == 200.0  # pre-clip norm reported


def test_schedule_warmup_and_decay():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) < 1e-6
    assert 0.4 < float(lr(jnp.int32(55))) < 0.6


# ----------------------------------------------------------------------
def test_int8_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 5
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """Constant gradient: EF-compressed mean over T steps → g with error
    ≤ half-quantization-step / T (the residual carries what each round
    dropped, so the *cumulative* emission is exact up to the last
    residual — the whole point of error feedback)."""
    g = {"w": jnp.asarray([1e-4, 5.0, -3.0, 2e-5])}
    res = ef_init(g)
    total = jnp.zeros(4)
    T = 400
    for _ in range(T):
        deq, res = ef_quantize(g, res)
        total = total + deq["w"]
    half_step = 5.0 / 127 / 2
    err = np.abs(np.asarray(total) / T - np.asarray(g["w"]))
    assert err.max() <= half_step / T + 1e-7
    # WITHOUT error feedback the tiny components would be lost entirely:
    zero = ef_init(g)
    deq_nof, _ = ef_quantize(g, zero)
    assert float(deq_nof["w"][0]) == 0.0   # 1e-4 under half-step → dropped
