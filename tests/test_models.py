"""Per-architecture smoke tests: every assigned arch instantiates a
reduced config, runs forward/train/decode on CPU, output shapes + no NaN.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import LM

SMOKE_ARCHS = list(ARCH_IDS)


def _batch(cfg, b=2, t=16, key=0):
    toks = jax.random.randint(jax.random.key(key), (b, t), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend is not None:
        batch["frontend_feats"] = 0.1 * jax.random.normal(
            jax.random.key(key + 1),
            (b, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke(arch)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    t_total = 16 + (cfg.frontend_len
                    if cfg.frontend and not cfg.encdec else 0)
    assert logits.shape == (2, t_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = model.loss_fn(params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_train_step(arch):
    from repro.optim import AdamW
    from repro.train import make_train_step

    cfg = get_smoke(arch)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, opt))
    opt_state = opt.init(params)
    batch = _batch(cfg)
    p1, o1, _, m1 = step(params, opt_state, jnp.zeros(()), batch)
    p2, _, _, m2 = step(p1, o1, jnp.zeros(()), batch)
    assert bool(jnp.isfinite(m1["loss"])) and bool(jnp.isfinite(m2["loss"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, p1)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_decode_matches_forward(arch):
    cfg = get_smoke(arch)
    if cfg.moe is not None:   # avoid capacity-drop divergence in the check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    b, t = 2, 12
    batch = _batch(cfg, b, t, key=3)
    off = cfg.frontend_len if (cfg.frontend and not cfg.encdec) else 0
    logits_full, _ = model.forward(params, batch)
    cache = model.init_cache(b, off + t + 4)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :t - 1]
    lg_pre, cache = model.prefill(params, pb, cache)
    lg_dec, cache = model.decode_step(
        params, batch["tokens"][:, t - 1], cache, jnp.int32(off + t - 1))
    np.testing.assert_allclose(
        np.asarray(logits_full[:, off + t - 2]), np.asarray(lg_pre),
        atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, off + t - 1]), np.asarray(lg_dec),
        atol=2e-4)


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_full_config_shapes_only(arch):
    """FULL configs must at least build their parameter *shapes* (no
    allocation) and count params plausibly."""
    cfg = get_config(arch)
    model = LM(cfg)
    shapes = model.init_shapes()
    counts = model.param_counts()
    assert counts["total"] > 0
    assert counts["active"] <= counts["total"]
    leaves = jax.tree.leaves(shapes)
    assert all(isinstance(x, jax.ShapeDtypeStruct) for x in leaves)


def test_param_counts_match_published_scale():
    """Sanity-check the full configs land near their advertised sizes."""
    expect = {
        "qwen3_14b": (13e9, 16e9),
        "qwen1_5_0_5b": (0.4e9, 0.8e9),
        "gemma_2b": (2e9, 3.2e9),
        "kimi_k2_1t_a32b": (0.8e12, 1.3e12),
        "phi3_5_moe_42b_a6_6b": (40e9, 45e9),
        "jamba_1_5_large_398b": (350e9, 450e9),
        "xlstm_350m": (0.2e9, 0.5e9),
    }
    for arch, (lo, hi) in expect.items():
        counts = LM(get_config(arch)).param_counts()
        assert lo <= counts["total"] <= hi, (
            f"{arch}: {counts['total'] / 1e9:.2f}B not in "
            f"[{lo / 1e9}, {hi / 1e9}]B")


def test_kimi_active_params_32b_scale():
    counts = LM(get_config("kimi_k2_1t_a32b")).param_counts()
    assert 20e9 <= counts["active"] <= 45e9
