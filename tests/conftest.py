"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device by
design (the 512-device mesh exists only inside launch/dryrun.py and the
subprocess-based tests in test_dist.py)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataPipeline
from repro.models import LM
from repro.optim import AdamW
from repro.optim.schedules import warmup_cosine
from repro.train import TrainConfig, Trainer


@pytest.fixture(scope="session")
def tiny_lm():
    """The paper-tiny-lm trained ~200 steps on the synthetic corpus.

    Session-scoped: trained once, shared by pruning/serving/benchmark
    tests. Returns (model, params, pipeline)."""
    cfg = get_config("paper_tiny_lm")
    model = LM(cfg)
    pipe = DataPipeline(cfg, global_batch=16, seq_len=64, seed=0)
    opt = AdamW(lr=warmup_cosine(1e-3, 20, 200))
    out = "/tmp/repro_test_tiny_lm"
    tc = TrainConfig(total_steps=200, global_batch=16, seq_len=64,
                     ckpt_every=200, out_dir=out, log_every=100)
    trainer = Trainer(model, opt, pipe, tc)
    params, _, _ = trainer.run()   # resumes from ckpt if already trained
    return model, params, pipe


def eval_ppl(model, params, pipe, n=6):
    tot = cnt = 0.0
    for i in range(n):
        _, m = model.loss_fn(params, pipe.eval_batch(i))
        tot += float(m["ce"]) * float(m["tokens"])
        cnt += float(m["tokens"])
    return float(np.exp(tot / cnt))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_psd_hessian(key, m, scale=1.0):
    """A well-conditioned random PSD 'calibration' Hessian."""
    x = jax.random.normal(key, (m, 4 * m))
    return scale * (2.0 * (x @ x.T) / (4 * m)) + 0.1 * jnp.eye(m)
