"""Fault-tolerant trainer: resume bit-exactness, NaN guard, stragglers."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import DataPipeline
from repro.models import LM
from repro.optim import AdamW
from repro.train import StragglerError, TrainConfig, Trainer
from repro.train.loop import make_train_step


def _mk(tmp_path, name, **kw):
    cfg = get_smoke("paper_tiny_lm")
    model = LM(cfg)
    pipe = DataPipeline(cfg, global_batch=4, seq_len=32, seed=0)
    opt = AdamW(lr=1e-3)
    defaults = dict(total_steps=20, global_batch=4, seq_len=32,
                    ckpt_every=5, out_dir=str(tmp_path / name), log_every=5)
    defaults.update(kw)
    tc = TrainConfig(**defaults)
    return Trainer(model, opt, pipe, tc), model


def _params_equal(a, b, atol=0.0):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


def test_loss_decreases(tmp_path):
    tr, _ = _mk(tmp_path, "a", total_steps=40)
    tr.run()
    lines = [json.loads(line) for line in
             open(tr.metrics_path)]
    assert lines[-1]["loss"] < lines[0]["loss"]


def test_resume_bit_exact(tmp_path):
    """Crash at step 10 of 20 → resume → same params as uninterrupted."""
    tr_full, _ = _mk(tmp_path, "full")
    p_full, _, _ = tr_full.run()

    tr_a, _ = _mk(tmp_path, "interrupted")
    tr_a.run(max_steps=10)            # "crash" after 10 steps
    tr_b, _ = _mk(tmp_path, "interrupted")   # new process, same dir
    p_resumed, _, info = tr_b.run()
    assert info["steps"] == 10        # only the remaining steps ran
    _params_equal(p_full, p_resumed)


def test_resume_skips_corrupt_checkpoint(tmp_path):
    tr, _ = _mk(tmp_path, "c")
    tr.run(max_steps=10)
    # corrupt the newest checkpoint (torn write on dying host)
    step = tr.store.latest_step()
    path = tr.store._step_dir(step) + "/arrays.npz"
    with open(path, "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 64)
    tr2, _ = _mk(tmp_path, "c")
    start, *_ = tr2.restore_or_init()
    assert start < step               # walked back to an older valid ckpt


def test_nan_guard_skips_update(tmp_path):
    cfg = get_smoke("paper_tiny_lm")
    model = LM(cfg)

    class PoisonModel:
        cfg = model.cfg

        def loss_fn(self, params, batch):
            loss, m = model.loss_fn(params, batch)
            # poison: NaN loss when flag set
            loss = jnp.where(batch["poison"], jnp.nan, loss)
            return loss, m

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(PoisonModel(), opt))
    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks, "poison": jnp.asarray(True)}
    p1, o1, _, m = step(params, opt_state, jnp.zeros(()), batch)
    assert float(m["skipped"]) == 1.0
    _params_equal(params, p1)         # untouched
    batch["poison"] = jnp.asarray(False)
    p2, _, _, m2 = step(params, opt_state, jnp.zeros(()), batch)
    assert float(m2["skipped"]) == 0.0


def test_microbatch_accumulation_close_to_full_batch(tmp_path):
    cfg = get_smoke("paper_tiny_lm")
    model = LM(cfg)
    opt = AdamW(lr=1e-3, clip_norm=None)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(2), (8, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    s1 = jax.jit(make_train_step(model, opt, microbatches=1))
    s4 = jax.jit(make_train_step(model, opt, microbatches=4))
    p1, *_ = s1(params, opt.init(params), jnp.zeros(()), batch)
    p4, *_ = s4(params, opt.init(params), jnp.zeros(()), batch)
    # mean-of-microbatch grads == full-batch grads (same tokens/weights)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-5)


def test_straggler_abort_checkpoints(tmp_path, monkeypatch):
    tr, _ = _mk(tmp_path, "s", total_steps=200,
                straggler_factor=0.0,     # every step is a "straggler"
                straggler_abort=2)
    with pytest.raises(StragglerError):
        tr.run()
    assert tr.straggler_events >= 2
    # it checkpointed before dying → a new trainer resumes
    tr2, _ = _mk(tmp_path, "s", total_steps=200, straggler_abort=10**9)
    start, *_ = tr2.restore_or_init()
    assert start > 0


def test_grad_compression_trains(tmp_path):
    """int8 EF-compressed grads still reduce the loss (error feedback)."""
    tr, _ = _mk(tmp_path, "g", total_steps=40, grad_compression=True)
    tr.run()
    lines = [json.loads(line) for line in open(tr.metrics_path)]
    assert lines[-1]["loss"] < lines[0]["loss"]


def test_mesh_headsplit_parity():
    """ROADMAP head-split hazard, TRAINING path: on a 2x4 mesh where the
    model axis would split a head (d_model=64, 2 heads, hd=32 -> 16
    columns/shard), the jax 0.4.x CPU partitioner mis-executes the
    rope/attention chain.  The Trainer now shards with the param_specs
    whole-heads guard (head_dim=cfg.hd) — mesh losses must track the
    single-device run step for step.  Subprocess for the same reason as
    test_dist.py: the parent must keep its single CPU device."""
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", ""))
    code = """
        import jax, numpy as np
        from repro.data import DataPipeline
        from repro.dist import use_mesh
        from repro.models import LM
        from repro.models.base import ArchConfig
        from repro.optim import AdamW
        from repro.train.loop import make_train_step
        from repro.dist.sharding import shard_params

        cfg = ArchConfig(name="headsplit", family="dense", num_layers=2,
                         d_model=64, num_heads=2, num_kv_heads=2,
                         d_ff=128, vocab_size=128, period=("attn",),
                         mlp_kind="swiglu", dtype="float32")
        model = LM(cfg)
        pipe = DataPipeline(cfg, global_batch=4, seq_len=32, seed=0)
        opt = AdamW(lr=1e-3)
        step_fn = make_train_step(model, opt)

        def losses(mesh, **kw):
            params = model.init(jax.random.key(0))
            if mesh is not None:
                params = shard_params(params, mesh,
                                      fsdp_axes=("data",), **kw)
            state = opt.init(params)
            ef = jax.numpy.zeros(())
            jstep = jax.jit(step_fn)
            out = []
            for s in range(5):
                params, state, ef, m = jstep(params, state, ef,
                                             pipe.batch_at(s))
                out.append(float(m["loss"]))
            return out

        base = losses(None)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            guarded = losses(mesh, head_dim=cfg.hd)   # Trainer's layout
        err = max(abs(a - b) for a, b in zip(base, guarded))
        assert err < 1e-4, f"guarded mesh training diverged: {err}"
        print("OK", err)
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout
