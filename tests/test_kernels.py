"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _make_24_sparse(key, k, n, dtype):
    w = jax.random.normal(key, (k, n)).astype(dtype)
    gt = w.reshape(k // 4, 4, n).transpose(0, 2, 1)
    _, idx = jax.lax.top_k(-jnp.abs(gt.astype(jnp.float32)), 2)
    mask = jax.nn.one_hot(idx, 4).sum(-2) > 0
    return jnp.where(mask, 0, gt).transpose(0, 2, 1).reshape(k, n)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("k,n,m", [(128, 128, 64), (256, 192, 96),
                                   (64, 320, 8), (512, 128, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nm_spmm_sweep(k, n, m, dtype):
    key = jax.random.key(k + n + m)
    wg = _make_24_sparse(key, k, n, dtype)
    vals, idx = ops.compress_24(wg)
    # roundtrip
    np.testing.assert_allclose(
        np.asarray(ref.decompress_24(vals, idx), np.float32),
        np.asarray(wg, np.float32))
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k)).astype(dtype)
    got = ops.nm_matmul(x, vals, idx, out_dtype=jnp.float32)
    want = ref.nm_spmm_ref(x, vals, idx)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 8)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("m,t", [(32, 128), (96, 320), (128, 128),
                                 (70, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hessian_sweep(m, t, dtype):
    x = jax.random.normal(jax.random.key(m * t), (m, t)).astype(dtype)
    got = ops.hessian_xxt(x)
    want = ref.hessian_accum_ref(x)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("r,c", [(16, 32), (48, 64), (128, 128), (33, 20)])
def test_nm_select_sweep(r, c):
    key = jax.random.key(r * c)
    w = jax.random.normal(key, (r, c))
    a = jax.random.normal(jax.random.fold_in(key, 1), (c, c))
    hinv = a @ a.T / c + jnp.eye(c)
    got = ops.nm_select_mask(w, hinv)
    want = ref.nm_select_ref(w, hinv)
    assert bool(jnp.all(got == want))
    # validity: exactly 2 pruned per group of 4
    assert (np.asarray(got).reshape(r, c // 4, 4).sum(-1) == 2).all()


# ----------------------------------------------------------------------
@pytest.mark.parametrize("bh,t,d", [(2, 128, 32), (4, 256, 64), (1, 384, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attn_sweep(bh, t, d, causal):
    key = jax.random.key(bh * t + d)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (bh, t, d))
               for i in range(3))
    got = ops.attention(q, k, v, causal=causal)
    want = ref.flash_attn_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attn_bf16():
    key = jax.random.key(9)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                 (2, 128, 64)).astype(jnp.bfloat16)
               for i in range(3))
    got = ops.attention(q, k, v, causal=True)
    want = ref.flash_attn_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


# ----------------------------------------------------------------------
def _paged_setup(key, b, kv, g, hd, n_pages, ps, pmax, dtype=jnp.float32):
    q = jax.random.normal(key, (b, kv, g, hd)).astype(dtype)
    kp = jax.random.normal(jax.random.fold_in(key, 1),
                           (n_pages, ps, kv, hd)).astype(dtype)
    vp = jax.random.normal(jax.random.fold_in(key, 2),
                           (n_pages, ps, kv, hd)).astype(dtype)
    # distinct physical pages per request, in logical order
    bt = np.zeros((b, pmax), np.int32)
    pid = 1
    rng = np.random.default_rng(7)
    lengths = rng.integers(0, pmax * ps + 1, size=b)
    for i in range(b):
        for j in range(-(-int(lengths[i]) // ps)):
            bt[i, j] = pid
            pid += 1
    assert pid <= n_pages
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths, jnp.int32)


@pytest.mark.parametrize("b,kv,g,hd,ps,pmax", [
    (3, 2, 2, 16, 8, 3), (2, 1, 4, 32, 16, 2), (4, 4, 1, 64, 8, 4)])
def test_paged_attn_kernel_vs_ref(b, kv, g, hd, ps, pmax):
    key = jax.random.key(b * hd + ps)
    q, kp, vp, bt, lengths = _paged_setup(key, b, kv, g, hd,
                                          b * pmax + 1, ps, pmax)
    got = ops.paged_attention(q, kp, vp, bt, lengths, use_kernel=True)
    want = ref.paged_attn_ref(q, kp, vp, bt, lengths)
    live = np.asarray(lengths) > 0
    np.testing.assert_allclose(np.asarray(got)[live],
                               np.asarray(want)[live],
                               rtol=2e-5, atol=2e-5)
    # idle slots (length 0) come back as exact zeros from the kernel
    assert (np.asarray(got)[~live] == 0).all()


def test_paged_attn_kernel_windowed():
    key = jax.random.key(42)
    q, kp, vp, bt, lengths = _paged_setup(key, 3, 2, 2, 16, 10, 8, 3)
    got = ops.paged_attention(q, kp, vp, bt, lengths, window=5,
                              use_kernel=True)
    want = ref.paged_attn_ref(q, kp, vp, bt, lengths, window=5)
    live = np.asarray(lengths) > 0
    np.testing.assert_allclose(np.asarray(got)[live],
                               np.asarray(want)[live],
                               rtol=2e-5, atol=2e-5)


def test_fused_sample_step():
    """The fused sample/record/advance step (serve.fused, ISSUE-5): a
    3-step device burst on the tiny LM's paged cache must emit exactly
    the tokens of three standalone decode_step + argmax rounds.  Under
    JAX_PALLAS_INTERPRET=1 (the CI kernel step) the burst's
    paged_attention dispatch runs the Pallas kernel BODY in interpret
    mode — the fused loop is exercised over the kernel, not just the
    jnp oracle."""
    from repro.configs import get_config
    from repro.models import LM
    from repro.serve import fused

    cfg = get_config("paper_tiny_lm")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    params["unembed"]["head"] = params["unembed"]["head"] * 8.0
    ps = 8
    prompt = np.asarray([1, 2, 3, 4, 5], np.int32)
    L = len(prompt)
    bt = np.zeros((1, 4), np.int32)
    bt[0, 0] = 1                                  # page 0 is scrap
    toks = np.zeros((1, 8), np.int32)
    toks[0, :L] = prompt

    def prefilled():
        kv = model.init_paged_cache(4, ps)
        lg, kv = model.prefill_paged(
            params, {"tokens": jnp.asarray(toks)}, kv,
            lengths=jnp.asarray([L], jnp.int32),
            block_tables=jnp.asarray(bt), page_size=ps)
        return jnp.argmax(lg, -1).astype(jnp.int32), kv

    # reference: standalone decode_step + argmax, per step
    tok, kv = prefilled()
    want = []
    for step in range(3):
        lg, kv = model.decode_step(
            params, tok, kv, jnp.asarray([L + step], jnp.int32),
            paged={"block_tables": jnp.asarray(bt)}, page_size=ps)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        want.append(int(tok[0]))

    # fused: one 3-step burst, single readback
    tok, kv = prefilled()
    burst = fused.make_continuous_burst(model, ps, temperature=0.0,
                                        top_k=None, top_p=None,
                                        eos_id=None)
    state = fused.init_burst_state(1, 3)
    state["tok"][0] = int(tok[0])
    state["pos"][0] = L
    state["n_tok"][0] = 1
    state["max_new"][0] = 10
    state["steps_left"] = np.asarray(3, np.int32)
    _, st = burst(params, kv, jnp.asarray(bt), state, jax.random.key(0))
    st = jax.device_get(st)
    assert int(st["n_out"][0]) == 3
    assert st["out"][0].tolist() == want
    assert int(st["pos"][0]) == L + 3 and not bool(st["done"][0])


def test_paged_attn_default_dispatch():
    """Default dispatch matches the oracle.  On plain CPU this is the
    oracle vs itself (trivially exact); under JAX_PALLAS_INTERPRET=1
    (the CI tier-1 kernel step) the default dispatch runs the Pallas
    kernel BODY in interpret mode — exercising kernels/paged_attn.py
    logic, not just the jnp shortcut, on CPU-only runners."""
    key = jax.random.key(3)
    q, kp, vp, bt, lengths = _paged_setup(key, 3, 2, 2, 16, 10, 8, 3)
    got = ops.paged_attention(q, kp, vp, bt, lengths)
    want = ref.paged_attn_ref(q, kp, vp, bt, lengths)
    live = np.asarray(lengths) > 0
    tol = 0.0 if not ops.dispatch_mode().force_pallas else 2e-5
    np.testing.assert_allclose(np.asarray(got)[live],
                               np.asarray(want)[live],
                               rtol=tol, atol=tol)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", [(1, 128, 128), (3, 256, 384),
                                   (8, 200, 256), (5, 132, 64)])
@pytest.mark.parametrize("act", [None, "silu", "gelu"])
@pytest.mark.parametrize("with_bias", [True, False])
def test_nm_spmm_decode_sweep(m, k, n, act, with_bias):
    """Decode-shaped nm_spmm (ISSUE-9): skinny M (every decode burst is
    one), fused bias+activation epilogue, and K/N off the 128 tile
    (200, 132, 64 — the wrapper zero-pads).  The kernel body must match
    the decompress oracle; the oracle itself (the CPU serving path) is
    exact vs ref by construction."""
    key = jax.random.key(m * 7 + k + n)
    wg = _make_24_sparse(key, k, n, jnp.float32)
    vals, idx = ops.compress_24(wg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k))
    bias = (jax.random.normal(jax.random.fold_in(key, 2), (n,))
            if with_bias else None)
    want = ref.nm_spmm_ref(x, vals, idx, bias=bias, activation=act)
    got = ops.nm_matmul(x, vals, idx, bias, activation=act,
                        use_kernel=True, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    # the jnp-oracle dispatch (CPU serving) is the ref path verbatim
    oracle = ops.nm_matmul(x, vals, idx, bias, activation=act,
                           use_kernel=False, out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(oracle), np.asarray(want))


# ----------------------------------------------------------------------
def test_kv_int8_parity(tiny_lm):
    """int8 per-page KV quantization (ISSUE-9, serve/kvpool.py
    ``kv_dtype="int8"``): on the TRAINED tiny config the greedy streams
    must be EXACTLY the fp32-KV streams — per-row quantization error is
    ~6e-3 relative while a trained model's argmax gaps are orders of
    magnitude larger, so a token flip here indicates a scale/gather
    bug, not rounding.  (An untrained random init has genuine near-tie
    logits that quantization legitimately flips — general checkpoints
    are held to the documented stream-agreement tolerance by the
    serve_throughput kv_int8 leg instead.)  Also the capacity
    acceptance: int8 resolves 2x the KV pages at no more pool bytes,
    and every allocated page ticks kv_quant_pages."""
    from repro.serve import Request, ServeEngine

    model, params, _ = tiny_lm
    reqs = [Request(uid=i,
                    prompt=np.asarray([2, 4, 6, 8][: 2 + i], np.int32),
                    max_new_tokens=5 + i) for i in range(3)]

    fp = ServeEngine(model, params, max_batch=2, max_len=32, page_size=8,
                     kv_dtype="fp32")
    q8 = ServeEngine(model, params, max_batch=2, max_len=32, page_size=8,
                     kv_dtype="int8")
    # page 0 is scrap; int8 pages cost half, so capacity doubles
    assert (q8.config.resolved_num_pages() - 1
            == 2 * (fp.config.resolved_num_pages() - 1))

    r_fp = fp.generate(reqs)
    r_q8 = q8.generate(reqs)
    for a, b in zip(r_fp, r_q8):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert q8.stats["kv_quant_pages"] > 0
    assert fp.stats["kv_quant_pages"] == 0


def test_dispatch_override():
    """override_dispatch scopes dispatch without mutating module state
    (the ISSUE-7 replacement for tests poking ops.INTERPRET /
    ops.FORCE_PALLAS globals): the ambient mode is resolved per call,
    overrides nest and unwind, and force_pallas=True routes the default
    paged_attention dispatch through the kernel body."""
    ambient = ops.dispatch_mode()
    with ops.override_dispatch(force_pallas=True) as m:
        assert m.force_pallas and ops.dispatch_mode() is m
        # unspecified fields inherit the ambient mode
        assert m.interpret == ambient.interpret
        with ops.override_dispatch(force_pallas=False) as inner:
            assert not ops.dispatch_mode().force_pallas
            assert inner.interpret == ambient.interpret
        assert ops.dispatch_mode() is m
    assert ops.dispatch_mode() == ambient

    # forced dispatch takes the kernel body: interpret-mode numerics
    # differ from the oracle only within float tolerance
    key = jax.random.key(3)
    q, kp, vp, bt, lengths = _paged_setup(key, 3, 2, 2, 16, 10, 8, 3)
    want = ref.paged_attn_ref(q, kp, vp, bt, lengths)
    with ops.override_dispatch(interpret=True, force_pallas=True):
        got = ops.paged_attention(q, kp, vp, bt, lengths)
    live = np.asarray(lengths) > 0
    np.testing.assert_allclose(np.asarray(got)[live],
                               np.asarray(want)[live],
                               rtol=2e-5, atol=2e-5)
