"""Serving engine + the 2:4-sparse weight path."""

import jax
import numpy as np

from repro.core import PruningEngine
from repro.data import calibration_batches
from repro.serve import Request, ServeEngine, sparsify_params


def test_greedy_generation_deterministic(tiny_lm):
    model, params, _ = tiny_lm
    eng = ServeEngine(model, params, max_batch=4, max_len=64)
    reqs = [Request(uid=i, prompt=np.arange(4 + i, dtype=np.int32),
                    max_new_tokens=6) for i in range(3)]
    r1 = eng.generate(reqs)
    r2 = eng.generate(reqs)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert len(a.tokens) == 6


def test_batched_equals_single(tiny_lm):
    """Batch-of-3 greedy decode == each request decoded alone."""
    model, params, _ = tiny_lm
    prompts = [np.asarray([1, 2, 3, 4], np.int32),
               np.asarray([9, 8, 7, 6], np.int32),
               np.asarray([5, 5, 5, 5], np.int32)]
    eng = ServeEngine(model, params, max_batch=3, max_len=48)
    batched = eng.generate(
        [Request(uid=i, prompt=p, max_new_tokens=5)
         for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        solo = eng.generate([Request(uid=0, prompt=p, max_new_tokens=5)])
        np.testing.assert_array_equal(batched[i].tokens, solo[0].tokens)


def test_eos_stops_early(tiny_lm):
    model, params, _ = tiny_lm
    eng = ServeEngine(model, params, max_batch=2, max_len=64)
    # find the greedy first token, then use it as "eos"
    probe = eng.generate(
        [Request(uid=0, prompt=np.asarray([3, 1], np.int32),
                 max_new_tokens=1)])
    eos = int(probe[0].tokens[0])
    eng2 = ServeEngine(model, params, max_batch=2, max_len=64, eos_id=eos)
    res = eng2.generate(
        [Request(uid=0, prompt=np.asarray([3, 1], np.int32),
                 max_new_tokens=8)])
    assert len(res[0].tokens) == 1 and int(res[0].tokens[0]) == eos


def test_sparse_serving_matches_dense(tiny_lm):
    """2:4-prune → pack → nm_spmm serving path produces the SAME greedy
    tokens as the dense pruned model (kernel integration end-to-end)."""
    model, params, _ = tiny_lm
    calib = calibration_batches(model.cfg, n_samples=8, seq_len=64, batch=8)
    eng = PruningEngine(model, "2:4", method="SM", blocksize=64)
    pruned, _ = eng.run(params, calib)
    packed = sparsify_params(pruned, patterns=(r"mlp/(wi|wg|wo)$",))

    # packed leaves actually exist (layer-stacked: one per linear kind)
    n_packed = sum(1 for leaf in jax.tree.leaves(
        packed, is_leaf=lambda x: isinstance(x, dict) and "vals" in x)
        if isinstance(leaf, dict) and "vals" in leaf)
    assert n_packed == 3

    prompts = [np.asarray([2, 4, 6, 8], np.int32)]
    dense_eng = ServeEngine(model, pruned, max_batch=1, max_len=32)
    sparse_eng = ServeEngine(model, packed, max_batch=1, max_len=32)
    d = dense_eng.generate([Request(0, prompts[0], max_new_tokens=4)])
    s = sparse_eng.generate([Request(0, prompts[0], max_new_tokens=4)])
    np.testing.assert_array_equal(d[0].tokens, s[0].tokens)


def test_sparsify_skips_non_sparse(tiny_lm):
    """Dense (unpruned) weights must pass through unpacked."""
    model, params, _ = tiny_lm
    packed = sparsify_params(params)
    assert not any(
        isinstance(leaf, dict) and "vals" in leaf
        for leaf in jax.tree.leaves(
            packed, is_leaf=lambda x: isinstance(x, dict) and "vals" in x))


def test_temperature_sampling_runs(tiny_lm):
    model, params, _ = tiny_lm
    eng = ServeEngine(model, params, max_batch=2, max_len=48,
                      temperature=1.0)
    res = eng.generate([Request(uid=0, prompt=np.asarray([1, 2], np.int32),
                                max_new_tokens=5)])
    assert len(res[0].tokens) == 5
