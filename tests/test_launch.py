"""Launch layer: build_lowerable + compile on a small virtual mesh
(subprocess — the main test process keeps its single CPU device)."""

from test_dist import run_with_devices


def test_lower_compile_smoke_cells():
    """Every shape kind lowers AND compiles for a smoke config on a 2×4
    mesh — the same code path the 512-chip dry-run exercises."""
    run_with_devices("""
        import jax, dataclasses
        import numpy as np
        from repro import configs as cfglib
        from repro.dist import cost_analysis_dict, use_mesh
        from repro.launch.dryrun import build_lowerable, OptFlags
        from repro.utils.hlo import collective_bytes

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(
            cfglib.get_smoke("qwen3_14b"), name="launch-smoke")
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            fn, args, shardings, model = build_lowerable(
                "qwen3_14b", shape, mesh, cfg_override=cfg,
                opt=OptFlags.level(6))
            with use_mesh(mesh):
                compiled = jax.jit(
                    fn, in_shardings=shardings).lower(*args).compile()
            cost = cost_analysis_dict(compiled)
            assert float(cost.get("flops", 0)) > 0
            stats = collective_bytes(compiled.as_text(), trip_counts=(2,))
            print(shape, "ok", stats.total_count, "collectives")
        print("OK")
    """, n=8)


def test_mesh_functions_pure():
    """make_production_mesh is a function (importing launch.mesh must not
    initialize jax devices) and axes match the spec."""
    run_with_devices("""
        import repro.launch.mesh as m   # import BEFORE any jax device use
        mesh = m.make_production_mesh()
        assert mesh.axis_names == ("data", "model")
        assert mesh.devices.shape == (16, 16), mesh.devices.shape
        assert m.dp_axes_of(mesh) == ("data",)
        print("OK")
    """, n=512)


def test_multi_pod_mesh_axes():
    run_with_devices("""
        import repro.launch.mesh as m
        mesh = m.make_production_mesh(multi_pod=True)
        assert mesh.axis_names == ("pod", "data", "model")
        assert m.dp_axes_of(mesh) == ("pod", "data")
        print("OK")
    """, n=512)
