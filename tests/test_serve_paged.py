"""Continuous-batching serve runtime: paged KV pool, scheduler, engine.

Covers the ISSUE-3/ISSUE-4/ISSUE-5 acceptance surface: pool alloc/
release/preemption unit behavior, paged-vs-dense decode and chunked-
prefill bit-parity (greedy, CPU), continuous-vs-static engine
equivalence (attention, Mamba, xLSTM and hybrid archs — no static
fallback; plain, under a mesh, and with 2:4-sparse weights), top-k/
top-p sampling determinism under the per-(uid, step) key scheme, the
recurrent-state slot pool, the Result utilization accounting, and the
device-resident fused decode loop (ISSUE-5): ``steps_per_sync=1`` vs
``=8`` token bit-parity across greedy/top-k/top-p, preemption-
recompute, EOS mid-burst, host-sync accounting, the non-preempting
burst page lookahead, and a 2x4-mesh subprocess run.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.models import LM
from repro.models.base import ArchConfig
from repro.serve import (PagedKVPool, Request, Scheduler, SeqState,
                         ServeEngine, StatePool)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# jamba-shaped hybrid (mamba + attention interleave) WITHOUT MoE —
# expert-capacity dropping is what keeps real Jamba on the static path,
# so this pins the hybrid continuous-batching mechanics separately
HYBRID = ArchConfig(
    name="hybrid-serve-test",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    period=("mamba", "attn"),
    mlp_kind="swiglu",
    ssm_mlp=True,
    ssm_state=4,
    ssm_conv=4,
    dtype="float32",
)


def _sharpened(cfg, seed=0):
    """Random-init model with a sharpened head: greedy argmax gaps wide
    enough to be robust to chunked-vs-dense reduction-order rounding."""
    model = LM(cfg)
    params = model.init(jax.random.key(seed))
    if cfg.tie_embeddings:
        params["embed"]["tok"] = params["embed"]["tok"] * 8.0
    else:
        params["unembed"]["head"] = params["unembed"]["head"] * 8.0
    return model, params


@pytest.fixture(scope="module")
def tiny_random():
    """Random-init full tiny LM with a sharpened head: greedy argmax
    gaps are wide enough to be robust to sharding reduction order."""
    cfg = get_config("paper_tiny_lm")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    params["unembed"]["head"] = params["unembed"]["head"] * 8.0
    return model, params


def _mixed_requests(vocab, n=10):
    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                prompt=rng.integers(0, vocab, size=(4, 7, 12)[i % 3],
                                    dtype=np.int32),
                max_new_tokens=(2, 5, 9, 14)[i % 4])
        for i in range(n)
    ]


# ======================================================================
# kvpool
# ======================================================================
def test_pool_alloc_release(tiny_random):
    model, _ = tiny_random
    pool = PagedKVPool(model, num_pages=9, page_size=8, max_slots=4,
                       max_len=32)
    assert pool.capacity == 8 and pool.free_pages == 8
    a = pool.alloc(3)
    assert len(a) == 3 and 0 not in a          # page 0 is scrap
    b = pool.alloc(5)
    assert pool.free_pages == 0
    assert pool.alloc(1) is None               # exhausted, all-or-nothing
    pool.release(b)
    assert pool.free_pages == 5
    c = pool.alloc(5)
    assert sorted(c) == sorted(b)
    # n=0 must not touch the free list ([-0:] slices everything)
    assert pool.alloc(0) == []
    assert pool.free_pages == 0


def test_pool_block_tables(tiny_random):
    model, _ = tiny_random
    pool = PagedKVPool(model, num_pages=9, page_size=8, max_slots=2,
                       max_len=32)
    pages = pool.alloc(2)
    pool.assign(0, pages)
    assert pool.slot_page_count(0) == 2
    assert pool.slot_pages(0) == pages
    np.testing.assert_array_equal(pool.block_tables[0, :2], pages)
    pool.clear_slot(0)
    assert pool.slot_page_count(0) == 0
    assert (pool.block_tables[0] == 0).all()
    assert pool.free_pages == 8
    pool.reset()
    assert pool.free_pages == 8


# ======================================================================
# scheduler
# ======================================================================
def _sched(model, num_pages=17, page_size=8, max_slots=2, max_len=64):
    pool = PagedKVPool(model, num_pages=num_pages, page_size=page_size,
                       max_slots=max_slots, max_len=max_len)
    return Scheduler(pool, max_slots), pool


def test_scheduler_admission_and_retire(tiny_random):
    model, _ = tiny_random
    sched, pool = _sched(model)
    seqs = [sched.submit(Request(uid=i, prompt=np.arange(6, dtype=np.int32)))
            for i in range(3)]
    admitted = sched.admit()
    assert [s.req.uid for s in admitted] == [0, 1]   # 2 slots, FIFO
    # admitted requests enter PREFILL; the engine feeds prompt chunks
    assert all(s.state is SeqState.PREFILL for s in admitted)
    assert sched.next_prefill() is admitted[0]       # oldest first
    assert sched.decoding() == []
    assert pool.free_pages == pool.capacity - 2      # 1 prompt page each
    sched.finish(seqs[0])                            # retire-at-EOS
    assert seqs[0].state is SeqState.FINISHED
    assert [s.req.uid for s in sched.admit()] == [2]  # slot recycled
    assert sched.has_work()


def test_scheduler_preempts_youngest(tiny_random):
    model, _ = tiny_random
    # 4 pages: two 1-page prompts admit, then growth exhausts the pool
    sched, pool = _sched(model, num_pages=5, page_size=8)
    a = sched.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32)))
    b = sched.submit(Request(uid=1, prompt=np.arange(8, dtype=np.int32)))
    assert len(sched.admit()) == 2
    for s, n in ((a, 8), (b, 8)):
        s.state = SeqState.RUNNING                   # prefill done
        s.n_prefilled = n
        s.n_written = n
        s.tokens = [1]
    pool.alloc(pool.free_pages)                      # drain the free list
    sched.ensure_decode_capacity()
    # the OLDEST request got the victim's page; the youngest re-queued
    assert a.state is SeqState.RUNNING
    assert pool.slot_page_count(a.slot) == 2
    assert b.state is SeqState.WAITING
    assert b.preemptions == 1 and b.n_written == 0 and b.tokens == []
    assert b.n_prefilled == 0                        # recompute from scratch
    assert sched.waiting[0] is b                     # front of the queue


def test_scheduler_single_request_exhaustion(tiny_random):
    model, _ = tiny_random
    sched, pool = _sched(model, num_pages=2, page_size=8, max_slots=1)
    a = sched.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32)))
    assert sched.admit() == [a]
    a.state = SeqState.RUNNING
    a.n_written = 8
    with pytest.raises(RuntimeError, match="exhausted"):
        sched.ensure_decode_capacity()


def test_scheduler_oversized_prompt_raises(tiny_random):
    model, _ = tiny_random
    sched, _ = _sched(model, num_pages=3, page_size=8, max_len=64)
    sched.submit(Request(uid=0, prompt=np.zeros(40, np.int32)))
    with pytest.raises(RuntimeError, match="prompt needs"):
        sched.admit()


# ======================================================================
# engine: paged vs dense equivalence
# ======================================================================
def test_continuous_matches_static_greedy(tiny_random):
    model, params = tiny_random
    reqs = _mixed_requests(model.cfg.vocab_size)
    static = ServeEngine(model, params, max_batch=4, max_len=48,
                         mode="static")
    cont = ServeEngine(model, params, max_batch=4, max_len=48,
                       mode="continuous", page_size=8)
    rs = static.generate(reqs)
    rc = cont.generate(reqs)
    for a, b in zip(rs, rc):
        assert a.uid == b.uid
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_paged_decode_bit_parity(tiny_random):
    """Model-level: paged prefill+decode logits are BIT-identical to the
    dense cache path (greedy CPU acceptance criterion)."""
    model, params = tiny_random
    ps = 8
    prompt = np.asarray([1, 2, 3, 4, 5], np.int32)
    L = len(prompt)

    cache = model.init_cache(1, 48)
    lg, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, cache)
    dense = [np.asarray(lg[0])]
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    for step in range(6):
        lg, cache = model.decode_step(params, tok, cache,
                                      jnp.asarray(L + step, jnp.int32))
        dense.append(np.asarray(lg[0]))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)

    kv = model.init_paged_cache(12, ps)
    bt = np.zeros((1, 6), np.int32)
    bt[0, 0] = 3
    toks = np.zeros((1, 8), np.int32)
    toks[0, :L] = prompt
    lg, kv = model.prefill_paged(
        params, {"tokens": jnp.asarray(toks)}, kv,
        lengths=jnp.asarray([L], jnp.int32),
        block_tables=jnp.asarray(bt), page_size=ps)
    paged = [np.asarray(lg[0])]
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    n = L
    for step in range(6):
        if n // ps >= 1 and bt[0, n // ps] == 0:
            bt[0, n // ps] = 5 + n // ps
        lg, kv = model.decode_step(
            params, tok, kv, jnp.asarray([n], jnp.int32),
            paged={"block_tables": jnp.asarray(bt)}, page_size=ps)
        paged.append(np.asarray(lg[0]))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        n += 1

    for d, p in zip(dense, paged):
        np.testing.assert_array_equal(d, p)


def test_preemption_reproduces_tokens(tiny_random):
    """A pool too small for the full workload forces preemptions; the
    recompute must reproduce the exact static tokens.  (num_pages=6:
    the prefill-fused K=8 bursts retire short requests within one
    interval and recycle their pages at the sync, so an 8-page pool no
    longer comes under enough step-one pressure to preempt.)"""
    model, params = tiny_random
    reqs = _mixed_requests(model.cfg.vocab_size)
    static = ServeEngine(model, params, max_batch=4, max_len=48,
                         mode="static")
    small = ServeEngine(model, params, max_batch=4, max_len=48,
                        mode="continuous", page_size=8, num_pages=6)
    rs = static.generate(reqs)
    rp = small.generate(reqs)
    assert sum(r.preemptions for r in rp) > 0
    for a, b in zip(rs, rp):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_continuous_eos_stops_early(tiny_random):
    model, params = tiny_random
    eng = ServeEngine(model, params, max_batch=2, max_len=64, page_size=8)
    probe = eng.generate(
        [Request(uid=0, prompt=np.asarray([3, 1], np.int32),
                 max_new_tokens=1)])
    eos = int(probe[0].tokens[0])
    eng2 = ServeEngine(model, params, max_batch=2, max_len=64,
                       page_size=8, eos_id=eos)
    res = eng2.generate(
        [Request(uid=0, prompt=np.asarray([3, 1], np.int32),
                 max_new_tokens=8)])
    assert len(res[0].tokens) == 1 and int(res[0].tokens[0]) == eos


def test_continuous_temperature_deterministic(tiny_random):
    """Per-(uid, step) sampling keys: the same request sampled alone or
    in a batch draws the same stream."""
    model, params = tiny_random
    eng = ServeEngine(model, params, max_batch=4, max_len=48,
                      temperature=1.0, page_size=8)
    reqs = _mixed_requests(model.cfg.vocab_size, n=4)
    batched = eng.generate(reqs, seed=7)
    solo = eng.generate([reqs[2]], seed=7)
    np.testing.assert_array_equal(batched[2].tokens, solo[0].tokens)


def test_utilization_accounting(tiny_random):
    """Satellite: Result.decode_steps exposes the static scrap waste
    that continuous batching recovers."""
    model, params = tiny_random
    reqs = [Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=2),
            Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=12)]
    rs = ServeEngine(model, params, max_batch=2, max_len=32,
                     mode="static").generate(reqs)
    rc = ServeEngine(model, params, max_batch=2, max_len=32,
                     mode="continuous", page_size=8).generate(reqs)
    # static: the short request holds its slot for all 12 bucket steps
    assert rs[0].decode_steps == 12
    assert rs[0].utilization == pytest.approx(2 / 12)
    assert rs[1].utilization == 1.0
    # continuous: every occupied step emits a token
    assert rc[0].decode_steps == 2 and rc[0].utilization == 1.0
    assert rc[1].utilization == 1.0


def test_zero_max_new_tokens_matches_static(tiny_random):
    model, params = tiny_random
    reqs = [Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=0),
            Request(uid=1, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=3)]
    rs = ServeEngine(model, params, max_batch=2, max_len=32,
                     mode="static").generate(reqs)
    rc = ServeEngine(model, params, max_batch=2, max_len=32,
                     mode="continuous", page_size=8).generate(reqs)
    assert len(rs[0].tokens) == 0 and len(rc[0].tokens) == 0
    np.testing.assert_array_equal(rs[1].tokens, rc[1].tokens)


# ======================================================================
# chunked paged prefill
# ======================================================================
def test_prefill_chunk_bit_parity(tiny_random):
    """Model-level: streaming a prompt through fixed-size prefill_chunk
    calls yields final logits BIT-identical to the dense prefill."""
    model, params = tiny_random
    prompt = np.asarray([5, 4, 3, 2, 1, 9, 8, 7, 6, 2, 3], np.int32)
    L = len(prompt)
    cache = model.init_cache(1, 48)
    want, _ = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, cache)

    ps, C = 8, 4
    kv = model.init_paged_cache(12, ps)
    bt = np.zeros((1, 6), np.int32)
    bt[0, 0], bt[0, 1] = 3, 5
    step = jax.jit(model.prefill_chunk, static_argnames=("page_size",))
    got = None
    for start in range(0, L, C):
        chunk = np.zeros((1, C), np.int32)
        piece = prompt[start:start + C]
        chunk[0, :len(piece)] = piece
        got, kv = step(
            params, {"tokens": jnp.asarray(chunk)}, kv,
            jnp.asarray(start, jnp.int32), jnp.asarray(L, jnp.int32),
            jnp.asarray(0, jnp.int32), jnp.asarray(bt), page_size=ps)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_multi_chunk_prefill_matches_static(tiny_random):
    """Engine-level: a chunk smaller than most prompts (every request
    takes 2-3 chunks) still emits the static greedy tokens."""
    model, params = tiny_random
    reqs = _mixed_requests(model.cfg.vocab_size)
    rs = ServeEngine(model, params, max_batch=4, max_len=48,
                     mode="static").generate(reqs)
    rc = ServeEngine(model, params, max_batch=4, max_len=48,
                     mode="continuous", page_size=8,
                     prefill_chunk=4).generate(reqs)
    for a, b in zip(rs, rc):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_chunked_prefill_occupies_steps(tiny_random):
    """A multi-chunk prompt holds its slot for every chunk step — the
    utilization accounting stays honest about prefill occupancy."""
    model, params = tiny_random
    reqs = [Request(uid=0, prompt=np.arange(12, dtype=np.int32),
                    max_new_tokens=4)]
    res = ServeEngine(model, params, max_batch=2, max_len=32,
                      mode="continuous", page_size=8,
                      prefill_chunk=4).generate(reqs)
    # 3 prefill chunks (the last samples token 0) + 3 decode steps
    assert res[0].decode_steps == 6
    assert res[0].utilization == pytest.approx(4 / 6)


# ======================================================================
# recurrent-state paging (Mamba / xLSTM / hybrid)
# ======================================================================
@pytest.mark.parametrize("arch", ["mamba", "xlstm", "hybrid"])
def test_recurrent_arch_continuous_matches_static(arch):
    """Mamba/xLSTM/hybrid archs serve through mode="continuous" (no
    static fallback) with greedy tokens identical to the dense-cache
    static path — multi-chunk prefills included."""
    if arch == "mamba":
        from repro.configs.paper_tiny_lm import MAMBA as cfg
    elif arch == "xlstm":
        cfg = get_smoke("xlstm_350m")
    else:
        cfg = HYBRID
    model, params = _sharpened(cfg)
    reqs = _mixed_requests(cfg.vocab_size, n=6)
    rs = ServeEngine(model, params, max_batch=4, max_len=48,
                     mode="static").generate(reqs)
    eng = ServeEngine(model, params, max_batch=4, max_len=48,
                      mode="continuous", page_size=8, prefill_chunk=8)
    assert eng.mode == "continuous"          # no fallback
    rc = eng.generate(reqs)
    for a, b in zip(rs, rc):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_recurrent_preemption_reproduces_tokens():
    """Hybrid arch under a starved pool: preemption drops pages AND
    state rows; the recompute (fresh state reset at re-admission)
    reproduces the static tokens exactly."""
    model, params = _sharpened(HYBRID)
    reqs = _mixed_requests(HYBRID.vocab_size, n=8)
    rs = ServeEngine(model, params, max_batch=4, max_len=48,
                     mode="static").generate(reqs)
    small = ServeEngine(model, params, max_batch=4, max_len=48,
                        mode="continuous", page_size=8, prefill_chunk=8,
                        num_pages=6)
    rp = small.generate(reqs)
    assert sum(r.preemptions for r in rp) > 0
    for a, b in zip(rs, rp):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_state_pool_resets_slot_rows():
    from repro.configs.paper_tiny_lm import MAMBA

    model = LM(MAMBA)
    pool = StatePool(model, max_slots=3)
    assert pool.has_state
    kv = model.init_paged_cache(4, 8, max_slots=3)
    # dirty every slot row of every state leaf
    dirty = jax.tree.map(lambda x: x + 7.0, kv)
    clean = pool.reset_slot(dirty, 1)
    for leaf, ref in zip(jax.tree.leaves(clean), jax.tree.leaves(kv)):
        # slot 1 restored to init, slots 0/2 still dirty (leading dim is
        # the scan layer stack; slots live on dim 1)
        np.testing.assert_array_equal(np.asarray(leaf[:, 1]),
                                      np.asarray(ref[:, 1]))
        assert not np.array_equal(np.asarray(leaf[:, 0]),
                                  np.asarray(ref[:, 0]))


def test_attention_arch_has_no_state_pool(tiny_random):
    model, _ = tiny_random
    assert not StatePool(model, max_slots=2).has_state


# ======================================================================
# top-k / top-p sampling
# ======================================================================
@pytest.mark.parametrize("kw", [dict(temperature=1.0, top_k=20),
                                dict(temperature=0.8, top_p=0.9)])
def test_topk_topp_deterministic_and_preemption_exact(tiny_random, kw):
    """Per-(uid, step) keys thread through top-k/p filtering: the same
    request draws the same stream alone or batched, and a preempted
    request's recompute replays it bit-exact."""
    model, params = tiny_random
    reqs = _mixed_requests(model.cfg.vocab_size, n=8)
    eng = ServeEngine(model, params, max_batch=4, max_len=48,
                      page_size=8, prefill_chunk=8, **kw)
    batched = eng.generate(reqs, seed=7)
    solo = eng.generate([reqs[2]], seed=7)
    np.testing.assert_array_equal(batched[2].tokens, solo[0].tokens)
    small = ServeEngine(model, params, max_batch=4, max_len=48,
                        page_size=8, prefill_chunk=8, num_pages=6, **kw)
    rp = small.generate(reqs, seed=7)
    assert sum(r.preemptions for r in rp) > 0
    for a, b in zip(batched, rp):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_topk_restricts_support(tiny_random):
    """top_k=1 must reduce to greedy regardless of temperature."""
    model, params = tiny_random
    reqs = _mixed_requests(model.cfg.vocab_size, n=4)
    greedy = ServeEngine(model, params, max_batch=4, max_len=48,
                         page_size=8).generate(reqs)
    k1 = ServeEngine(model, params, max_batch=4, max_len=48, page_size=8,
                     temperature=3.0, top_k=1).generate(reqs, seed=11)
    for a, b in zip(greedy, k1):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_moe_arch_falls_back_to_static():
    """MoE expert-capacity dropping makes logits batch-dependent, so the
    continuous path's parity guarantees can't hold — must fall back."""
    from repro.configs import get_smoke

    model = LM(get_smoke("phi3_5_moe_42b_a6_6b"))
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=2, max_len=32,
                      mode="continuous")
    assert eng.mode == "static"


# ======================================================================
# device-resident fused decode loop (ISSUE-5, serve.fused)
# ======================================================================
def test_fused_burst_parity_greedy(tiny_random):
    """steps_per_sync=1 and =8 emit bit-identical greedy tokens (and
    match static): the burst length is a dynamic field of the state
    blob, so every K runs the same compiled fused body.  The burst
    engine must also sync the host strictly less often per token."""
    model, params = tiny_random
    reqs = _mixed_requests(model.cfg.vocab_size)
    rs = ServeEngine(model, params, max_batch=4, max_len=48,
                     mode="static").generate(reqs)
    stats = {}
    for sps in (1, 8):
        eng = ServeEngine(model, params, max_batch=4, max_len=48,
                          page_size=8, steps_per_sync=sps)
        rc = eng.generate(reqs)
        stats[sps] = dict(eng.stats)
        for a, b in zip(rs, rc):
            assert a.uid == b.uid
            np.testing.assert_array_equal(a.tokens, b.tokens)
    total = sum(len(r.tokens) for r in rs)
    assert stats[1]["tokens"] == stats[8]["tokens"] == total
    # the whole point of the burst: fewer blocking readbacks per token
    assert stats[8]["host_syncs"] < stats[1]["host_syncs"]
    # per-step mode syncs at least once per decode step
    assert stats[1]["host_syncs"] >= stats[1]["device_steps"]


@pytest.mark.parametrize("kw", [dict(temperature=1.0, top_k=20),
                                dict(temperature=0.8, top_p=0.9)])
def test_fused_burst_parity_sampled(tiny_random, kw):
    """top-k / top-p streams are steps_per_sync-independent (the fused
    step draws under the same per-(uid, step) keys), including across
    preemption-recompute under a starved pool."""
    model, params = tiny_random
    reqs = _mixed_requests(model.cfg.vocab_size, n=8)
    base = ServeEngine(model, params, max_batch=4, max_len=48,
                       page_size=8, steps_per_sync=1,
                       **kw).generate(reqs, seed=7)
    burst = ServeEngine(model, params, max_batch=4, max_len=48,
                        page_size=8, steps_per_sync=8,
                        **kw).generate(reqs, seed=7)
    for a, b in zip(base, burst):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    small = ServeEngine(model, params, max_batch=4, max_len=48,
                        page_size=8, num_pages=6, steps_per_sync=8, **kw)
    rp = small.generate(reqs, seed=7)
    assert sum(r.preemptions for r in rp) > 0
    for a, b in zip(base, rp):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_fused_burst_eos_mid_burst(tiny_random):
    """A request hitting EOS inside a burst freezes on device (its
    remaining burst steps treat the slot idle) and retires at the next
    sync with exactly the per-step loop's tokens."""
    model, params = tiny_random
    probe = ServeEngine(model, params, max_batch=2, max_len=64,
                        page_size=8).generate(
        [Request(uid=0, prompt=np.asarray([3, 1], np.int32),
                 max_new_tokens=1)])
    eos = int(probe[0].tokens[0])
    reqs = [Request(uid=0, prompt=np.asarray([3, 1], np.int32),
                    max_new_tokens=12),
            Request(uid=1, prompt=np.asarray([5, 2, 4], np.int32),
                    max_new_tokens=12)]
    r1 = ServeEngine(model, params, max_batch=2, max_len=64, page_size=8,
                     eos_id=eos, steps_per_sync=1).generate(reqs)
    r8 = ServeEngine(model, params, max_batch=2, max_len=64, page_size=8,
                     eos_id=eos, steps_per_sync=8).generate(reqs)
    for a, b in zip(r1, r8):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    # uid 0 really stopped at EOS, mid-burst
    assert len(r8[0].tokens) == 1 and int(r8[0].tokens[0]) == eos


def test_fused_burst_recurrent_arch():
    """The jamba-shaped hybrid through 8-step bursts: recurrent-state
    rows advance inside the device loop (idle rows frozen by the pos<0
    mask) with tokens identical to per-step mode.  (Mamba/xLSTM run
    the burst default in test_recurrent_arch_continuous_matches_static
    already — this pins the K-independence explicitly on a hybrid.)"""
    model, params = _sharpened(HYBRID)
    reqs = _mixed_requests(HYBRID.vocab_size, n=6)
    r1 = ServeEngine(model, params, max_batch=4, max_len=48,
                     page_size=8, prefill_chunk=8,
                     steps_per_sync=1).generate(reqs)
    r8 = ServeEngine(model, params, max_batch=4, max_len=48,
                     page_size=8, prefill_chunk=8,
                     steps_per_sync=8).generate(reqs)
    for a, b in zip(r1, r8):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_static_fused_early_exit_variants(tiny_random):
    """Static mode: the no-EOS equal-max_new bucket takes the fori
    variant (no done bookkeeping at all — the satellite fast path), the
    mixed bucket the while variant; both match continuous."""
    model, params = tiny_random
    prompts = [np.arange(4, dtype=np.int32) + i for i in range(3)]
    equal = [Request(uid=i, prompt=p, max_new_tokens=6)
             for i, p in enumerate(prompts)]
    eng = ServeEngine(model, params, max_batch=4, max_len=32,
                      mode="static")
    rs = eng.generate(equal)
    assert set(eng._static_bursts) == {False}       # fori path only
    rc = ServeEngine(model, params, max_batch=4, max_len=32,
                     mode="continuous", page_size=8).generate(equal)
    for a, b in zip(rs, rc):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    mixed = [Request(uid=i, prompt=p, max_new_tokens=4 + 3 * i)
             for i, p in enumerate(prompts)]
    rs = eng.generate(mixed)
    assert set(eng._static_bursts) == {False, True}  # while path now too
    rc = ServeEngine(model, params, max_batch=4, max_len=32,
                     mode="continuous", page_size=8).generate(mixed)
    for a, b in zip(rs, rc):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_extend_capacity_never_preempts(tiny_random):
    """Burst page lookahead shortens the burst instead of evicting: with
    pages for only 2 more tokens, extend_decode_capacity(8) maps what it
    can, returns the safe burst length, and preempts nobody."""
    model, _ = tiny_random
    # capacity 4: one 1-page prompt + 1 free page after admission
    sched, pool = _sched(model, num_pages=5, page_size=8, max_slots=2,
                         max_len=64)
    a = sched.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                             max_new_tokens=32))
    b = sched.submit(Request(uid=1, prompt=np.arange(8, dtype=np.int32),
                             max_new_tokens=32))
    assert len(sched.admit()) == 2
    for s in (a, b):
        s.state = SeqState.RUNNING
        s.n_prefilled = s.n_written = 8
        s.tokens = [1]
    # 2 pages free: an 8-step burst needs one more page per seq — fits
    k = sched.extend_decode_capacity(8)
    assert k == 8
    assert pool.slot_page_count(a.slot) == 2
    assert pool.free_pages == 0
    # pool now dry: each seq has 2*8 - 8 = 8 writable positions, so a
    # 24-step burst clamps to 8 — and NOBODY gets preempted
    k = sched.extend_decode_capacity(24)
    assert k == 8
    assert a.state is SeqState.RUNNING and b.state is SeqState.RUNNING
    assert a.preemptions == 0 and b.preemptions == 0
    assert not sched.waiting


def test_tables_device_row_update(tiny_random):
    """The device block-table mirror is resident: mutations scatter only
    the dirty rows (no full re-upload), and the mirror always matches
    the host tables."""
    model, _ = tiny_random
    pool = PagedKVPool(model, num_pages=9, page_size=8, max_slots=3,
                       max_len=32)
    t0 = pool.tables_device()
    np.testing.assert_array_equal(np.asarray(t0), pool.block_tables)
    assert pool.tables_device() is t0                # steady state: reused
    pages = pool.alloc(2)
    pool.assign(1, pages)
    t1 = pool.tables_device()
    assert t1 is not t0
    np.testing.assert_array_equal(np.asarray(t1), pool.block_tables)
    pool.clear_slot(1)
    np.testing.assert_array_equal(np.asarray(pool.tables_device()),
                                  pool.block_tables)


def test_fused_burst_2x4_mesh():
    """The device-resident burst under a real 2x4 mesh (state blob
    placed by dist.sharding.decode_state_specs): steps_per_sync=8
    serving emits the same greedy tokens as single-device per-step mode
    (subprocess, as in test_dist.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = """
        import jax, numpy as np
        from repro.configs import get_config
        from repro.models import LM
        from repro.dist import use_mesh
        from repro.serve import Request, ServeEngine

        cfg = get_config("paper_tiny_lm")
        model = LM(cfg)
        params = model.init(jax.random.key(0))
        params["unembed"]["head"] = params["unembed"]["head"] * 8.0
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=(4, 8)[i % 2],
                                            dtype=np.int32),
                        max_new_tokens=(3, 6, 10)[i % 3])
                for i in range(8)]
        base = ServeEngine(model, params, max_batch=4, max_len=48,
                           mode="continuous", page_size=8,
                           steps_per_sync=1).generate(reqs)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            eng = ServeEngine(model, params, max_batch=4, max_len=48,
                              mode="continuous", page_size=8,
                              steps_per_sync=8)
            got = eng.generate(reqs)
        assert eng.stats["host_syncs"] < eng.stats["device_steps"] + \\
            len(reqs) + 8, "burst mode must not sync per step"
        for a, b in zip(base, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        print("OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout


# ======================================================================
# equivalence under a mesh / with sparse weights
# ======================================================================
def test_continuous_matches_static_host_mesh(tiny_random):
    from repro.dist import make_host_mesh, use_mesh

    model, params = tiny_random
    reqs = _mixed_requests(model.cfg.vocab_size, n=6)
    base = ServeEngine(model, params, max_batch=4, max_len=48,
                       mode="static").generate(reqs)
    with use_mesh(make_host_mesh()):
        got = ServeEngine(model, params, max_batch=4, max_len=48,
                          mode="continuous", page_size=8).generate(reqs)
    for a, b in zip(base, got):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_continuous_matches_static_2x4_mesh():
    """Real multi-device equivalence (subprocess: the parent must keep
    its single CPU device, as in test_dist.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = """
        import jax, numpy as np
        from repro.configs import get_config
        from repro.models import LM
        from repro.dist import use_mesh
        from repro.serve import Request, ServeEngine

        cfg = get_config("paper_tiny_lm")
        model = LM(cfg)
        params = model.init(jax.random.key(0))
        params["unembed"]["head"] = params["unembed"]["head"] * 8.0
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=(4, 8)[i % 2],
                                            dtype=np.int32),
                        max_new_tokens=(3, 6, 10)[i % 3])
                for i in range(8)]
        nomesh = ServeEngine(model, params, max_batch=4, max_len=48,
                             mode="continuous", page_size=8).generate(reqs)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            static = ServeEngine(model, params, max_batch=4, max_len=48,
                                 mode="static").generate(reqs)
            cont = ServeEngine(model, params, max_batch=4, max_len=48,
                               mode="continuous", page_size=8
                               ).generate(reqs)
        for a, b, c in zip(static, cont, nomesh):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(a.tokens, c.tokens)
        print("OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout


def test_recurrent_continuous_2x4_mesh():
    """State-pool placement (paged_state_block_specs) on a real 2x4
    mesh: Mamba continuous serving emits the same greedy tokens as
    single-device (subprocess, as in test_dist.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = """
        import jax, numpy as np
        from repro.configs.paper_tiny_lm import MAMBA
        from repro.models import LM
        from repro.dist import use_mesh
        from repro.serve import Request, ServeEngine

        model = LM(MAMBA)
        params = model.init(jax.random.key(0))
        params["unembed"]["head"] = params["unembed"]["head"] * 8.0
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, MAMBA.vocab_size,
                                            size=(4, 9)[i % 2],
                                            dtype=np.int32),
                        max_new_tokens=(3, 6)[i % 2])
                for i in range(4)]
        base = ServeEngine(model, params, max_batch=2, max_len=32,
                           mode="continuous", page_size=8,
                           prefill_chunk=8).generate(reqs)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            got = ServeEngine(model, params, max_batch=2, max_len=32,
                              mode="continuous", page_size=8,
                              prefill_chunk=8).generate(reqs)
        for a, b in zip(base, got):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        print("OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "OK" in out.stdout


def test_continuous_with_sparse_weights(tiny_lm):
    """2:4-prune → pack → nm_spmm path through the PAGED runtime emits
    the same greedy tokens as the static engine on the same weights."""
    from repro.core import PruningEngine
    from repro.data import calibration_batches
    from repro.serve import sparsify_params

    model, params, _ = tiny_lm
    calib = calibration_batches(model.cfg, n_samples=8, seq_len=64, batch=8)
    eng = PruningEngine(model, "2:4", method="SM", blocksize=64)
    pruned, _ = eng.run(params, calib)
    packed = sparsify_params(pruned, patterns=(r"mlp/(wi|wg|wo)$",))

    reqs = [Request(uid=i, prompt=np.asarray([2, 4, 6, 8], np.int32),
                    max_new_tokens=4 + i) for i in range(3)]
    rs = ServeEngine(model, packed, max_batch=2, max_len=32,
                     mode="static").generate(reqs)
    rc = ServeEngine(model, packed, max_batch=2, max_len=32,
                     mode="continuous", page_size=8).generate(reqs)
    for a, b in zip(rs, rc):
        np.testing.assert_array_equal(a.tokens, b.tokens)
