"""Distributed pruning / collectives — run in a subprocess with 8 virtual
devices (XLA device count is locked at first jax init, so the main test
process must keep its single CPU device)."""

import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_row_parallel_prune_matches_single_device():
    """shard_map row-parallel MRP pruning == single-device result
    (Remark 4.2: rows are independent)."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import prune_matrix_sharded
        from repro.core.pruner import prune_matrix
        from repro.core.sparsity import SparsitySpec

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        n, m = 32, 64
        w = jax.random.normal(jax.random.key(0), (n, m))
        x = jax.random.normal(jax.random.key(1), (m, 4 * m))
        h = 2.0 * x @ x.T / (4 * m)

        for spec in ("2:4", "0.5"):
            w_sh, mask_sh = prune_matrix_sharded(
                w, h, spec, mesh, method="SM", blocksize=32)
            res = prune_matrix(w, h, SparsitySpec.parse(spec), method="SM",
                               blocksize=32, row_balanced=True)
            np.testing.assert_allclose(
                np.asarray(w_sh), np.asarray(res.w), atol=2e-4)
            np.testing.assert_array_equal(
                np.asarray(mask_sh), np.asarray(res.mask))
        print("OK")
    """)


def test_hessian_psum_across_data_shards():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import hessian_allreduce
        from repro.core.hessian import HessianAccumulator

        mesh = jax.make_mesh((8,), ("data",))
        m = 16
        xs = [jax.random.normal(jax.random.key(i), (m, 10 + 7 * i))
              for i in range(8)]
        accs = []
        for x in xs:
            a = HessianAccumulator(m); a.update(x); accs.append(a)
        ref = accs[0]
        for a in accs[1:]:
            ref = ref.merge(a)
        h_shards = jnp.stack([a.h for a in accs])
        counts = jnp.stack([a.count for a in accs])
        merged = hessian_allreduce(mesh, h_shards, counts)
        np.testing.assert_allclose(np.asarray(merged), np.asarray(ref.h),
                                   rtol=1e-4)
        print("OK")
    """)


def test_compressed_psum_close_to_exact():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist import shard_map
        from repro.optim.compression import compressed_psum

        mesh = jax.make_mesh((8,), ("pods",))
        n = 1024
        xs = jax.random.normal(jax.random.key(0), (8, n))

        def body(x):
            return compressed_psum(x[0], "pods")

        out = jax.jit(shard_map(
            body, mesh=mesh, in_specs=P("pods"), out_specs=P("pods"),
        ))(xs)
        got = np.asarray(out).reshape(8, -1)[0]
        want = np.asarray(xs.mean(0))
        # int8 quantization error ≈ amax/127 per element, two rounds
        scale = np.abs(np.asarray(xs)).max() / 127
        assert np.abs(got - want).max() < 4 * scale
        print("OK")
    """)


def test_moe_expert_parallel_matches_single_device():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_smoke
        from repro.dist.api import use_mesh
        from repro.models import LM

        cfg = get_smoke("phi3_5_moe_42b_a6_6b")
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        model = LM(cfg)
        params = model.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (4, 16), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        ref, _ = model.forward(params, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            dist, _ = jax.jit(model.forward)(params, batch)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(dist),
                                   atol=2e-3)
        print("OK")
    """)


def test_sharded_train_step_matches_single_device():
    """pjit on a 2×4 mesh == single-device step (same seed, same batch)."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.dist import use_mesh
        from repro.dist.sharding import batch_sharding, param_shardings
        from repro.models import LM
        from repro.optim import AdamW
        from repro.train import make_train_step

        cfg = get_smoke("qwen3_14b")
        model = LM(cfg)
        opt = AdamW(lr=1e-3)
        params = model.init(jax.random.key(0))
        opt_state = opt.init(params)
        toks = jax.random.randint(jax.random.key(1), (8, 16), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        step = make_train_step(model, opt)
        p_ref, o_ref, _, m_ref = jax.jit(step)(
            params, opt_state, jnp.zeros(()), batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        psh = param_shardings(params, mesh)
        bsh = batch_sharding(mesh)
        params_d = jax.device_put(params, psh)
        opt_d = type(opt_state)(
            jax.device_put(opt_state.step),
            jax.device_put(opt_state.mu, psh),
            jax.device_put(opt_state.nu, psh))
        batch_d = {k: jax.device_put(v, bsh) for k, v in batch.items()}
        with use_mesh(mesh):
            p_d, o_d, _, m_d = jax.jit(step)(
                params_d, opt_d, jnp.zeros(()), batch_d)
        assert abs(float(m_ref["loss"]) - float(m_d["loss"])) < 1e-4
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_d)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=5e-4)
        print("OK")
    """)


def test_sharded_calibration_matches_local_accumulation():
    """CalibrationSets accumulated per pod×data shard and merged with
    allreduce_calibration == one local accumulation over all tokens
    (the calibration-sharding path of core.pipeline)."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.calibration import CalibrationSet
        from repro.core.distributed import allreduce_calibration

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        m, key = 16, jax.random.key(0)
        shard_caps = []
        for s in range(8):
            k = jax.random.fold_in(key, s)
            x = jax.random.normal(k, (3 + s % 2, 5, m))   # uneven tokens
            wts = (jax.random.uniform(jax.random.fold_in(k, 1),
                                      x.shape[:-1]) > 0.3)
            shard_caps.append({
                "attn.wq": x,
                "moe.wi": (x * 0.5, wts.astype(jnp.float32)),
            })
        sets = [CalibrationSet.from_captures(c) for c in shard_caps]
        merged = allreduce_calibration(sets, mesh,
                                       axis_name=("pod", "data"))

        ref = CalibrationSet()
        for c in shard_caps:
            ref.update(c)
        for name in ("attn.wq", "moe.wi"):
            np.testing.assert_allclose(
                np.asarray(merged.hessian(name)),
                np.asarray(ref.hessian(name)), rtol=1e-4, atol=1e-5)
            np.testing.assert_allclose(
                float(merged.accs[name].count),
                float(ref.accs[name].count), rtol=1e-5)
        print("OK")
    """)


def test_pipelined_engine_sharded_calibration_matches_serial():
    """Whole-engine parity: pipelined run with calibration sharded over
    the pod×data axes of a (2, 2, 2) mesh == the serial single-device
    reference (float-tie mask flips only)."""
    run_with_devices("""
        import jax, numpy as np
        from repro.configs import get_smoke
        from repro.core import PruningEngine
        from repro.data import calibration_batches
        from repro.dist import use_mesh
        from repro.models import LM

        cfg = get_smoke("paper_tiny_lm")
        model = LM(cfg)
        params = model.init(jax.random.key(0))
        calib = calibration_batches(cfg, n_samples=64, seq_len=32, batch=8)

        ref, ref_reports = PruningEngine(
            model, "2:4", method="SM", blocksize=32,
            pipeline="off").run(params, calib)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        with use_mesh(mesh):
            eng = PruningEngine(model, "2:4", method="SM", blocksize=32,
                                calib_shard="on")
            got, reports = eng.run(params, calib)
        s = eng.last_pipeline_stats
        assert s.calib_shards == 4, s          # one per pod×data slice
        assert len(reports) == len(ref_reports)

        total = mismatched = 0
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            a = np.asarray(a, np.float32)
            b = np.asarray(b, np.float32)
            agree = (a == 0) == (b == 0)
            total += agree.size
            mismatched += int((~agree).sum())
        assert mismatched / total < 1e-3, (mismatched, total)
        print("OK")
    """)
