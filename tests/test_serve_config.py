"""ServeConfig (ISSUE-7 satellite): the one serve-knob surface.

Pins the validation messages, the default resolutions (pool size, swap
arena), the ``from_args`` CLI mapping through the real launcher parser,
and the ServeEngine intake back-compat contract — bare keywords, an
explicit config, and config + keyword overrides all land on the same
attributes."""

import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.models import LM
from repro.serve import ServeConfig, ServeEngine


# ---------------------------------------------------------------- validate
def test_defaults_validate():
    cfg = ServeConfig().validate()
    assert cfg.mode == "continuous"
    assert cfg.prefix_cache is True
    assert cfg.host_swap_pages is None      # → pool-sized arena


@pytest.mark.parametrize("field,value,msg", [
    ("mode", "turbo", "unknown serve mode"),
    ("max_batch", 0, "max_batch"),
    ("max_len", 0, "max_len"),
    ("page_size", 0, "page_size"),
    ("num_pages", 1, "num_pages"),
    ("prefill_chunk", 0, "prefill_chunk"),
    ("steps_per_sync", 0, "steps_per_sync"),
    ("temperature", -0.5, "temperature"),
    ("top_k", 0, "top_k"),
    ("top_p", 0.0, "top_p"),
    ("top_p", 1.5, "top_p"),
    ("host_swap_pages", -1, "host_swap_pages"),
    ("replicas", 0, "replicas"),
    ("queue_depth", 0, "queue_depth"),
])
def test_validate_rejects(field, value, msg):
    cfg = dataclasses.replace(ServeConfig(), **{field: value})
    with pytest.raises(ValueError, match=msg):
        cfg.validate()


def test_resolved_num_pages():
    cfg = ServeConfig(max_batch=4, max_len=100, page_size=16)
    # ceil(100/16)=7 pages per slot, x4 slots, +1 scrap
    assert cfg.resolved_num_pages() == 4 * 7 + 1
    assert dataclasses.replace(cfg, num_pages=9).resolved_num_pages() == 9


def test_resolved_swap_pages():
    cfg = ServeConfig(max_batch=2, max_len=32, page_size=16)
    assert cfg.resolved_swap_pages() == cfg.resolved_num_pages()
    assert dataclasses.replace(cfg, host_swap_pages=0
                               ).resolved_swap_pages() == 0
    assert dataclasses.replace(cfg, host_swap_pages=7
                               ).resolved_swap_pages() == 7


# ---------------------------------------------------------------- from_args
def _parse(argv):
    from repro.launch.serve import build_parser

    return ServeConfig.from_args(build_parser().parse_args(argv))


def test_from_args_defaults():
    cfg = _parse([])
    assert cfg == ServeConfig(max_len=128)   # launcher default max-len


def test_from_args_full_mapping():
    cfg = _parse([
        "--serve-mode", "continuous", "--max-batch", "4",
        "--max-len", "64", "--page-size", "8", "--num-pages", "33",
        "--prefill-chunk", "16", "--steps-per-sync", "4",
        "--no-prefix-cache", "--host-swap-pages", "12",
        "--replicas", "2", "--queue-depth", "16",
        "--sampling", "top-k", "--top-k", "7", "--temperature", "0.8",
    ])
    assert cfg == ServeConfig(
        max_batch=4, max_len=64, page_size=8, num_pages=33,
        prefill_chunk=16, steps_per_sync=4, prefix_cache=False,
        host_swap_pages=12, replicas=2, queue_depth=16,
        temperature=0.8, top_k=7)


def test_from_args_sampling_resolution():
    # non-greedy sampling with a zero temperature bumps to a live draw
    cfg = _parse(["--sampling", "top-p", "--top-p", "0.5"])
    assert cfg.temperature == 1.0 and cfg.top_p == 0.5 and cfg.top_k is None
    # greedy ignores the top-k/top-p flags entirely
    cfg = _parse(["--sampling", "greedy", "--top-k", "7"])
    assert cfg.top_k is None and cfg.temperature == 0.0


def test_from_args_validates():
    with pytest.raises(ValueError, match="num_pages"):
        _parse(["--num-pages", "1"])


# ------------------------------------------------------------ engine intake
@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("paper_tiny_lm")
    model = LM(cfg)
    return model, model.init(jax.random.key(0))


def test_engine_bare_keywords_backcompat(tiny):
    model, params = tiny
    eng = ServeEngine(model, params, max_batch=2, max_len=32,
                      page_size=8, mode="static")
    assert eng.config == ServeConfig(mode="static", max_batch=2,
                                     max_len=32, page_size=8)
    assert eng.mode == "static"
    assert eng.max_batch == 2 and eng.max_len == 32


def test_engine_explicit_config(tiny):
    model, params = tiny
    cfg = ServeConfig(max_batch=2, max_len=32, page_size=8,
                      num_pages=9, prefix_cache=True, host_swap_pages=4)
    eng = ServeEngine(model, params, cfg)
    assert eng.config is not cfg or eng.config == cfg
    assert eng.pool.num_pages == 9
    assert eng.pool.prefix is not None
    assert eng.pool.arena is not None and eng.pool.arena.capacity == 4


def test_engine_config_plus_overrides(tiny):
    model, params = tiny
    base = ServeConfig(max_batch=2, max_len=32, page_size=8)
    eng = ServeEngine(model, params, base, max_batch=3,
                      prefix_cache=False, host_swap_pages=0)
    assert eng.config.max_batch == 3                # override wins
    assert eng.config.max_len == 32                 # base survives
    assert base.max_batch == 2                      # base not mutated
    assert eng.pool.prefix is None and eng.pool.arena is None


def test_engine_rejects_bad_knobs(tiny):
    model, params = tiny
    with pytest.raises(ValueError, match="unknown serve mode"):
        ServeEngine(model, params, mode="warp")
    with pytest.raises(TypeError):
        ServeEngine(model, params, not_a_knob=1)


def test_engine_default_pool_sizing(tiny):
    model, params = tiny
    eng = ServeEngine(model, params, max_batch=2, max_len=32, page_size=8)
    cfg = eng.config
    assert eng.pool.num_pages == cfg.resolved_num_pages() == 2 * 4 + 1
    # swap defaults on, pool-sized
    assert eng.pool.arena is not None
    assert eng.pool.arena.capacity == cfg.resolved_swap_pages()
