"""Serving front end (ISSUE-6): SLA-aware admission, streaming
sessions, the HTTP/SSE server, and the multi-replica router.

Covers the satellite/acceptance surface: priority/deadline admission
order (high priority admitted ahead of older low-priority; FIFO when
unset), the ``max_waiting`` backpressure cap (QueueFull at the
documented depth; preemption re-queues exempt), bit-identical token
streams under priority reordering (per-(uid, step) key contract),
streaming-vs-batch parity through a real asyncio HTTP server (SSE
chunks arrive incrementally, concatenation matches ``generate()``,
greedy AND sampled), 2-replica router parity + least-loaded/failover/
drain semantics, and the prefill sync-floor fix (bursts stay > 1 under
prefill-heavy load in ``engine.stats``).
"""

import asyncio
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import LM
from repro.serve import (PagedKVPool, QueueFull, Request, Scheduler,
                         ServeEngine)
from repro.serve.frontend import (CompletionChunk, CompletionRequest,
                                  Replica, ReplicaDraining, Router, Server,
                                  sse_decode, sse_encode, to_engine_request)


@pytest.fixture(scope="module")
def tiny():
    """Sharpened random-init smoke LM (wide greedy argmax gaps)."""
    cfg = get_smoke("paper_tiny_lm")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    params["unembed"]["head"] = params["unembed"]["head"] * 8.0
    return model, params


def _engine(tiny, **kw):
    model, params = tiny
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 8)
    return ServeEngine(model, params, **kw)


def _reqs(vocab, n=8, max_new=(2, 5, 9, 14), **kw):
    rng = np.random.default_rng(0)
    return [
        Request(uid=i,
                prompt=rng.integers(0, vocab, size=(4, 7, 12)[i % 3],
                                    dtype=np.int32),
                max_new_tokens=max_new[i % len(max_new)], **kw)
        for i in range(n)
    ]


# ======================================================================
# scheduler: SLA-aware admission + backpressure
# ======================================================================
def _sched(tiny, max_slots=2, max_waiting=None):
    model, _ = tiny
    pool = PagedKVPool(model, num_pages=17, page_size=8,
                       max_slots=max_slots, max_len=64)
    return Scheduler(pool, max_slots, max_waiting=max_waiting)


def test_priority_admits_ahead_of_older_fifo(tiny):
    """A later high-priority request beats earlier low-priority ones to
    the only free slot."""
    sched = _sched(tiny, max_slots=1)
    p = np.arange(4, dtype=np.int32)
    sched.submit(Request(uid=0, prompt=p))
    sched.submit(Request(uid=1, prompt=p))
    sched.submit(Request(uid=2, prompt=p, priority=5))
    assert [s.req.uid for s in sched.waiting] == [2, 0, 1]
    assert [s.req.uid for s in sched.admit()] == [2]
    # FIFO resumes within the remaining equal-priority class
    assert sched.waiting[0].req.uid == 0


def test_deadline_orders_within_priority(tiny):
    """Earlier deadline first within a priority class; priority still
    dominates; no SLA fields = exact FIFO."""
    sched = _sched(tiny)
    p = np.arange(4, dtype=np.int32)
    sched.submit(Request(uid=0, prompt=p, deadline=90.0))
    sched.submit(Request(uid=1, prompt=p, deadline=10.0))
    sched.submit(Request(uid=2, prompt=p))            # no deadline: last
    sched.submit(Request(uid=3, prompt=p, priority=1, deadline=99.0))
    assert [s.req.uid for s in sched.waiting] == [3, 1, 0, 2]


def test_queue_depth_cap_rejects_and_preempt_exempt(tiny):
    """submit() raises QueueFull exactly past ``max_waiting``; a
    preemption re-queue is exempt (the victim already holds its place)
    and resumes ahead of later submissions."""
    sched = _sched(tiny, max_slots=2, max_waiting=2)
    p = np.arange(4, dtype=np.int32)
    sched.submit(Request(uid=0, prompt=p))
    sched.submit(Request(uid=1, prompt=p))
    assert [s.req.uid for s in sched.admit()] == [0, 1]   # queue drains
    sched.submit(Request(uid=2, prompt=p))
    sched.submit(Request(uid=3, prompt=p))
    with pytest.raises(QueueFull):
        sched.submit(Request(uid=4, prompt=p))
    victim = sched.running[-1]                            # uid 1
    sched.preempt(victim)                                 # cap-exempt
    assert len(sched.waiting) == 3
    # original arrival number: the victim sorts ahead of uids 2 and 3
    assert sched.waiting[0].req.uid == victim.req.uid


def test_session_submit_maps_cap_and_validation(tiny):
    eng = _engine(tiny)
    session = eng.session(max_waiting=1)
    reqs = _reqs(eng.model.cfg.vocab_size, n=3)
    session.submit(reqs[0])
    with pytest.raises(QueueFull):
        session.submit(reqs[1])
    with pytest.raises(ValueError):
        session.submit(Request(uid=9, prompt=np.arange(60, dtype=np.int32),
                               max_new_tokens=60))


def test_priority_streams_bit_identical_to_fifo(tiny):
    """Admission ORDER must never change a request's tokens: the same
    workload with priorities permuted (sampled top-k, so any key-
    contract breakage shows) yields per-uid identical streams."""
    kw = dict(temperature=0.9, top_k=20)
    fifo = _engine(tiny, **kw).generate(
        _reqs(tiny[0].cfg.vocab_size, n=8), seed=3)
    # reversed priorities + staggered deadlines: admission reorders
    prio = _reqs(tiny[0].cfg.vocab_size, n=8)
    for i, r in enumerate(prio):
        r.priority = i % 3
        r.deadline = 100.0 - i
    rp = _engine(tiny, **kw).generate(prio, seed=3)
    for a, b in zip(fifo, rp):
        assert a.uid == b.uid
        np.testing.assert_array_equal(a.tokens, b.tokens)


# ======================================================================
# sync-floor fix: bursts stay > 1 under prefill-heavy load
# ======================================================================
def test_prefill_fused_bursts_stay_above_one(tiny):
    """Prefill-heavy mixed load used to clamp every interval to one
    decode step per sync; with chunks fused into the burst body the
    device_steps / host_syncs ratio must stay well above 1."""
    eng = _engine(tiny, max_batch=4, steps_per_sync=8)
    reqs = _reqs(tiny[0].cfg.vocab_size, n=12,
                 max_new=(6, 10, 14, 18))     # prompts keep streaming in
    eng.generate(reqs)
    assert eng.stats["prefill_chunks"] >= 12  # it WAS prefill-heavy
    burst = eng.stats["device_steps"] / eng.stats["host_syncs"]
    assert burst > 1.5, eng.stats


# ======================================================================
# protocol
# ======================================================================
def test_protocol_roundtrip_and_validation():
    body = json.dumps({"prompt": [1, 2, 3], "max_tokens": 4,
                       "stream": True, "priority": 2,
                       "deadline_ms": 500.0, "uid": 7}).encode()
    creq = CompletionRequest.from_json(body)
    assert (creq.prompt, creq.max_tokens, creq.stream) == ([1, 2, 3], 4, True)
    req = to_engine_request(creq, uid=7, now=100.0)
    assert req.uid == 7 and req.priority == 2
    assert req.deadline == pytest.approx(100.5)
    for bad in (b"not json", b"[1,2]", b'{"prompt": []}',
                b'{"prompt": ["a"]}', b'{"prompt": [1], "max_tokens": 0}'):
        with pytest.raises(ValueError):
            CompletionRequest.from_json(bad)


def test_sse_roundtrip():
    chunks = [CompletionChunk(uid=1, tokens=[5, 6]),
              CompletionChunk(uid=1, tokens=[7], finished=True)]
    wire = b"".join(sse_encode(c) for c in chunks) + b"data: [DONE]\n\n"
    back = sse_decode(wire)
    assert [(c.uid, c.tokens, c.finished) for c in back] == \
           [(1, [5, 6], False), (1, [7], True)]


# ======================================================================
# replica + router
# ======================================================================
def test_router_two_replica_parity_sampled(tiny):
    """Acceptance: per-request streams are bit-identical to batch
    ServeEngine output regardless of which replica served them —
    sampled, so the shared-seed/per-(uid, step) contract is load-
    bearing, not just greedy argmax."""
    kw = dict(temperature=0.9, top_k=20)
    reqs = _reqs(tiny[0].cfg.vocab_size, n=8)
    ref = _engine(tiny, **kw).generate(reqs, seed=0)
    router = Router([Replica(_engine(tiny, **kw), name=f"r{i}", seed=0)
                     for i in range(2)])
    try:
        creqs = [CompletionRequest(prompt=[int(t) for t in r.prompt],
                                   max_tokens=r.max_new_tokens, uid=r.uid)
                 for r in reqs]
        out = router.complete(creqs)
        assert sorted({c.replica for c in out}) == ["r0", "r1"]
        for a, b in zip(ref, out):
            assert a.uid == b.uid
            assert list(a.tokens) == b.tokens
    finally:
        router.close()


def test_router_failover_and_drain(tiny):
    """A full replica fails over to the next; drain stops intake
    (ReplicaDraining) after finishing in-flight work."""
    r0 = Replica(_engine(tiny), name="r0", max_waiting=0)
    r1 = Replica(_engine(tiny), name="r1")
    router = Router([r0, r1])
    try:
        creq = CompletionRequest(prompt=[1, 2, 3], max_tokens=2, uid=0)
        out = router.complete([creq])
        assert out[0].replica == "r1"                 # r0 cap rejected
        assert router.drain(timeout=30)
        with pytest.raises((QueueFull, ReplicaDraining)):
            router.submit(CompletionRequest(prompt=[1], max_tokens=1,
                                            uid=1), lambda ev: None)
    finally:
        router.close()


def test_router_skips_unhealthy_replica(tiny):
    r0 = Replica(_engine(tiny), name="r0")
    r1 = Replica(_engine(tiny), name="r1")
    router = Router([r0, r1])
    try:
        r0.close()                                    # worker gone
        assert not r0.healthy and r1.healthy
        out = router.complete(
            [CompletionRequest(prompt=[1, 2], max_tokens=2, uid=0)])
        assert out[0].replica == "r1"
    finally:
        router.close()


# ======================================================================
# HTTP server: SSE streaming, parity, backpressure
# ======================================================================
async def _post(host, port, obj):
    body = json.dumps(obj).encode()
    r, w = await asyncio.open_connection(host, port)
    w.write(f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
            f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
    await w.drain()
    data = await r.read()
    w.close()
    head, _, rest = data.partition(b"\r\n\r\n")
    return int(head.split()[1]), rest


async def _get(host, port, path):
    r, w = await asyncio.open_connection(host, port)
    w.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    h = await r.read()
    w.close()
    head, _, rest = h.partition(b"\r\n\r\n")
    return int(head.split()[1]), rest


def test_http_streaming_matches_batch(tiny):
    """SSE chunks arrive incrementally (several frames per request, one
    per sync interval) and concatenate to exactly the batch engine's
    tokens; non-streaming calls return the same as JSON; /healthz and
    /stats respond; unknown routes 404."""
    reqs = _reqs(tiny[0].cfg.vocab_size, n=4, max_new=(9, 12, 7, 10))
    ref = _engine(tiny, steps_per_sync=2).generate(reqs, seed=0)
    router = Router([Replica(_engine(tiny, steps_per_sync=2), name="r0")])

    async def scenario():
        srv = Server(router, port=0)
        host, port = await srv.start()
        outs = await asyncio.gather(*[
            _post(host, port, {"prompt": [int(t) for t in r.prompt],
                               "max_tokens": r.max_new_tokens,
                               "uid": r.uid, "stream": True})
            for r in reqs])
        for r, (status, rest) in zip(reqs, outs):
            assert status == 200
            chunks = sse_decode(rest)
            assert chunks[-1].finished
            # incremental: steps_per_sync=2 forces multiple frames
            assert len(chunks) > 1
            toks = [t for c in chunks for t in c.tokens]
            want = next(x for x in ref if x.uid == r.uid)
            assert toks == list(want.tokens)
        status, body = await _post(
            host, port, {"prompt": [int(t) for t in reqs[0].prompt],
                         "max_tokens": reqs[0].max_new_tokens, "uid": 100})
        assert status == 200
        # same stream as uid 100 would get in batch mode (greedy: equal
        # to uid 0's reference tokens)
        assert json.loads(body)["tokens"] == list(ref[0].tokens)
        assert (await _post(host, port, {"prompt": "nope"}))[0] == 400
        status, body = await _get(host, port, "/healthz")
        assert status == 200 and json.loads(body)["r0"]["healthy"]
        assert (await _get(host, port, "/stats"))[0] == 200
        assert (await _get(host, port, "/nope"))[0] == 404
        await srv.shutdown(timeout=30)

    try:
        asyncio.run(scenario())
    finally:
        router.close()


def test_http_backpressure_429(tiny):
    """With a single slot and queue depth 1, a burst of concurrent
    long requests must see at least one 429 — and every accepted one
    still completes."""
    router = Router([Replica(_engine(tiny, max_batch=1), name="r0",
                             max_waiting=1)])

    async def scenario():
        srv = Server(router, port=0)
        host, port = await srv.start()
        outs = await asyncio.gather(*[
            _post(host, port, {"prompt": [1, 2, 3, i], "max_tokens": 20,
                               "uid": i})
            for i in range(6)])
        statuses = sorted(s for s, _ in outs)
        assert statuses[0] == 200 and statuses[-1] == 429, statuses
        for status, body in outs:
            if status == 200:
                assert len(json.loads(body)["tokens"]) == 20
        await srv.shutdown(timeout=30)

    try:
        asyncio.run(scenario())
    finally:
        router.close()
