"""End-to-end system behaviour: the paper's full workflow on CPU.

train → prune (the paper's SM) → evaluate ordering → pack 2:4 → serve.
Each stage consumes the previous stage's artifacts through the public
API, exactly like examples/ and the launch/ CLIs do.
"""

import jax
import jax.numpy as jnp
import numpy as np

from conftest import eval_ppl
from repro.core import PruningEngine
from repro.data import calibration_batches
from repro.serve import Request, ServeEngine, sparsify_params


def test_full_workflow(tiny_lm):
    model, params, pipe = tiny_lm
    dense_ppl = eval_ppl(model, params, pipe)
    assert dense_ppl < 15.0                     # the model actually trained

    calib = calibration_batches(model.cfg, n_samples=16, seq_len=64, batch=8)
    engine = PruningEngine(model, "2:4", method="SM", blocksize=64)
    pruned, reports = engine.run(params, calib)
    sm_ppl = eval_ppl(model, pruned, pipe)
    assert dense_ppl < sm_ppl < 3.0 * dense_ppl  # damaged but not destroyed

    packed = sparsify_params(pruned, patterns=(r"mlp/(wi|wg|wo)$",))
    eng = ServeEngine(model, packed, max_batch=2, max_len=48)
    res = eng.generate([
        Request(uid=0, prompt=np.asarray([1, 2, 3], np.int32),
                max_new_tokens=4),
        Request(uid=1, prompt=np.asarray([7, 8, 9], np.int32),
                max_new_tokens=4),
    ])
    assert all(len(r.tokens) == 4 for r in res)


def test_sparsity_is_real(tiny_lm):
    """After 2:4 pruning, every MLP/attn weight is ≥49% zeros."""
    model, params, _ = tiny_lm
    calib = calibration_batches(model.cfg, n_samples=8, seq_len=64, batch=8)
    engine = PruningEngine(model, "2:4", method="SM", blocksize=64)
    pruned, _ = engine.run(params, calib)
    flat = jax.tree_util.tree_flatten_with_path(pruned)[0]
    checked = 0
    for keypath, leaf in flat:
        path = "/".join(str(getattr(k, "key", k)) for k in keypath)
        if any(s in path for s in ("attn/w", "mlp/w")) and leaf.ndim >= 2:
            frac = float(jnp.mean(leaf == 0.0))
            assert frac >= 0.49, f"{path}: only {frac:.2%} zeros"
            checked += 1
    # layer-stacked params: one leaf covers all periods → 7 linear kinds
    assert checked >= 7
