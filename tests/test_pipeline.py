"""Pipelined calibration/solve scheduler (core.pipeline): equivalence
with the serial reference loop, resume-on-segment-boundary semantics,
and scheduler bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import PruneProgressStore
from repro.core import PruningEngine
from repro.core.engine import summarize
from repro.core.pipeline import SegmentScheduler, _resolve_shards
from repro.data import calibration_batches


@pytest.fixture(scope="module")
def calib(tiny_lm):
    model, params, pipe = tiny_lm
    return calibration_batches(model.cfg, n_samples=16, seq_len=64, batch=8)


def _leaves32(tree):
    return [np.asarray(x, np.float32) for x in jax.tree.leaves(tree)]


def test_pipelined_matches_serial(tiny_lm, calib):
    """Default (pipelined) engine == pipeline="off" reference on
    paper_tiny_lm.  The jitted batched capture fuses differently than
    the eager per-batch walk (and accumulates the Hessian in one update
    instead of a streaming mean), so float-level score ties may flip a
    tiny fraction of mask entries — the contract is ≥ 99.9% mask
    agreement, identical per-linear sparsity, and indistinguishable
    pruned-model quality."""
    from conftest import eval_ppl

    model, params, pipe = tiny_lm
    ref, ref_reports = PruningEngine(
        model, "2:4", method="SM", blocksize=64,
        pipeline="off").run(params, calib)
    eng = PruningEngine(model, "2:4", method="SM", blocksize=64)
    got, reports = eng.run(params, calib)

    total = mismatched = 0
    for a, b in zip(_leaves32(ref), _leaves32(got)):
        agree = (a == 0) == (b == 0)
        total += agree.size
        mismatched += int((~agree).sum())
    assert mismatched / total < 1e-3, f"{mismatched}/{total} mask flips"
    assert [r.name for r in reports] == [r.name for r in ref_reports]
    assert [r.sparsity for r in reports] == [r.sparsity for r in ref_reports]
    np.testing.assert_allclose(
        summarize(reports)["total_recon_error"],
        summarize(ref_reports)["total_recon_error"], rtol=0.05)
    ppl_ref, ppl_got = eval_ppl(model, ref, pipe), eval_ppl(model, got, pipe)
    assert abs(ppl_got - ppl_ref) / ppl_ref < 0.02
    s = eng.last_pipeline_stats
    assert s is not None
    assert s.segments == model.cfg.num_layers
    assert s.calib_shards == 1          # no mesh → local accumulation
    assert s.batches == len(calib)
    # all period segments share one capture + one propagate compile
    assert s.compiles == 2


def test_pipelined_unstructured_fallback(tiny_lm, calib):
    """Unstructured global top-k is not traceable — the pipelined path
    must fall back to the host solve and still match the serial loop."""
    model, params, pipe = tiny_lm
    ref, _ = PruningEngine(model, "0.5", method="SM", blocksize=64,
                           pipeline="off").run(params, calib)
    got, reports = PruningEngine(model, "0.5", method="SM",
                                 blocksize=64).run(params, calib)
    total = mismatched = 0
    for a, b in zip(_leaves32(ref), _leaves32(got)):
        agree = (a == 0) == (b == 0)
        total += agree.size
        mismatched += int((~agree).sum())
    assert mismatched / total < 1e-3, f"{mismatched}/{total} mask flips"
    assert abs(summarize(reports)["mean_sparsity"] - 0.5) < 0.02


def test_pipeline_resume_on_segment_boundary(tiny_lm, calib, tmp_path):
    """Interrupt mid-run → every checkpoint lands on a segment boundary
    (params identical to the uninterrupted run's state after the same
    number of segments) and the resumed run's final params are
    bit-identical to the uninterrupted run."""
    model, params, pipe = tiny_lm
    out = str(tmp_path / "prog")

    class Recorder:
        """In-memory progress store: snapshots every segment-boundary save."""

        def __init__(self):
            self.saves = []

        def load_into(self, template):
            return None

        def save(self, next_segment, p):
            self.saves.append((next_segment, _leaves32(p)))

        def finalize(self):
            pass

    rec = Recorder()
    ref_params, _ = PruningEngine(
        model, "2:4", method="SM", blocksize=64,
        progress_store=rec).run(params, calib)
    assert [s for s, _ in rec.saves] == list(
        range(1, model.cfg.num_layers + 1))

    class Bomb(PruneProgressStore):
        def __init__(self, root, fuse):
            super().__init__(root)
            self.fuse = fuse

        def save(self, next_segment, p):
            super().save(next_segment, p)
            self.fuse -= 1
            if self.fuse == 0:
                raise RuntimeError("simulated node failure")

    with pytest.raises(RuntimeError):
        PruningEngine(model, "2:4", method="SM", blocksize=64,
                      progress_store=Bomb(out, fuse=2)).run(params, calib)

    # the surviving checkpoint is exactly the uninterrupted run's state
    # at the same segment boundary (bit-identical)
    seg_idx, ckpt = PruneProgressStore(out).load_into(params)
    assert seg_idx == 2
    for a, b in zip(dict(rec.saves)[seg_idx], _leaves32(ckpt)):
        np.testing.assert_array_equal(a, b)

    res_params, reports = PruningEngine(
        model, "2:4", method="SM", blocksize=64,
        progress_store=PruneProgressStore(out)).run(params, calib)
    # only the remaining segments were pruned in the resumed run...
    assert len(reports) == (model.cfg.num_layers - seg_idx) * 7
    # ...and the final params are bit-identical to the uninterrupted run
    for a, b in zip(_leaves32(ref_params), _leaves32(res_params)):
        np.testing.assert_array_equal(a, b)


def test_scheduler_stacking_and_shard_resolution():
    """shard_states round-robins batches into stacked per-shard trees."""
    batches = [{"h": jnp.full((2, 3), float(i))} for i in range(6)]
    sched = SegmentScheduler(mesh=None, calib_shard=2)
    states = sched.shard_states(batches)
    assert len(states) == 2
    assert states[0]["h"].shape == (6, 3)
    np.testing.assert_array_equal(
        np.asarray(states[0]["h"][:, 0]), [0, 0, 2, 2, 4, 4])
    assert sched.stats.calib_shards == 2 and sched.stats.batches == 6

    # no mesh → "auto"/"on" degrade to local accumulation
    assert _resolve_shards("auto", None, (), 8) == 1
    assert _resolve_shards("on", None, (), 8) == 1
    assert _resolve_shards("off", None, (), 8) == 1
    # booleans alias on/off (and must not be swallowed by int handling)
    assert _resolve_shards(True, None, (), 8) == 1
    assert _resolve_shards(False, None, (), 8) == 1
    assert _resolve_shards(3, None, (), 8) == 3
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert _resolve_shards("auto", mesh, ("data",), 8) == 1
    with pytest.raises(ValueError):
        _resolve_shards("definitely", None, (), 8)


def test_engine_rejects_unknown_pipeline_mode(tiny_lm):
    model, params, pipe = tiny_lm
    with pytest.raises(ValueError):
        PruningEngine(model, "2:4", pipeline="sideways")
