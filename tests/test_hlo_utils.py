"""HLO collective parser (the roofline's collective-bytes source)."""

from repro.utils.hlo import collective_bytes, parse_collectives

HLO = """
HloModule jit_step
%all-reduce.1 = f32[256,4096]{1,0} all-reduce(%x), channel_id=1
%all-reduce.2 = (f32[8,16]{1,0}, bf16[4,4]{1,0}) all-reduce(%a, %b)
%all-gather.3 = bf16[1024,2816]{1,0} all-gather(%p), dimensions={0}
%all-to-all.4 = f32[64,32]{1,0} all-to-all(%q), dimensions={0}
%collective-permute.5 = bf16[128]{0} collective-permute(%r)
%reduce-scatter.6 = f32[32]{0} reduce-scatter(%s), dimensions={0}
%add.7 = f32[2,2]{1,0} add(%u, %v)
"""

SHLO = """
%0 = stablehlo.all_reduce(%arg0) : (tensor<512x1024xf32>) -> tensor<512x1024xf32>
%1 = stablehlo.all_gather(%arg1) : (tensor<16x8xbf16>) -> tensor<128x8xbf16>
"""


def test_parse_hlo_ops():
    recs = parse_collectives(HLO)
    ops = [r["op"] for r in recs]
    assert ops == ["all-reduce", "all-reduce", "all-gather", "all-to-all",
                   "collective-permute", "reduce-scatter"]
    b = {r["op"]: 0 for r in recs}
    for r in recs:
        b[r["op"]] += r["operand_bytes"]
    assert b["all-reduce"] == 256 * 4096 * 4 + (8 * 16 * 4 + 4 * 4 * 2)
    assert b["all-gather"] == 1024 * 2816 * 2
    assert b["all-to-all"] == 64 * 32 * 4
    assert b["collective-permute"] == 128 * 2
    assert b["reduce-scatter"] == 32 * 4


def test_aggregate_and_wire_multipliers():
    stats = collective_bytes(HLO)
    assert stats.total_count == 6
    # all-reduce rings move ~2× operand bytes
    ar = stats.operand_bytes["all-reduce"]
    assert stats.wire_bytes >= stats.total_bytes + ar - 1


def test_parse_stablehlo():
    recs = parse_collectives(SHLO)
    assert [r["op"] for r in recs] == ["all-reduce", "all-gather"]
    assert recs[0]["operand_bytes"] == 512 * 1024 * 4
    assert recs[1]["operand_bytes"] == 16 * 8 * 2


def test_no_false_positives():
    assert parse_collectives("%x = f32[8] add(%a, %b)\n") == []
