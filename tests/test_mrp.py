"""The paper's math: MRP closed-form solution (core.mrp) vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import random_psd_hessian
from repro.core import masks as masks_lib
from repro.core import mrp
from repro.core.hessian import dampened_inverse


def _random_mask(rng, n, m, max_k):
    mask = np.zeros((n, m), bool)
    for i in range(n):
        k = rng.integers(0, max_k + 1)
        cols = rng.choice(m, size=k, replace=False)
        mask[i, cols] = True
    return mask


@pytest.mark.parametrize("n,m,max_k", [(8, 32, 6), (16, 64, 16), (5, 48, 1)])
def test_mrp_compensate_matches_rowwise_oracle(rng, n, m, max_k):
    """Batched padded solve == literal per-row Eq. (13)/(12)."""
    key = jax.random.key(n * m)
    w = jax.random.normal(key, (n, m))
    hinv = np.linalg.inv(np.asarray(
        random_psd_hessian(jax.random.key(1), m), np.float64))
    mask = _random_mask(rng, n, m, max_k)

    w_new, loss = mrp.mrp_compensate_mask(
        w, jnp.asarray(hinv, jnp.float32), jnp.asarray(mask))
    w_new = np.asarray(w_new)
    for i in range(n):
        ref_row, ref_loss = mrp.mrp_row_reference(
            np.asarray(w)[i], hinv, np.where(mask[i])[0])
        np.testing.assert_allclose(w_new[i], ref_row, atol=2e-4)
        np.testing.assert_allclose(float(loss[i]), ref_loss, rtol=2e-3,
                                   atol=1e-5)


def test_pruned_slots_exactly_zero(rng):
    n, m = 12, 40
    w = jax.random.normal(jax.random.key(0), (n, m))
    h = random_psd_hessian(jax.random.key(1), m)
    hinv = dampened_inverse(h)
    mask = jnp.asarray(_random_mask(rng, n, m, 10))
    w_new, _ = mrp.mrp_compensate_mask(w, hinv, mask)
    assert jnp.all(jnp.where(mask, w_new, 0.0) == 0.0)
    # unpruned weights moved (compensation is active)
    assert float(jnp.abs(jnp.where(mask, 0.0, w_new - w)).max()) > 0


def test_row_chunking_equivalent(rng):
    n, m = 16, 32
    w = jax.random.normal(jax.random.key(2), (n, m))
    hinv = dampened_inverse(random_psd_hessian(jax.random.key(3), m))
    mask = jnp.asarray(_random_mask(rng, n, m, 8))
    w_a, l_a = mrp.mrp_compensate_mask(w, hinv, mask)
    w_b, l_b = mrp.mrp_compensate_mask(w, hinv, mask, row_chunk=4)
    np.testing.assert_allclose(np.asarray(w_a), np.asarray(w_b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_a), np.asarray(l_b), rtol=1e-4)


def test_srp_is_special_case():
    """Single pruned weight: MRP loss reduces to Eq. (14) = w²/(2·Hinv_jj)."""
    m = 24
    w = jax.random.normal(jax.random.key(4), (1, m))
    hinv = dampened_inverse(random_psd_hessian(jax.random.key(5), m))
    j = 7
    mask = jnp.zeros((1, m), bool).at[0, j].set(True)
    _, loss = mrp.mrp_compensate_mask(w, hinv, mask)
    expected = float(w[0, j]) ** 2 / (2.0 * float(hinv[j, j]))
    np.testing.assert_allclose(float(loss[0]), expected, rtol=1e-5)


def test_mrp_loss_beats_independent_srp_sum():
    """Eq. (12) with interactions ≤ sum of independent SRP losses is NOT
    generally true, but the achieved ‖δw x‖² of the JOINT solve must be ≤
    the error of applying SRP compensations independently (the paper's
    core advantage)."""
    m, n = 32, 6
    key = jax.random.key(6)
    w = jax.random.normal(key, (n, m))
    h = random_psd_hessian(jax.random.key(7), m)
    hinv = dampened_inverse(h, gamma=1e-4)
    rng = np.random.default_rng(1)
    mask = jnp.asarray(_random_mask(rng, n, m, 8))

    w_joint, _ = mrp.mrp_compensate_mask(w, hinv, mask)

    # independent SRP: each pruned weight compensated in isolation, summed
    w_srp = np.asarray(w, np.float64).copy()
    hinv64 = np.asarray(hinv, np.float64)
    for i, j in zip(*np.where(np.asarray(mask))):
        delta = -(float(w[i, j]) / hinv64[j, j]) * hinv64[j, :]
        w_srp[i] += delta
    w_srp[np.asarray(mask)] = 0.0

    h64 = np.asarray(h, np.float64)

    def recon(wn):
        d = np.asarray(wn, np.float64) - np.asarray(w, np.float64)
        return 0.5 * np.einsum("ij,jk,ik->", d, h64, d)

    assert recon(w_joint) <= recon(w_srp) + 1e-9


def test_nm_group_losses_and_mask():
    """Eq. (12) combo enumeration: losses positive, mask = argmin combo,
    exactly N pruned per group."""
    n, m = 10, 32
    w = jax.random.normal(jax.random.key(8), (n, m))
    hinv = dampened_inverse(random_psd_hessian(jax.random.key(9), m))
    losses = mrp.nm_group_losses(w, hinv, 2, 4)
    assert losses.shape == (n, 8, 6)
    assert bool(jnp.all(losses > 0))
    mask = mrp.select_nm_mask_mrp(w, hinv, 2, 4)
    assert masks_lib.validate_nm(np.asarray(mask), 2, 4)
    # chosen combo == argmin of enumerated losses
    best = jnp.argmin(losses, axis=-1)
    combos = mrp.nm_combinations(2, 4)
    chosen = combos[best]
    for i in range(n):
        for g in range(8):
            cols = set((4 * g + np.asarray(chosen[i, g])).tolist())
            got = set(np.where(np.asarray(mask[i, 4 * g:4 * g + 4]))[0]
                      + 4 * g)
            assert cols == got


def test_mm_mask_not_worse_than_sm_mask_on_average():
    """The 𝔐 mask minimizes Eq.(12) within each group exactly, so its
    summed group loss must be ≤ the 𝔖 (diagonal) mask's group loss."""
    n, m = 32, 64
    w = jax.random.normal(jax.random.key(10), (n, m))
    hinv = dampened_inverse(random_psd_hessian(jax.random.key(11), m))
    losses = mrp.nm_group_losses(w, hinv, 2, 4)        # (n, G, 6)

    mask_m = mrp.select_nm_mask_mrp(w, hinv, 2, 4)
    from repro.core.scores import obs_score
    from repro.core.masks import nm_mask_from_scores
    mask_s = nm_mask_from_scores(obs_score(w, hinv), 2, 4)

    def group_loss(mask):
        combos = np.asarray(mrp.nm_combinations(2, 4))
        mg = np.asarray(mask).reshape(n, -1, 4)
        total = 0.0
        for i in range(n):
            for g in range(mg.shape[1]):
                cols = tuple(np.where(mg[i, g])[0])
                ci = [t for t, c in enumerate(map(tuple, combos))
                      if c == cols][0]
                total += float(losses[i, g, ci])
        return total

    assert group_loss(mask_m) <= group_loss(mask_s) + 1e-6
