"""Whole-model pruning engine: end-to-end quality + fault tolerance."""


import jax
import numpy as np
import pytest

from conftest import eval_ppl
from repro.ckpt import PruneProgressStore
from repro.core import PruningEngine
from repro.core.engine import summarize
from repro.data import calibration_batches


@pytest.fixture(scope="module")
def calib(tiny_lm):
    model, params, pipe = tiny_lm
    return calibration_batches(model.cfg, n_samples=16, seq_len=64, batch=8)


def test_engine_prunes_all_linears(tiny_lm, calib):
    model, params, pipe = tiny_lm
    eng = PruningEngine(model, "2:4", method="SM", blocksize=64)
    pruned, reports = eng.run(params, calib)
    s = summarize(reports)
    # 4 layers × (4 attn + 3 mlp) linears
    assert s["linears"] == model.cfg.num_layers * 7
    assert abs(s["mean_sparsity"] - 0.5) < 1e-6


def test_engine_ppl_ordering(tiny_lm, calib):
    """Paper Table-1 ordering on the tiny model: dense < SM ≤ SS(SparseGPT)
    < magnitude."""
    model, params, pipe = tiny_lm
    dense = eval_ppl(model, params, pipe)
    ppl = {}
    for method in ("magnitude", "SS", "SM"):
        eng = PruningEngine(model, "2:4", method=method, blocksize=64)
        pruned, _ = eng.run(params, calib)
        ppl[method] = eval_ppl(model, pruned, pipe)
    assert dense < ppl["SM"]
    assert ppl["SM"] <= ppl["SS"] * 1.02
    assert ppl["SS"] < ppl["magnitude"]


def test_engine_skip_patterns(tiny_lm, calib):
    model, params, pipe = tiny_lm
    eng = PruningEngine(model, "2:4", method="SM", blocksize=64,
                        skip=("mlp",))
    _, reports = eng.run(params, calib)
    assert all("mlp" not in r.name for r in reports)
    assert any("attn" in r.name for r in reports)


def test_engine_resume_mid_model(tiny_lm, calib, tmp_path):
    """Kill after N segments → resume → identical final params."""
    model, params, pipe = tiny_lm
    out = str(tmp_path / "prog")

    # uninterrupted reference
    eng_ref = PruningEngine(model, "2:4", method="SM", blocksize=64)
    ref_params, _ = eng_ref.run(params, calib)

    # interrupted: a store that raises after 2 segment saves
    class Bomb(PruneProgressStore):
        def __init__(self, root, fuse):
            super().__init__(root)
            self.fuse = fuse

        def save(self, next_segment, p):
            super().save(next_segment, p)
            self.fuse -= 1
            if self.fuse == 0:
                raise RuntimeError("simulated node failure")

    with pytest.raises(RuntimeError):
        PruningEngine(model, "2:4", method="SM", blocksize=64,
                      progress_store=Bomb(out, fuse=2)).run(params, calib)

    # resume with a fresh engine + fresh store on the same dir
    eng2 = PruningEngine(model, "2:4", method="SM", blocksize=64,
                         progress_store=PruneProgressStore(out))
    res_params, reports = eng2.run(params, calib)
    # only the remaining segments were pruned in the resumed run
    assert len(reports) < model.cfg.num_layers * 7
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(res_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_unstructured_engine(tiny_lm, calib):
    model, params, pipe = tiny_lm
    eng = PruningEngine(model, "0.5", method="SM", blocksize=64)
    pruned, reports = eng.run(params, calib)
    s = summarize(reports)
    assert abs(s["mean_sparsity"] - 0.5) < 0.02
    assert eval_ppl(model, pruned, pipe) < 3 * eval_ppl(model, params, pipe)
